//! Figure 1 reproduction: YOSO-m vs YOSO-E vs softmax on the 3-sphere.
//!
//! Random K in R^{32x3}, V in R^{32x1}; queries sweep the unit sphere on
//! a (theta, phi) grid. Emits `results/fig1_sphere.csv` with columns
//! theta,phi,softmax,yoso_e,yoso_8,yoso_32 — the surfaces the paper
//! renders — and prints the correlation between each estimate and YOSO-E.
//!
//! Run: `cargo run --release --example sphere_vis`

use std::io::Write;
use yoso::attention::{Attention, SoftmaxAttention, YosoAttention, YosoE};
use yoso::tensor::Mat;
use yoso::util::Rng;

fn correlation(a: &[f32], b: &[f32]) -> f64 {
    let n = a.len() as f64;
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (&x, &y) in a.iter().zip(b) {
        num += (x as f64 - ma) * (y as f64 - mb);
        da += (x as f64 - ma).powi(2);
        db += (y as f64 - mb).powi(2);
    }
    num / (da.sqrt() * db.sqrt()).max(1e-12)
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(3);
    let k = Mat::randn(32, 3, 1.0, &mut rng).unit_rows();
    let v = Mat::randn(32, 1, 1.0, &mut rng);

    // query grid over the sphere
    let steps = 48usize;
    let mut queries = Mat::zeros(steps * steps, 3);
    let mut angles = Vec::with_capacity(steps * steps);
    for ti in 0..steps {
        let theta = std::f32::consts::PI * ti as f32 / (steps - 1) as f32;
        for pi in 0..steps {
            let phi = std::f32::consts::TAU * pi as f32 / (steps - 1) as f32;
            let row = queries.row_mut(ti * steps + pi);
            row[0] = theta.sin() * phi.cos();
            row[1] = theta.sin() * phi.sin();
            row[2] = theta.cos();
            angles.push((theta, phi));
        }
    }

    // raw (unnormalized) outputs: with dv = 1 the l2 normalization would
    // collapse everything to +-1; the paper's surfaces are raw B V values.
    let tau = 6;
    let softmax = SoftmaxAttention.forward(&queries, &k, &v, &mut rng);
    let yoso_e = YosoE { tau }.forward_raw(&queries, &k, &v);
    let yoso_8 = YosoAttention::new(tau, 8, false).forward_raw(&queries, &k, &v, &mut rng);
    let yoso_32 = YosoAttention::new(tau, 32, false).forward_raw(&queries, &k, &v, &mut rng);

    std::fs::create_dir_all("results")?;
    let mut f = std::fs::File::create("results/fig1_sphere.csv")?;
    writeln!(f, "theta,phi,softmax,yoso_e,yoso_8,yoso_32")?;
    for (i, (theta, phi)) in angles.iter().enumerate() {
        writeln!(
            f,
            "{theta},{phi},{},{},{},{}",
            softmax.at(i, 0),
            yoso_e.at(i, 0),
            yoso_8.at(i, 0),
            yoso_32.at(i, 0)
        )?;
    }

    println!("Figure 1 sphere visualization -> results/fig1_sphere.csv");
    println!("correlation with YOSO-E over the sphere:");
    println!("  softmax : {:.4}", correlation(&softmax.data, &yoso_e.data));
    println!("  yoso-8  : {:.4}", correlation(&yoso_8.data, &yoso_e.data));
    println!("  yoso-32 : {:.4}", correlation(&yoso_32.data, &yoso_e.data));
    println!("(paper: YOSO-m surfaces converge to YOSO-E, which closely \
              tracks softmax)");
    Ok(())
}
