//! End-to-end driver (the repo's headline validation): pretrain a
//! transformer with YOSO attention on the synthetic corpus, through all
//! three layers — Rust data pipeline + loop, fused HLO train step (L2),
//! YOSO estimators (L1) — logging the loss curve, evaluating, and saving
//! a checkpoint that the GLUE fine-tuning path consumes.
//!
//! Run: `cargo run --release --example pretrain_e2e`
//! Env: YOSO_E2E_STEPS (default 300), YOSO_E2E_VARIANT (default yoso_32)

use std::path::Path;
use yoso::metrics::Recorder;
use yoso::runtime::Runtime;
use yoso::train::{PretrainSource, Trainer};
use yoso::data::corpus::{CorpusConfig, CorpusGenerator};
use yoso::data::mlm::{MlmConfig, PretrainStream};
use yoso::data::tokenizer::WordTokenizer;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    yoso::util::log::init_from_env();
    let steps = env_usize("YOSO_E2E_STEPS", 300);
    let variant =
        std::env::var("YOSO_E2E_VARIANT").unwrap_or_else(|_| "yoso_32".into());

    let rt = Runtime::open(Path::new("artifacts"))?;
    let mut trainer = Trainer::new(
        &rt,
        &format!("train_pretrain_{variant}"),
        Some(&format!("eval_pretrain_{variant}")),
        42,
        None,
    )?;
    println!(
        "pretraining {variant}: {} parameters, {} steps, batch 16, seq 128",
        trainer.param_template.total_elements(),
        steps
    );

    let source = PretrainSource {
        stream: PretrainStream::new(
            CorpusGenerator::new(CorpusConfig::default()),
            WordTokenizer { n_words: 2000 },
            MlmConfig::default(),
            42,
        ),
    };

    let mut rec = Recorder::new();
    let t = yoso::util::Timer::start();
    trainer.run(&source, steps, 1e-3, (steps / 4).max(1), 4, (steps / 20).max(1),
                &mut rec)?;
    let train_secs = t.elapsed_secs();

    let eval = trainer.evaluate(&source, 8)?;
    println!("\n=== end-to-end result ({variant}, {steps} steps) ===");
    println!("wall time           {train_secs:.1} s ({:.2} s/step)",
             train_secs / steps as f64);
    println!("final train loss    {:.4}", rec.last("train_loss").unwrap());
    println!("eval MLM perplexity {:.2}", eval.mlm_perplexity);
    println!("eval MLM accuracy   {:.4}", eval.accuracy);
    println!("eval SOP accuracy   {:.4}", eval.sop_accuracy);

    std::fs::create_dir_all("results")?;
    rec.write_csv(Path::new(&format!("results/pretrain_e2e_{variant}.csv")))?;
    trainer.save_checkpoint(Path::new(&format!(
        "results/checkpoints/pretrain_{variant}.ckpt"
    )))?;
    println!("\nloss curve  -> results/pretrain_e2e_{variant}.csv");
    println!("checkpoint  -> results/checkpoints/pretrain_{variant}.ckpt");
    println!("(fine-tune it: ./target/release/yoso finetune --task mrpc \
              --variant {variant} --checkpoint results/checkpoints/pretrain_{variant}.ckpt)");
    Ok(())
}
