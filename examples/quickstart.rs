//! Quickstart: the YOSO public API in five minutes.
//!
//! 1. pure-Rust YOSO attention vs exact softmax on random data;
//! 2. convergence of YOSO-m to YOSO-E as m grows;
//! 3. (if `make artifacts` has run) executing the Pallas-lowered YOSO
//!    attention op through the PJRT runtime.
//!
//! Run: `cargo run --release --example quickstart`

use std::path::Path;
use yoso::attention::{Attention, SoftmaxAttention, YosoAttention, YosoE};
use yoso::runtime::literal::{f32_literal, i32_literal, to_f32_vec};
use yoso::runtime::Runtime;
use yoso::tensor::Mat;
use yoso::util::stats::radians_between;
use yoso::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0);
    let (n, d) = (256, 64);
    let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
    let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
    let v = Mat::randn(n, d, 1.0, &mut rng);

    // 1. softmax vs YOSO
    let softmax = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
    let yoso = YosoAttention::new(8, 32, false).forward(&q, &k, &v, &mut rng);
    println!("softmax out[0][..4]  = {:?}", &softmax.row(0)[..4]);
    println!("yoso-32 out[0][..4]  = {:?}", &yoso.row(0)[..4]);

    // 2. YOSO-m -> YOSO-E convergence
    let expectation = YosoE { tau: 8 }.forward(&q, &k, &v, &mut rng);
    println!("\nconvergence to YOSO-E (mean radians, lower is better):");
    for m in [8usize, 16, 32, 64, 128] {
        let est = YosoAttention::new(8, m, false).forward(&q, &k, &v, &mut rng);
        let err: f64 = (0..n)
            .map(|i| radians_between(est.row(i), expectation.row(i)))
            .sum::<f64>()
            / n as f64;
        println!("  m = {m:>3}: {err:.4} rad");
    }

    // 3. the AOT path (optional)
    let artifacts = Path::new("artifacts");
    if artifacts.join("manifest.json").exists() {
        println!("\nexecuting Pallas-lowered attn_yoso_m8_n256 via PJRT:");
        let rt = Runtime::open(artifacts)?;
        let art = rt.artifact("attn_yoso_m8_n256")?;
        let inputs = vec![
            f32_literal(&q.data, &[n, d])?,
            f32_literal(&k.data, &[n, d])?,
            f32_literal(&v.data, &[n, d])?,
            i32_literal(&[7], &[])?,
        ];
        let out = art.execute(&inputs)?;
        let y = to_f32_vec(&out[0])?;
        println!("  artifact out[..4] = {:?}", &y[..4]);
        println!("  (row norm: {:.4})",
                 y[..d].iter().map(|x| x * x).sum::<f32>().sqrt());
    } else {
        println!("\n(run `make artifacts` to also demo the PJRT path)");
    }
    Ok(())
}
