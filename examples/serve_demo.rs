//! Serving demo: dynamic-batched inference over the AOT forward artifact,
//! with a warmup phase (artifact compilation) excluded from the reported
//! latencies, an open-loop arrival process, and a latency/throughput
//! report — the serving-coordinator path of the stack.
//!
//! Run: `cargo run --release --example serve_demo`
//! Env: YOSO_SERVE_REQUESTS (default 512), YOSO_SERVE_VARIANT (yoso_32)

use std::path::PathBuf;
use std::time::Duration;
use yoso::data::glue_synth::{GlueGenerator, GlueTask};
use yoso::serve::{BatchPolicy, ServerHandle};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    yoso::util::log::init_from_env();
    let n_requests = env_usize("YOSO_SERVE_REQUESTS", 512);
    let variant =
        std::env::var("YOSO_SERVE_VARIANT").unwrap_or_else(|_| "yoso_32".into());

    let handle = ServerHandle::spawn(
        PathBuf::from("artifacts"),
        format!("fwd_glue_{variant}"),
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(4) },
        42,
        None,
    );

    let gen = GlueGenerator::new(GlueTask::Qnli, 128, 7);

    // warmup: first request triggers artifact compilation
    println!("warming up (compiles fwd_glue_{variant})...");
    let ex = gen.example(u64::MAX - 1);
    handle.submit(ex.input_ids, ex.segment_ids).recv()?;

    println!("driving {n_requests} requests (open loop)...");
    let t = yoso::util::Timer::start();
    let mut receivers = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let ex = gen.example(i as u64);
        receivers.push(handle.submit(ex.input_ids, ex.segment_ids));
        // open-loop arrivals: a small gap every few requests
        if i % 4 == 3 {
            std::thread::sleep(Duration::from_micros(300));
        }
    }
    let mut latencies = Vec::with_capacity(n_requests);
    let mut class_counts = [0usize; 3];
    for rx in receivers {
        let resp = rx.recv()?;
        latencies.push(resp.total_ms);
        let arg = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        class_counts[arg.min(2)] += 1;
    }
    let wall = t.elapsed_secs();
    let stats = handle.shutdown()?;

    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| yoso::util::stats::percentile(&latencies, q);
    println!("\n=== serving report (fwd_glue_{variant}) ===");
    println!("requests        {n_requests} in {wall:.2} s  ->  {:.1} req/s",
             n_requests as f64 / wall);
    println!("batches         {} (mean occupancy {:.1})", stats.batches,
             stats.requests as f64 / stats.batches.max(1) as f64);
    println!("latency ms      p50 {:.2}  p90 {:.2}  p99 {:.2}",
             pct(0.5), pct(0.9), pct(0.99));
    println!("queue wait ms   p50 {:.2}  p99 {:.2}",
             stats.queue_latency.p50, stats.queue_latency.p99);
    println!("class counts    {class_counts:?}");
    Ok(())
}
