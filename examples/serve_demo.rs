//! Serving demo. Two modes:
//!
//! * **gateway** (default, artifact-free): the multi-replica
//!   `serve::gateway` over the pure-Rust CPU encoder — length-bucketed
//!   batching, bounded-queue admission control, deadline sheds, and the
//!   per-bucket/per-replica latency histogram report.
//! * **artifact** (`YOSO_SERVE_ARTIFACTS=1`): the single-loop PJRT
//!   artifact path with dynamic batching, as before (needs
//!   `make artifacts`).
//!
//! Run: `cargo run --release --example serve_demo`
//! Env: YOSO_SERVE_REQUESTS (default 512), YOSO_SERVE_VARIANT (yoso_32),
//!      YOSO_SERVE_REPLICAS (default: available cores),
//!      YOSO_SERVE_RPS (open-loop offered load, default 300)

use std::path::PathBuf;
use std::time::{Duration, Instant};
use yoso::attention::KernelVariant;
use yoso::data::glue_synth::{GlueGenerator, GlueTask};
use yoso::model::encoder::EncoderConfig;
use yoso::serve::{
    BatchPolicy, BatchPolicyTable, BucketLayout, CpuServeConfig, Gateway,
    GatewayConfig, SchedPolicy, ServerHandle, ShedPolicy,
};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    yoso::util::log::init_from_env();
    if std::env::var("YOSO_SERVE_ARTIFACTS").as_deref() == Ok("1") {
        return artifact_demo();
    }
    gateway_demo()
}

/// Open-loop load against the CPU gateway; prints the merged stats.
fn gateway_demo() -> anyhow::Result<()> {
    let n_requests = env_usize("YOSO_SERVE_REQUESTS", 512);
    let replicas = env_usize(
        "YOSO_SERVE_REPLICAS",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    let rps = env_usize("YOSO_SERVE_RPS", 300) as f64;
    let variant =
        std::env::var("YOSO_SERVE_VARIANT").unwrap_or_else(|_| "yoso_32".into());

    let encoder = EncoderConfig::base(2005, 128, 2);
    let mut cfg = GatewayConfig::new(CpuServeConfig {
        attention: variant.clone(),
        encoder,
        threads: 1, // replicas are the parallelism axis
        chunk_policy: Default::default(),
        kernel: KernelVariant::from_env(), // YOSO_KERNEL A/Bs the demo too
        seed: 42,
    });
    cfg.replicas = replicas;
    cfg.queue_capacity = 128;
    cfg.shed = ShedPolicy::Reject;
    // width-scaled per-bucket policies + work-conserving deadline-aware
    // scheduling: the production defaults, spelled out for the demo
    cfg.batch = BatchPolicyTable::scaled(BatchPolicy {
        max_batch: 16,
        max_wait: Duration::from_millis(2),
    });
    cfg.sched = SchedPolicy::Conserve;
    cfg.buckets = BucketLayout::pow2(16, 128);
    let gw = Gateway::spawn(cfg);

    // variable-length GLUE-style requests: short ones ride small buckets
    let gen = GlueGenerator::new(GlueTask::Qnli, 128, 7);
    println!(
        "gateway demo: {n_requests} requests at ~{rps:.0} req/s offered, \
         {replicas} replicas, attention {variant}"
    );
    let gap = Duration::from_secs_f64(1.0 / rps.max(1.0));
    let start = Instant::now();
    let mut rxs = Vec::with_capacity(n_requests);
    let mut rejected = 0usize;
    for i in 0..n_requests {
        let target = start + gap * i as u32;
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        let ex = gen.example(i as u64);
        // a slice of traffic carries deadlines, exercising late sheds
        let deadline = (i % 8 == 7).then(|| Duration::from_millis(250));
        match gw.submitter().submit_with_deadline(
            ex.input_ids,
            ex.segment_ids,
            deadline,
        ) {
            Ok(rx) => rxs.push(rx),
            Err(_) => rejected += 1,
        }
    }
    let mut served = 0usize;
    let mut late_shed = 0usize;
    for rx in rxs {
        match rx.recv()? {
            Ok(_) => served += 1,
            Err(_) => late_shed += 1,
        }
    }
    let stats = gw.shutdown();
    println!(
        "\nclient view: {served} served, {late_shed} deadline-shed, \
         {rejected} rejected at admission"
    );
    print!("{stats}");
    Ok(())
}

/// The original artifact-path demo (single loop, PJRT executor).
fn artifact_demo() -> anyhow::Result<()> {
    let n_requests = env_usize("YOSO_SERVE_REQUESTS", 512);
    let variant =
        std::env::var("YOSO_SERVE_VARIANT").unwrap_or_else(|_| "yoso_32".into());

    let handle = ServerHandle::spawn(
        PathBuf::from("artifacts"),
        format!("fwd_glue_{variant}"),
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(4) },
        42,
        None,
    );

    let gen = GlueGenerator::new(GlueTask::Qnli, 128, 7);

    // warmup: first request triggers artifact compilation
    println!("warming up (compiles fwd_glue_{variant})...");
    let ex = gen.example(u64::MAX - 1);
    handle.submit(ex.input_ids, ex.segment_ids).recv()?;

    println!("driving {n_requests} requests (open loop)...");
    let t = yoso::util::Timer::start();
    let mut receivers = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let ex = gen.example(i as u64);
        receivers.push(handle.submit(ex.input_ids, ex.segment_ids));
        // open-loop arrivals: a small gap every few requests
        if i % 4 == 3 {
            std::thread::sleep(Duration::from_micros(300));
        }
    }
    let mut class_counts = [0usize; 3];
    for rx in receivers {
        let resp = rx.recv()?;
        let arg = resp
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0);
        class_counts[arg.min(2)] += 1;
    }
    let wall = t.elapsed_secs();
    let stats = handle.shutdown()?;

    println!("\n=== serving report (fwd_glue_{variant}) ===");
    println!("wall            {wall:.2} s");
    println!("{stats}");
    println!("class counts    {class_counts:?}");
    Ok(())
}
