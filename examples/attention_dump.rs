//! Figure 6 reproduction: attention matrices from a model's Q, K —
//! softmax vs YOSO-m realizations (first 64 tokens), as CSV heat maps.
//!
//! Uses the pure-Rust encoder over a trained checkpoint when one exists
//! (`results/checkpoints/pretrain_yoso_32.ckpt`, produced by the
//! pretrain_e2e example), else freshly initialized weights.
//!
//! Run: `cargo run --release --example attention_dump`

use std::io::Write;
use std::path::Path;
use yoso::attention::SoftmaxAttention;
use yoso::data::glue_synth::{GlueGenerator, GlueTask};
use yoso::lsh::{collision_probability, Hasher, HyperplaneHasher};
use yoso::model::encoder::{pad_to, Encoder, EncoderConfig};
use yoso::model::ParamSet;
use yoso::runtime::Runtime;
use yoso::tensor::Mat;
use yoso::util::Rng;

fn write_matrix(path: &str, m: &Mat) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    for i in 0..m.rows {
        let row: Vec<String> = m.row(i).iter().map(|x| format!("{x:.5}")).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let n_vis = 64usize;
    std::fs::create_dir_all("results")?;

    // model weights: trained checkpoint if available
    let ckpt = Path::new("results/checkpoints/pretrain_yoso_32.ckpt");
    let params: ParamSet = if ckpt.exists() {
        println!("using trained checkpoint {ckpt:?}");
        yoso::train::checkpoint::load(ckpt)?
    } else {
        println!("no checkpoint found; using initialized weights \
                  (run `cargo run --release --example pretrain_e2e` first \
                  for the trained-figure variant)");
        let rt = Runtime::open(Path::new("artifacts"))?;
        ParamSet::init_for(rt.manifest.get("train_pretrain_yoso_32")?, 0)
    };

    let cfg = EncoderConfig::base(2048, 128, 3);
    let enc = Encoder::new(cfg, &params);

    // a real input sequence from the synthetic corpus
    let gen = GlueGenerator::new(GlueTask::Qnli, 128, 9);
    let ex = gen.example(0);
    let (ids, segs) = pad_to(&ex.input_ids, &ex.segment_ids, 128);

    let mut rng = Rng::new(0);
    let (q, k) = enc.layer_qk(1, &ids, &segs, 0, &SoftmaxAttention, &mut rng);

    // softmax attention matrix (first n_vis tokens)
    let mut scores = q.matmul_t(&k);
    scores.scale(1.0 / (q.cols as f32).sqrt());
    scores.softmax_rows();
    let softmax_vis = Mat::from_fn(n_vis, n_vis, |i, j| scores.at(i, j));
    write_matrix("results/fig6_softmax.csv", &softmax_vis)?;

    // YOSO expectation + realizations
    let tau = 8;
    let qn = q.unit_rows();
    let kn = k.unit_rows();
    let mut expect = Mat::zeros(n_vis, n_vis);
    for i in 0..n_vis {
        for j in 0..n_vis {
            let sim = yoso::tensor::linalg::dot(qn.row(i), kn.row(j));
            expect.set(i, j, collision_probability(sim as f64, tau) as f32);
        }
    }
    write_matrix("results/fig6_yoso_e.csv", &expect)?;

    for m in [16usize, 64] {
        let hasher = HyperplaneHasher::new(&mut rng, m, q.cols, tau as usize);
        let cq = hasher.hash_all(&qn);
        let ck = hasher.hash_all(&kn);
        let n = qn.rows;
        let mut bhat = Mat::zeros(n_vis, n_vis);
        for h in 0..m {
            for i in 0..n_vis {
                for j in 0..n_vis {
                    if cq[h * n + i] == ck[h * n + j] {
                        let cur = bhat.at(i, j);
                        bhat.set(i, j, cur + 1.0 / m as f32);
                    }
                }
            }
        }
        write_matrix(&format!("results/fig6_yoso_{m}.csv"), &bhat)?;
        // pattern-preservation score: correlation with the expectation
        let mut num = 0.0f64;
        let mut da = 0.0f64;
        let mut db = 0.0f64;
        let ma = expect.data.iter().map(|&x| x as f64).sum::<f64>()
            / expect.data.len() as f64;
        let mb = bhat.data.iter().map(|&x| x as f64).sum::<f64>()
            / bhat.data.len() as f64;
        for (&a, &b) in expect.data.iter().zip(&bhat.data) {
            num += (a as f64 - ma) * (b as f64 - mb);
            da += (a as f64 - ma).powi(2);
            db += (b as f64 - mb).powi(2);
        }
        println!("yoso-{m} vs YOSO-E pattern correlation: {:.4}",
                 num / (da.sqrt() * db.sqrt()).max(1e-12));
    }

    println!("attention matrices -> results/fig6_{{softmax,yoso_e,yoso_16,yoso_64}}.csv");
    Ok(())
}
