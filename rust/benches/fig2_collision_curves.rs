//! Figure 2: collision probability vs exp attention weight, their
//! derivatives, and the backward lower bound, for tau = 8.
//!
//! Emits results/fig2_curves.csv and prints spot checks. The paper's
//! claim: both curves are monotone with positive curvature on [-1, 1],
//! and (tau/2) * p(sim) lower-bounds the true derivative.

use std::io::Write;
use yoso::bench_support::smoke_or;
use yoso::lsh::collision::{collision_probability, collision_probability_grad,
                           collision_probability_grad_lower_bound, exp_weight};

fn main() {
    let tau = 8u32;
    std::fs::create_dir_all("results").unwrap();
    let mut f = std::fs::File::create("results/fig2_curves.csv").unwrap();
    writeln!(f, "sim,exp_weight,collision_prob,exp_grad,collision_grad,lower_bound")
        .unwrap();

    let steps = smoke_or(50, 400);
    let mut max_gap: f64 = 0.0;
    let mut violations = 0usize;
    for i in 0..=steps {
        let sim = -1.0 + 2.0 * i as f64 / steps as f64;
        let e = exp_weight(sim, tau);
        let p = collision_probability(sim, tau);
        let eg = tau as f64 * e; // d/dsim exp(tau (sim-1))
        let pg = collision_probability_grad(sim, tau);
        let lb = collision_probability_grad_lower_bound(sim, tau);
        writeln!(f, "{sim},{e},{p},{eg},{pg},{lb}").unwrap();
        if lb > pg + 1e-9 {
            violations += 1;
        }
        max_gap = max_gap.max((e - p).abs());
    }

    println!("Figure 2 curves -> results/fig2_curves.csv  (tau = {tau})");
    println!("{:>6} {:>12} {:>12} {:>12} {:>12}", "sim", "exp", "collision",
             "grad", "lower-bnd");
    for sim in [-0.8, -0.4, 0.0, 0.4, 0.8, 0.95] {
        println!(
            "{:>6.2} {:>12.5} {:>12.5} {:>12.5} {:>12.5}",
            sim,
            exp_weight(sim, tau),
            collision_probability(sim, tau),
            collision_probability_grad(sim, tau),
            collision_probability_grad_lower_bound(sim, tau)
        );
    }
    println!("\nlower-bound violations: {violations} (expect 0)");
    println!("max |exp - collision| on [-1,1]: {max_gap:.4} \
              (curves agree in shape, not value — as in the paper)");
    assert_eq!(violations, 0);
}
