//! Streaming amortization (serving scenario family): per-token cost of
//! keeping a session's encoder state current, streamed vs re-encoded.
//!
//! For each session length L, three costs:
//!
//! * `append` — amortized ms/token to absorb L tokens one at a time
//!   into an `EncoderStream` (the O(m·dv) accumulator update; no
//!   logits);
//! * `classify` — ms to produce logits from the live session (PAD-tail
//!   overlay + upper layers; paid only when logits are needed);
//! * `full` — ms for one cold bucketed batch encode of the same L
//!   tokens (what a cache miss, or a gateway without the prefix cache,
//!   pays per request).
//!
//! Plus the gateway end to end: the same request submitted twice
//! through a `Gateway` with the prefix cache on — the second submit
//! checks the whole session out (`cache_hits == 1`) and pays only the
//! classify, which is the measured hit-path speedup.
//!
//! Writes results/fig_stream.csv with columns
//! `mode,session_len,ms_per_token,ms_total,cache_hits,cache_misses`.
//!
//! Regression gate (CI smoke mode, `YOSO_BENCH_SMOKE=1`; full runs only
//! warn): at the largest smoke session length, the streamed append must
//! beat the full re-encode by >= 2x per token — if appending a token
//! costs half a re-encode, the incremental path has regressed into a
//! rebuild.

use std::io::Write;
use std::sync::Arc;
use std::time::{Duration, Instant};
use yoso::attention::{
    Attention, ChunkPolicy, KernelVariant, MultiHeadAttention, YosoAttention,
};
use yoso::bench_support::{smoke, smoke_or};
use yoso::model::encoder::{
    bucket_len, encoder_abi_spec, serving_rng, Encoder, EncoderConfig,
    EncoderStream,
};
use yoso::model::ParamSet;
use yoso::serve::{CpuServeConfig, Gateway, GatewayConfig};
use yoso::util::Rng;

fn session_tokens(len: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let ids = (0..len).map(|_| 5 + rng.below(1990) as i32).collect();
    let segs = vec![0i32; len];
    (ids, segs)
}

/// Amortized ms/token: absorb the session one token at a time.
fn time_append(
    enc: &Encoder,
    att: &YosoAttention,
    seed: u64,
    width: usize,
    ids: &[i32],
    segs: &[i32],
    reps: usize,
) -> f64 {
    let mut total = Duration::ZERO;
    for _ in 0..reps {
        let mut s = EncoderStream::new(enc, att, seed, width);
        let t0 = Instant::now();
        for (id, seg) in ids.iter().zip(segs) {
            s.append(enc, std::slice::from_ref(id), std::slice::from_ref(seg));
        }
        total += t0.elapsed();
        std::hint::black_box(s.len());
    }
    total.as_secs_f64() * 1e3 / (reps * ids.len()) as f64
}

/// ms per logits readout from a live session.
fn time_classify(
    enc: &Encoder,
    att: &YosoAttention,
    seed: u64,
    width: usize,
    ids: &[i32],
    segs: &[i32],
    reps: usize,
) -> f64 {
    let mut s = EncoderStream::new(enc, att, seed, width);
    s.append(enc, ids, segs);
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(s.classify(enc));
    }
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

/// ms per cold bucketed batch encode of the whole session.
fn time_full(
    enc: &Encoder,
    shared: &Arc<dyn Attention>,
    mh: &MultiHeadAttention,
    seed: u64,
    width: usize,
    ids: &[i32],
    segs: &[i32],
    reps: usize,
) -> f64 {
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(enc.classify_bucketed(
            ids,
            segs,
            width,
            shared,
            mh,
            &mut serving_rng(seed, width),
        ));
    }
    t0.elapsed().as_secs_f64() * 1e3 / reps as f64
}

fn main() {
    yoso::util::log::init_from_env();
    let ecfg = smoke_or(
        EncoderConfig {
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            vocab_size: 2005,
            max_len: 64,
            n_classes: 2,
        },
        EncoderConfig::base(2005, 128, 2),
    );
    let lens: Vec<usize> = smoke_or(vec![12, 32], vec![16, 48, 96]);
    let reps = smoke_or(3, 10);
    let seed = 42u64;
    let att = YosoAttention::new(8, 8, false);
    let shared: Arc<dyn Attention> = Arc::new(att.clone());
    let mh = MultiHeadAttention::serial_with_policy(ChunkPolicy::default());
    let params = ParamSet::init_for(&encoder_abi_spec(&ecfg), seed);
    let enc = Encoder::new(ecfg.clone(), &params);

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/fig_stream.csv").unwrap();
    writeln!(
        csv,
        "mode,session_len,ms_per_token,ms_total,cache_hits,cache_misses"
    )
    .unwrap();

    println!("Streaming amortization — per-token session cost\n");
    println!(
        "{:>5} {:>16} {:>16} {:>12} {:>10}",
        "L", "append ms/tok", "full ms/tok", "classify ms", "ratio"
    );
    let mut gate_ratio = 0.0f64;
    for &len in &lens {
        let (ids, segs) = session_tokens(len, 7 + len as u64);
        let width = bucket_len(len, ecfg.max_len);
        let app = time_append(&enc, &att, seed, width, &ids, &segs, reps);
        let cls = time_classify(&enc, &att, seed, width, &ids, &segs, reps);
        let full =
            time_full(&enc, &shared, &mh, seed, width, &ids, &segs, reps);
        let full_per_tok = full / len as f64;
        let ratio = full_per_tok / app.max(1e-9);
        gate_ratio = ratio; // the largest length runs last
        writeln!(csv, "append,{len},{app:.6},{:.6},0,0", app * len as f64)
            .unwrap();
        writeln!(csv, "classify,{len},{:.6},{cls:.6},0,0", cls / len as f64)
            .unwrap();
        writeln!(csv, "full,{len},{full_per_tok:.6},{full:.6},0,0").unwrap();
        println!(
            "{len:>5} {app:>16.5} {full_per_tok:>16.5} {cls:>12.4} \
             {ratio:>9.2}x"
        );
    }

    // gateway end to end: identical request twice; the repeat checks
    // the whole session out of the prefix cache and pays only the
    // classify — the hit-path speedup, measured at the front door
    let gw_len = *lens.last().unwrap();
    let (ids, segs) = session_tokens(gw_len, 99);
    let gw = Gateway::spawn(GatewayConfig::new(CpuServeConfig {
        attention: "yoso_8".into(),
        encoder: ecfg.clone(),
        threads: 1,
        chunk_policy: ChunkPolicy::default(),
        kernel: KernelVariant::from_env(),
        seed,
    }));
    let serve_ms = |ids: &[i32], segs: &[i32]| {
        let t0 = Instant::now();
        gw.submit(ids.to_vec(), segs.to_vec())
            .expect("admitted")
            .recv()
            .unwrap()
            .expect("served");
        t0.elapsed().as_secs_f64() * 1e3
    };
    let cold_ms = serve_ms(&ids, &segs);
    let hit_ms = serve_ms(&ids, &segs);
    let stats = gw.shutdown();
    writeln!(
        csv,
        "gateway_cold,{gw_len},{:.6},{cold_ms:.6},{},{}",
        cold_ms / gw_len as f64,
        stats.cache_hits,
        stats.cache_misses
    )
    .unwrap();
    writeln!(
        csv,
        "gateway_hit,{gw_len},{:.6},{hit_ms:.6},{},{}",
        hit_ms / gw_len as f64,
        stats.cache_hits,
        stats.cache_misses
    )
    .unwrap();
    println!(
        "\ngateway (L={gw_len}): cold {cold_ms:.3} ms, cached repeat \
         {hit_ms:.3} ms ({:.2}x) — {} hits / {} misses",
        cold_ms / hit_ms.max(1e-9),
        stats.cache_hits,
        stats.cache_misses
    );
    println!("-> results/fig_stream.csv");

    println!(
        "\nstream gate: full re-encode vs streamed append at L={} — \
         {gate_ratio:.2}x per token (need >= 2x)",
        lens.last().unwrap()
    );
    let mut failed = false;
    if gate_ratio < 2.0 {
        println!(
            "WARNING: streamed append no longer beats full re-encode 2x \
             per token — the incremental path is doing rebuild-scale work"
        );
        failed = smoke();
    }
    if stats.cache_hits < 1 {
        println!(
            "WARNING: identical repeat request did not hit the gateway \
             prefix cache"
        );
        failed = failed || smoke();
    }
    if failed {
        // the bench-smoke CI job is the regression gate
        std::process::exit(1);
    }
}
