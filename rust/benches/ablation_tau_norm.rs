//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. **τ (hyperplanes per hash)** — controls the attention-weight decay
//!    rate (paper §3.2, Remark 3). Sweep τ and report (a) the sharpness
//!    of the expected attention (entropy of E[B] rows), (b) the
//!    approximation error of YOSO-32 against YOSO-E, (c) forward time.
//! 2. **ℓ2 output normalization (N-YOSO)** — the paper argues it replaces
//!    the softmax row normalization without hurting performance. Compare
//!    the *direction* of normalized vs unnormalized outputs: they must be
//!    identical (normalization is a positive row scaling), and the
//!    normalized output must be unit-length.
//! 3. **fast-Hadamard vs Gaussian projection** — the §3.2 speed-up:
//!    equal estimator quality at lower hashing cost.

use std::io::Write;
use yoso::attention::{YosoAttention, YosoE};
use yoso::bench_support::{bench, smoke_or};
use yoso::tensor::Mat;
use yoso::util::stats::radians_between;
use yoso::util::Rng;

fn mean_row_entropy(w: &Mat) -> f64 {
    let mut total = 0.0;
    for i in 0..w.rows {
        let sum: f64 = w.row(i).iter().map(|&x| x as f64).sum();
        let mut h = 0.0;
        for &x in w.row(i) {
            let p = (x as f64 / sum).max(1e-12);
            h -= p * p.ln();
        }
        total += h;
    }
    total / w.rows as f64
}

fn main() {
    let (n, d) = (smoke_or(128usize, 512), 64usize);
    let mut rng = Rng::new(0);
    let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
    let mut qn = k.clone();
    for x in qn.data.iter_mut() {
        *x += 0.8 * rng.normal();
    }
    let q = qn.unit_rows();
    let v = Mat::randn(n, d, 1.0, &mut rng);

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/ablation_tau.csv").unwrap();
    writeln!(csv, "tau,row_entropy,yoso32_radians,forward_ms").unwrap();

    println!("Ablation 1 — tau sweep (n = {n}, d = {d}, m = 32)\n");
    println!("{:>4} {:>14} {:>16} {:>12}", "tau", "row entropy",
             "rad(E, yoso-32)", "fwd ms");
    let mut entropies = Vec::new();
    for tau in [2usize, 4, 6, 8, 10] {
        // (a) sharpness of the expectation
        let e_attn = YosoE { tau };
        let mut w = q.matmul_t(&k);
        for x in w.data.iter_mut() {
            *x = yoso::lsh::collision_probability(*x as f64, tau as u32) as f32;
        }
        let entropy = mean_row_entropy(&w);
        // (b) estimator error at m = 32
        let e = e_attn.forward_raw(&q, &k, &v);
        let est = YosoAttention::new(tau, 32, false).forward_raw(&q, &k, &v, &mut rng);
        let err: f64 = (0..n)
            .map(|i| radians_between(est.row(i), e.row(i)))
            .sum::<f64>()
            / n as f64;
        // (c) forward time
        let attn = YosoAttention::new(tau, 32, false);
        let mut r2 = Rng::new(1);
        let t = bench("tau", 1, 3, || {
            std::hint::black_box(attn.forward_raw(&q, &k, &v, &mut r2));
        });
        println!("{tau:>4} {entropy:>14.3} {err:>16.4} {:>12.2}",
                 t.summary.mean * 1e3);
        writeln!(csv, "{tau},{entropy},{err},{}", t.summary.mean * 1e3).unwrap();
        entropies.push(entropy);
    }
    // higher tau -> sharper attention (lower entropy), monotone
    for w in entropies.windows(2) {
        assert!(w[1] < w[0], "entropy must fall with tau: {entropies:?}");
    }

    println!("\nAblation 2 — l2 normalization (N-YOSO)\n");
    let raw = YosoAttention::new(8, 32, false);
    let mut r = Rng::new(42);
    let y_raw = raw.forward_raw(&q, &k, &v, &mut r);
    let mut y_norm = y_raw.clone();
    y_norm.l2_normalize_rows();
    let mut max_angle: f64 = 0.0;
    for i in 0..n {
        let norm: f32 = y_norm.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
        assert!(norm <= 1.0 + 1e-4);
        if y_raw.row(i).iter().any(|&x| x != 0.0) {
            max_angle = max_angle.max(radians_between(y_raw.row(i), y_norm.row(i)));
        }
    }
    println!("max direction change under l2 normalization: {max_angle:.2e} rad");
    println!("(normalization rescales rows only — information-preserving, \
              as the paper argues)");
    assert!(max_angle < 1e-3);

    println!("\nAblation 3 — Gaussian vs fast-Hadamard projection (m = 64)\n");
    let e = YosoE { tau: 6 }.forward_raw(&q, &k, &v);
    for (label, fast) in [("gaussian", false), ("hadamard", true)] {
        let attn = YosoAttention::new(6, 64, fast);
        let mut r = Rng::new(5);
        let est = attn.forward_raw(&q, &k, &v, &mut r);
        let err: f64 = (0..n)
            .map(|i| radians_between(est.row(i), e.row(i)))
            .sum::<f64>()
            / n as f64;
        let mut r2 = Rng::new(6);
        let t = bench(label, 1, 3, || {
            std::hint::black_box(attn.forward_raw(&q, &k, &v, &mut r2));
        });
        println!("{label:<10} rad(E) = {err:.4}   fwd = {:.2} ms",
                 t.summary.mean * 1e3);
    }
    println!("\n-> results/ablation_tau.csv");
}
