//! Figure 9 (serving scenario family): gateway latency and throughput
//! under offered load — a **closed-loop** generator (workers submit,
//! wait, repeat: natural backpressure, measures the service ceiling) and
//! an **open-loop** generator (paced arrivals at a target rate,
//! independent of completions: measures queueing and shed behavior under
//! overload), swept over offered load × replicas × bucketing on/off,
//! plus a **scheduler A/B** (work-conserving `conserve` vs the PR-3
//! `fifo` baseline) on a skewed-bucket workload.
//!
//! Writes results/fig9_serve_load.csv with columns
//! `replicas,bucketing,offered_rps,p50_ms,p99_ms,shed_rate,throughput_rps,sched,mode`
//! (mode = closed | open; closed-loop rows report their measured attempt
//! rate as the offered load — in a closed system they coincide), plus
//! the merged gateway stats via the `Recorder` emitters
//! (results/fig9_gateway_stats.{csv,json}).
//!
//! The expected shape: on a short-sequence workload, bucketed batching
//! pads each request to its content-canonical power-of-two width instead
//! of `max_len`, so per-request cost drops by the length ratio and both
//! p50 and the throughput ceiling improve. Two regression gates run in
//! the CI smoke mode (`YOSO_BENCH_SMOKE=1`, mirroring fig7's kernel
//! gate; full runs only warn):
//!
//! * **bucketing gate** — if bucketing *loses* to unbucketed on mean
//!   latency at the smallest bucket by more than 5%, exit non-zero;
//! * **scheduler gate** — on the skewed-bucket load (deep narrow bucket
//!   + sparse wide bucket, where FIFO parks replicas on foreign-bucket
//!   aging waits), work-conserving p99 must not lose to FIFO p99 by
//!   more than the repo's standard 5% noisy-runner margin (best-of-3
//!   per scheduler for symmetric noise damping);
//! * **degradation gate** — the overload A/B (same deadline-carrying
//!   burst run shed-only and then with a `DegradeLadder`) must show the
//!   ladder matching or beating shed-only on goodput (completions
//!   inside their deadline): trading hash rounds for latency may never
//!   serve *fewer* users than shedding them. Rows land in
//!   results/fig9_overload_ab.csv with the per-quality counters
//!   (`served_full`/`served_degraded`) from [`GatewayStats`];
//! * **supervision gate** — the same fault-free closed loop runs with
//!   replica supervision on (the default: per-request panic isolation +
//!   the restart trampoline) and off (the PR-8 baseline), best-of-3
//!   mean each; the supervised arm must stay within the same 5% margin
//!   — fault tolerance is not allowed to tax the fault-free fast path.
//!   Rows land in results/fig9_robustness_ab.csv;
//! * **flight-recorder gate** — the same closed loop runs with tracing
//!   off and on (`obs::set_trace_enabled`, best-of-3 mean each);
//!   traced mean latency must stay within the same 5% margin. The
//!   traced arm's event stream plus the fused kernel's phase sub-spans
//!   are always written as a Chrome `trace_event` timeline to
//!   results/trace_fig9.json (a CI artifact). Running the whole bench
//!   under `YOSO_TRACE=1` traces the main sweep too — `GatewayConfig`
//!   defaults its `trace` knob from the env gate;
//! * **steal gate** — the skewed FIFO closed loop again, cross-replica
//!   batch stealing off vs on (best-of-3 p99 each): an idle peer taking
//!   the tail of a parked partial batch must not *cost* p99 beyond the
//!   standard 5% margin. Rows (with the `steal` column and the stolen-
//!   batch count) land in results/fig9_steal_ab.csv.

use std::io::Write;
use std::time::{Duration, Instant};
use yoso::attention::{ChunkPolicy, KernelVariant};
use yoso::bench_support::{smoke, smoke_or};
use yoso::model::encoder::EncoderConfig;
use yoso::serve::{
    BatchPolicy, BatchPolicyTable, BucketLayout, CpuServeConfig,
    DegradeLadder, Gateway, GatewayConfig, GatewayStats, SchedPolicy,
    ShedPolicy,
};
use yoso::util::stats::quantile_exact;
use yoso::util::Rng;

type Req = (Vec<i32>, Vec<i32>);

/// Short-sequence workload: lengths in [lo, hi], token ids in-vocab.
fn make_requests(n: usize, lo: usize, hi: usize, seed: u64) -> Vec<Req> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let len = lo + rng.below(hi - lo + 1);
            let ids: Vec<i32> =
                (0..len).map(|_| 5 + rng.below(1990) as i32).collect();
            let segs = vec![0i32; len];
            (ids, segs)
        })
        .collect()
}

/// Skewed-bucket workload for the scheduler A/B: three quarters of the
/// traffic is short (deep narrow bucket), one quarter near `max_len`
/// (sparse wide bucket) — the shape where FIFO parks an idle replica
/// on a foreign bucket's aging wait while the narrow backlog grows.
fn make_skewed_requests(n: usize, max_len: usize, seed: u64) -> Vec<Req> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let len = if i % 4 == 3 {
                max_len * 3 / 4 + rng.below(max_len / 4)
            } else {
                4 + rng.below(5)
            };
            let ids: Vec<i32> =
                (0..len).map(|_| 5 + rng.below(1990) as i32).collect();
            let segs = vec![0i32; len];
            (ids, segs)
        })
        .collect()
}

fn spawn_gateway(
    replicas: usize,
    bucketing: bool,
    sched: SchedPolicy,
    max_wait_ms: u64,
    supervised: bool,
    encoder: &EncoderConfig,
) -> Gateway {
    let mut cfg = GatewayConfig::new(CpuServeConfig {
        attention: "yoso_16".into(),
        encoder: encoder.clone(),
        // replicas are the parallelism axis here; 1-wide pools keep the
        // replica sweep honest on small CI boxes
        threads: 1,
        chunk_policy: ChunkPolicy::default(),
        // env default so the serve-load sweep can A/B kernels too
        kernel: KernelVariant::from_env(),
        seed: 42,
    });
    cfg.replicas = replicas;
    cfg.queue_capacity = 64;
    cfg.shed = ShedPolicy::Reject;
    cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(max_wait_ms),
    });
    cfg.buckets = BucketLayout::pow2(8, encoder.max_len);
    cfg.sched = sched;
    cfg.bucketing = bucketing;
    cfg.supervised = supervised;
    Gateway::spawn(cfg)
}

struct RunResult {
    offered_rps: f64,
    p50: f64,
    p99: f64,
    mean: f64,
    shed_rate: f64,
    throughput_rps: f64,
    stats: GatewayStats,
}

fn summarize(
    mut latencies: Vec<f64>,
    offered_rps: f64,
    stats: GatewayStats,
) -> RunResult {
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99, mean) = if latencies.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (
            quantile_exact(&latencies, 0.50),
            quantile_exact(&latencies, 0.99),
            latencies.iter().sum::<f64>() / latencies.len() as f64,
        )
    };
    RunResult {
        offered_rps,
        p50,
        p99,
        mean,
        shed_rate: stats.shed_rate(),
        throughput_rps: stats.throughput_rps,
        stats,
    }
}

/// Paced arrivals at `rps`, independent of completions; queue-full
/// rejections count as sheds (the gateway reports them too).
fn open_loop(
    replicas: usize,
    bucketing: bool,
    sched: SchedPolicy,
    encoder: &EncoderConfig,
    reqs: &[Req],
    rps: f64,
) -> RunResult {
    let gw = spawn_gateway(replicas, bucketing, sched, 1, true, encoder);
    let gap = Duration::from_secs_f64(1.0 / rps);
    let start = Instant::now();
    let mut rxs = Vec::with_capacity(reqs.len());
    for (i, (ids, segs)) in reqs.iter().enumerate() {
        let target = start + gap * i as u32;
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        if let Ok(rx) = gw.submit(ids.clone(), segs.clone()) {
            rxs.push(rx);
        }
    }
    let latencies: Vec<f64> = rxs
        .into_iter()
        .filter_map(|rx| rx.recv().ok().and_then(|r| r.ok()))
        .map(|resp| resp.total_ms)
        .collect();
    summarize(latencies, rps, gw.shutdown())
}

/// `workers` concurrent submit-wait-repeat loops: the closed-loop
/// ceiling. Offered load == measured attempt rate by construction.
fn closed_loop(
    replicas: usize,
    bucketing: bool,
    sched: SchedPolicy,
    max_wait_ms: u64,
    encoder: &EncoderConfig,
    reqs: &[Req],
    workers: usize,
) -> RunResult {
    closed_loop_supervised(
        replicas, bucketing, sched, max_wait_ms, true, encoder, reqs, workers,
    )
}

/// [`closed_loop`] with the replica supervision knob exposed — the
/// fault-free robustness A/B compares `supervised` on (the default)
/// against the pre-supervision baseline on identical work.
#[allow(clippy::too_many_arguments)]
fn closed_loop_supervised(
    replicas: usize,
    bucketing: bool,
    sched: SchedPolicy,
    max_wait_ms: u64,
    supervised: bool,
    encoder: &EncoderConfig,
    reqs: &[Req],
    workers: usize,
) -> RunResult {
    let gw = spawn_gateway(
        replicas, bucketing, sched, max_wait_ms, supervised, encoder,
    );
    drive_closed_loop(gw, reqs, workers)
}

/// Submit-wait-repeat workers against an already-spawned gateway — the
/// shared closed-loop driver (the steal A/B spawns its own config).
fn drive_closed_loop(gw: Gateway, reqs: &[Req], workers: usize) -> RunResult {
    let start = Instant::now();
    let mut joins = Vec::new();
    for w in 0..workers {
        let sub = gw.submitter();
        let mine: Vec<Req> = reqs
            .iter()
            .enumerate()
            .filter(|(i, _)| i % workers == w)
            .map(|(_, r)| r.clone())
            .collect();
        joins.push(std::thread::spawn(move || {
            let mut lats = Vec::new();
            for (ids, segs) in mine {
                if let Ok(rx) = sub.submit(ids, segs) {
                    if let Ok(Ok(resp)) = rx.recv() {
                        lats.push(resp.total_ms);
                    }
                }
            }
            lats
        }));
    }
    let mut latencies = Vec::with_capacity(reqs.len());
    for j in joins {
        latencies.extend(j.join().expect("load worker"));
    }
    let attempted_rps = reqs.len() as f64 / start.elapsed().as_secs_f64();
    summarize(latencies, attempted_rps, gw.shutdown())
}

/// Overload A/B run: paced arrivals past one replica's ceiling, every
/// request carrying the same relative deadline. With
/// `DegradeLadder::none()` the only relief valve is the deadline
/// shedder; with a ladder, BestEffort traffic steps down to fewer hash
/// rounds first. Returns the run summary plus client-observed goodput
/// (completions whose `total_ms` landed inside the deadline).
fn overload_run(
    encoder: &EncoderConfig,
    reqs: &[Req],
    rps: f64,
    deadline: Duration,
    degrade: DegradeLadder,
) -> (RunResult, u64) {
    let mut cfg = GatewayConfig::new(CpuServeConfig {
        attention: "yoso_16".into(),
        encoder: encoder.clone(),
        threads: 1,
        chunk_policy: ChunkPolicy::default(),
        kernel: KernelVariant::from_env(),
        seed: 42,
    });
    // one replica, deep queue: overload shows up as queue delay (the
    // ladder's input), not as admission rejections
    cfg.replicas = 1;
    cfg.queue_capacity = 512;
    cfg.shed = ShedPolicy::Reject;
    cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
        max_batch: 8,
        max_wait: Duration::from_millis(1),
    });
    cfg.buckets = BucketLayout::pow2(8, encoder.max_len);
    cfg.sched = SchedPolicy::Conserve;
    cfg.bucketing = true;
    cfg.degrade = degrade;
    let gw = Gateway::spawn(cfg);
    let sub = gw.submitter();
    let gap = Duration::from_secs_f64(1.0 / rps);
    let start = Instant::now();
    let mut rxs = Vec::with_capacity(reqs.len());
    for (i, (ids, segs)) in reqs.iter().enumerate() {
        let target = start + gap * i as u32;
        if let Some(wait) = target.checked_duration_since(Instant::now()) {
            std::thread::sleep(wait);
        }
        if let Ok(rx) =
            sub.submit_with_deadline(ids.clone(), segs.clone(), Some(deadline))
        {
            rxs.push(rx);
        }
    }
    let deadline_ms = deadline.as_secs_f64() * 1e3;
    let mut goodput = 0u64;
    let latencies: Vec<f64> = rxs
        .into_iter()
        .filter_map(|rx| rx.recv().ok().and_then(|r| r.ok()))
        .map(|resp| {
            if resp.total_ms <= deadline_ms {
                goodput += 1;
            }
            resp.total_ms
        })
        .collect();
    (summarize(latencies, rps, gw.shutdown()), goodput)
}

fn main() {
    yoso::util::log::init_from_env();
    // short-sequence workload on a much longer model window — exactly
    // where O(bucket) beats O(max_len)
    let encoder = smoke_or(
        EncoderConfig {
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            vocab_size: 2005,
            max_len: 64,
            n_classes: 2,
        },
        EncoderConfig::base(2005, 128, 2),
    );
    let n_requests = smoke_or(64, 384);
    let reqs = make_requests(n_requests, 4, 20, 7);
    let nproc = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut replica_counts = vec![1usize];
    if nproc > 1 {
        replica_counts.push(nproc);
    }
    let rps_sweep = smoke_or(vec![100.0, 400.0], vec![50.0, 150.0, 400.0, 900.0]);
    let closed_workers = smoke_or(4, 8);

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/fig9_serve_load.csv").unwrap();
    // `sched` and `mode` ride as the last columns so the PR-3 required
    // column set stays a stable prefix: closed-loop rows report their
    // measured attempt rate as offered_rps, open-loop rows the
    // configured pace — different disciplines a consumer must not
    // conflate
    writeln!(
        csv,
        "replicas,bucketing,offered_rps,p50_ms,p99_ms,shed_rate,\
         throughput_rps,sched,mode"
    )
    .unwrap();

    println!("Figure 9 — gateway latency under offered load\n");
    println!(
        "{:>4} {:>9} {:>9} {:>7} {:>12} {:>10} {:>10} {:>10} {:>12}",
        "repl", "bucketing", "sched", "loop", "offered_rps", "p50_ms",
        "p99_ms", "shed", "tput_rps"
    );
    let emit = |csv: &mut std::fs::File,
                    replicas: usize,
                    onoff: &str,
                    sched: SchedPolicy,
                    mode: &str,
                    r: &RunResult| {
        writeln!(
            csv,
            "{replicas},{onoff},{:.1},{:.3},{:.3},{:.4},{:.1},{},{mode}",
            r.offered_rps,
            r.p50,
            r.p99,
            r.shed_rate,
            r.throughput_rps,
            sched.label(),
        )
        .unwrap();
        println!(
            "{replicas:>4} {onoff:>9} {:>9} {mode:>7} {:>12.1} {:>10.3} \
             {:>10.3} {:>9.1}% {:>12.1}",
            sched.label(),
            r.offered_rps,
            r.p50,
            r.p99,
            r.shed_rate * 100.0,
            r.throughput_rps
        );
    };
    let mut last_stats: Option<GatewayStats> = None;
    let sched = SchedPolicy::Conserve; // the sweep runs the default scheduler
    for &replicas in &replica_counts {
        for bucketing in [false, true] {
            let onoff = if bucketing { "on" } else { "off" };
            let closed = closed_loop(
                replicas,
                bucketing,
                sched,
                1,
                &encoder,
                &reqs,
                closed_workers,
            );
            let mut rows = vec![("closed", closed)];
            for &rps in &rps_sweep {
                rows.push((
                    "open",
                    open_loop(replicas, bucketing, sched, &encoder, &reqs, rps),
                ));
            }
            for (mode, r) in rows {
                emit(&mut csv, replicas, onoff, sched, mode, &r);
                last_stats = Some(r.stats);
            }
        }
    }
    if let Some(stats) = &last_stats {
        // the merged gateway observability surface, through the
        // Recorder emitters
        let mut rec = yoso::metrics::Recorder::new();
        stats.record_into(&mut rec);
        rec.write_csv(std::path::Path::new("results/fig9_gateway_stats.csv"))
            .unwrap();
        rec.write_json(std::path::Path::new("results/fig9_gateway_stats.json"))
            .unwrap();
        print!("\nfinal run gateway stats:\n{stats}");
    }

    // scheduler A/B gate: skewed-bucket closed loop, conserve vs fifo.
    // A generous max_wait (4 ms) is what FIFO pays for when it parks a
    // replica on the sparse wide bucket; best-of-3 per scheduler damps
    // runner noise symmetrically (the fig7 pattern).
    let skewed =
        make_skewed_requests(smoke_or(48, 192), encoder.max_len, 13);
    let ab_replicas = nproc.clamp(1, 2);
    let mut best: Vec<(SchedPolicy, RunResult)> = Vec::new();
    for sched in [SchedPolicy::Fifo, SchedPolicy::Conserve] {
        let mut runs: Vec<RunResult> = (0..3)
            .map(|_| {
                closed_loop(ab_replicas, true, sched, 4, &encoder, &skewed, 4)
            })
            .collect();
        runs.sort_by(|a, b| a.p99.partial_cmp(&b.p99).unwrap());
        let r = runs.remove(0);
        emit(&mut csv, ab_replicas, "on", sched, "closed", &r);
        best.push((sched, r));
    }
    println!("-> results/fig9_serve_load.csv");

    let fifo_p99 = best[0].1.p99;
    let conserve_p99 = best[1].1.p99;
    println!(
        "\nskewed-bucket sched gate: p99 ms conserve {conserve_p99:.3} vs \
         fifo {fifo_p99:.3} ({:.2}x)",
        fifo_p99 / conserve_p99.max(1e-9)
    );
    let mut failed = false;
    if conserve_p99 > fifo_p99 * 1.05 {
        println!(
            "WARNING: work-conserving scheduling lost to FIFO on p99 at the \
             skewed-bucket load (>5%)"
        );
        failed = smoke();
    }

    // steal A/B gate: the skewed FIFO closed loop — the shape where a
    // replica parks aging a partial wide batch while its peer idles.
    // With stealing on, the idle peer takes the parked tail instead of
    // sleeping through the aging wait; the gate only demands stealing
    // never *costs* p99 past the standard 5% margin (best-of-3 per arm
    // damps runner noise symmetrically).
    let steal_reqs =
        make_skewed_requests(smoke_or(48, 192), encoder.max_len, 19);
    let steal_arm = |steal: bool| -> RunResult {
        let mut runs: Vec<RunResult> = (0..3)
            .map(|_| {
                let mut cfg = GatewayConfig::new(CpuServeConfig {
                    attention: "yoso_16".into(),
                    encoder: encoder.clone(),
                    threads: 1,
                    chunk_policy: ChunkPolicy::default(),
                    kernel: KernelVariant::from_env(),
                    seed: 42,
                });
                cfg.replicas = 2;
                cfg.queue_capacity = 64;
                cfg.shed = ShedPolicy::Reject;
                cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
                    max_batch: 8,
                    max_wait: Duration::from_millis(4),
                });
                cfg.buckets = BucketLayout::pow2(8, encoder.max_len);
                cfg.sched = SchedPolicy::Fifo;
                cfg.bucketing = true;
                cfg.steal = steal;
                cfg.heartbeat = Duration::from_millis(2);
                drive_closed_loop(Gateway::spawn(cfg), &steal_reqs, 4)
            })
            .collect();
        runs.sort_by(|a, b| a.p99.partial_cmp(&b.p99).unwrap());
        runs.remove(0)
    };
    let no_steal = steal_arm(false);
    let with_steal = steal_arm(true);
    let mut st = std::fs::File::create("results/fig9_steal_ab.csv").unwrap();
    writeln!(
        st,
        "steal,replicas,p50_ms,p99_ms,mean_ms,shed_rate,throughput_rps,stolen"
    )
    .unwrap();
    for (name, r) in [("off", &no_steal), ("on", &with_steal)] {
        writeln!(
            st,
            "{name},2,{:.3},{:.3},{:.3},{:.4},{:.1},{}",
            r.p50, r.p99, r.mean, r.shed_rate, r.throughput_rps, r.stats.stolen
        )
        .unwrap();
    }
    println!(
        "\nsteal gate: p99 ms steal {:.3} vs no-steal {:.3} ({:.2}x, \
         {} stolen)",
        with_steal.p99,
        no_steal.p99,
        no_steal.p99 / with_steal.p99.max(1e-9),
        with_steal.stats.stolen
    );
    println!("-> results/fig9_steal_ab.csv");
    if with_steal.p99 > no_steal.p99 * 1.05 {
        println!(
            "WARNING: cross-replica stealing cost more than 5% p99 on the \
             skewed closed loop"
        );
        failed = failed || smoke();
    }

    // regression gate: at the smallest bucket, bucketed batching must
    // not lose to unbucketed on mean latency by more than 5%. Paired
    // single-replica single-worker closed loops minimize noise; the
    // smoke run (CI) fails hard, full runs warn.
    let short = make_requests(smoke_or(40, 160), 4, 8, 11);
    let unbucketed =
        closed_loop(1, false, SchedPolicy::Conserve, 1, &encoder, &short, 1);
    let bucketed =
        closed_loop(1, true, SchedPolicy::Conserve, 1, &encoder, &short, 1);
    println!(
        "\nsmallest-bucket gate: mean ms bucketed {:.3} vs unbucketed {:.3} \
         ({:.2}x)",
        bucketed.mean,
        unbucketed.mean,
        unbucketed.mean / bucketed.mean.max(1e-9)
    );
    if bucketed.mean > unbucketed.mean * 1.05 {
        println!(
            "WARNING: bucketed batching lost to unbucketed on mean latency \
             at the smallest bucket (>5%)"
        );
        failed = failed || smoke();
    }

    // overload A/B: degrade-vs-shed. The same deadline-carrying burst
    // runs twice — shed-only, then with an aggressive ladder sized to
    // this workload ("yoso_16": step to m'=8 at 5 ms of estimated
    // backlog, m'=4 at 15 ms). The ladder must convert deadline sheds
    // into degraded-but-on-time completions, never serve fewer.
    let overload_reqs = make_requests(smoke_or(96, 384), 4, 20, 17);
    let overload_rps = smoke_or(1500.0, 3000.0);
    let deadline = Duration::from_millis(smoke_or(30, 60));
    let (shed_r, shed_good) = overload_run(
        &encoder,
        &overload_reqs,
        overload_rps,
        deadline,
        DegradeLadder::none(),
    );
    let (lad_r, lad_good) = overload_run(
        &encoder,
        &overload_reqs,
        overload_rps,
        deadline,
        DegradeLadder::steps(vec![(5, 8), (15, 4)]),
    );
    let mut ab = std::fs::File::create("results/fig9_overload_ab.csv").unwrap();
    writeln!(
        ab,
        "ladder,offered_rps,deadline_ms,completed,goodput,shed_deadline,\
         shed_rate,p50_ms,p99_ms,served_full,served_degraded"
    )
    .unwrap();
    println!(
        "\noverload A/B @ {overload_rps:.0} rps, {:.0} ms deadline:",
        deadline.as_secs_f64() * 1e3
    );
    println!(
        "{:>7} {:>10} {:>8} {:>10} {:>8} {:>10} {:>10} {:>10}",
        "ladder", "completed", "goodput", "shed_ddl", "shed", "p99_ms",
        "full", "degraded"
    );
    for (name, r, good) in
        [("off", &shed_r, shed_good), ("on", &lad_r, lad_good)]
    {
        writeln!(
            ab,
            "{name},{:.1},{:.1},{},{good},{},{:.4},{:.3},{:.3},{},{}",
            r.offered_rps,
            deadline.as_secs_f64() * 1e3,
            r.stats.completed,
            r.stats.shed_deadline,
            r.shed_rate,
            r.p50,
            r.p99,
            r.stats.served_full,
            r.stats.served_degraded,
        )
        .unwrap();
        println!(
            "{name:>7} {:>10} {good:>8} {:>10} {:>7.1}% {:>10.3} {:>10} \
             {:>10}",
            r.stats.completed,
            r.stats.shed_deadline,
            r.shed_rate * 100.0,
            r.p99,
            r.stats.served_full,
            r.stats.served_degraded,
        );
    }
    println!("-> results/fig9_overload_ab.csv");
    if lad_good < shed_good {
        println!(
            "WARNING: the degradation ladder served fewer within-deadline \
             requests than shed-only under overload"
        );
        failed = failed || smoke();
    }

    // supervision overhead gate: identical fault-free closed loops,
    // supervised (catch_unwind per request + the restart trampoline +
    // recovering lock helpers) vs the pre-supervision baseline.
    // Best-of-3 mean per arm, standard 5% noisy-runner margin.
    let robust_reqs = make_requests(smoke_or(40, 160), 4, 20, 29);
    let robust_arm = |supervised: bool| -> f64 {
        let mut means: Vec<f64> = (0..3)
            .map(|_| {
                closed_loop_supervised(
                    1,
                    true,
                    SchedPolicy::Conserve,
                    1,
                    supervised,
                    &encoder,
                    &robust_reqs,
                    4,
                )
                .mean
            })
            .collect();
        means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        means[0]
    };
    let unsup_mean = robust_arm(false);
    let sup_mean = robust_arm(true);
    let mut rob =
        std::fs::File::create("results/fig9_robustness_ab.csv").unwrap();
    writeln!(rob, "supervised,mean_ms").unwrap();
    writeln!(rob, "off,{unsup_mean:.3}").unwrap();
    writeln!(rob, "on,{sup_mean:.3}").unwrap();
    println!(
        "\nsupervision gate: mean ms supervised {sup_mean:.3} vs \
         unsupervised {unsup_mean:.3} ({:.2}x)",
        sup_mean / unsup_mean.max(1e-9)
    );
    println!("-> results/fig9_robustness_ab.csv");
    if sup_mean > unsup_mean * 1.05 {
        println!(
            "WARNING: replica supervision cost more than 5% mean latency \
             on the fault-free closed loop"
        );
        failed = failed || smoke();
    }

    // flight-recorder overhead gate: the same single-replica closed
    // loop, tracing off vs on (the process gate also flips every
    // gateway spawned inside the arm — `GatewayConfig::new` defaults
    // its `trace` knob from it). Best-of-3 mean per arm damps runner
    // noise symmetrically, same margin as the other gates.
    let trace_reqs = make_requests(smoke_or(40, 160), 4, 20, 23);
    let trace_arm = |on: bool| -> f64 {
        yoso::obs::set_trace_enabled(on);
        let mut means: Vec<f64> = (0..3)
            .map(|_| {
                closed_loop(
                    1,
                    true,
                    SchedPolicy::Conserve,
                    1,
                    &encoder,
                    &trace_reqs,
                    4,
                )
                .mean
            })
            .collect();
        means.sort_by(|a, b| a.partial_cmp(b).unwrap());
        means[0]
    };
    let untraced_mean = trace_arm(false);
    // reset so the artifact below holds only the traced arm's spans
    yoso::obs::reset_kernel_profile();
    let traced_mean = trace_arm(true);
    println!(
        "\nflight-recorder gate: mean ms traced {traced_mean:.3} vs \
         untraced {untraced_mean:.3} ({:.2}x)",
        traced_mean / untraced_mean.max(1e-9)
    );
    if traced_mean > untraced_mean * 1.05 {
        println!(
            "WARNING: flight-recorder tracing cost more than 5% mean \
             latency on the closed loop"
        );
        failed = failed || smoke();
    }

    // one more traced run feeds the Chrome timeline artifact — this one
    // keeps its gateway in scope so the sink survives shutdown
    let gw = spawn_gateway(1, true, SchedPolicy::Conserve, 1, true, &encoder);
    let sub = gw.submitter();
    let mut rxs = Vec::with_capacity(trace_reqs.len());
    for (ids, segs) in &trace_reqs {
        if let Ok(rx) = sub.submit(ids.clone(), segs.clone()) {
            rxs.push(rx);
        }
    }
    for rx in rxs {
        let _ = rx.recv();
    }
    let sink = gw.trace_sink();
    gw.shutdown();
    yoso::obs::set_trace_enabled(false);
    let log = sink.expect("tracing was enabled").drain();
    let kernel = yoso::obs::kernel_snapshot();
    yoso::obs::write_chrome_trace(
        std::path::Path::new("results/trace_fig9.json"),
        &log,
        &kernel,
    )
    .unwrap();
    println!(
        "-> results/trace_fig9.json ({} events, {} kernel spans)",
        log.events.len(),
        kernel.spans.len()
    );

    if failed {
        // the bench-smoke CI job is the regression gate
        std::process::exit(1);
    }
}
