//! Table 1: forward time/memory complexity — softmax O(n^2 d) / O(n^2)
//! vs YOSO O(nmd) / O(m 2^tau) — measured empirically and fitted.
//!
//! For each n we time the pure-Rust forward kernels and record workspace
//! bytes (analytic model + counting allocator), then fit the scaling
//! exponent alpha in t ~ n^alpha. Softmax should fit ~2, YOSO ~1. The
//! engine column runs on the work-stealing pool under both chunk
//! policies; rows land in results/table1_complexity.csv with a
//! `chunk_policy` column. `YOSO_BENCH_SMOKE=1` shrinks the sweep and
//! skips the exponent assertions (the quadratic term does not dominate
//! at smoke sizes).

use std::io::Write;
use yoso::attention::{Attention, ChunkPolicy, Engine, SoftmaxAttention, YosoAttention};
use yoso::bench_support::{bench, bench_threads, human_bytes, smoke, smoke_or,
                          CountingAlloc};
use yoso::tensor::Mat;
use yoso::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn fit_exponent(ns: &[usize], ts: &[f64]) -> f64 {
    // least-squares slope of log t vs log n
    let lx: Vec<f64> = ns.iter().map(|&n| (n as f64).ln()).collect();
    let ly: Vec<f64> = ts.iter().map(|&t| t.ln()).collect();
    let k = ns.len() as f64;
    let mx = lx.iter().sum::<f64>() / k;
    let my = ly.iter().sum::<f64>() / k;
    let num: f64 = lx.iter().zip(&ly).map(|(x, y)| (x - mx) * (y - my)).sum();
    let den: f64 = lx.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den
}

fn main() {
    let d = 64;
    let ns = smoke_or(vec![128usize, 256, 512], vec![512usize, 1024, 2048, 4096]);
    let mut rng = Rng::new(0);
    let threads = bench_threads();
    let iters = smoke_or(3, 5);
    let fixed = ChunkPolicy::default();
    let adaptive = ChunkPolicy::adaptive(threads);
    let engines = [
        Engine::with_policy(threads, fixed),
        Engine::with_policy(threads, adaptive),
    ];

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/table1_complexity.csv").unwrap();
    writeln!(csv, "method,n,threads,chunk_policy,time_ms,model_bytes").unwrap();

    println!("Table 1 — empirical forward cost (d = {d}, tau = 8, m = 32)\n");
    println!(
        "{:>6} {:>16} {:>14} {:>16} {:>16} {:>16} {:>14}",
        "n",
        "softmax ms",
        "sm mem",
        "yoso-32 ms",
        format!("yoso@{threads}t {} ms", fixed.label()),
        format!("yoso@{threads}t {} ms", adaptive.label()),
        "yoso mem"
    );

    let mut sm_times = Vec::new();
    let mut yo_times = Vec::new();
    for &n in &ns {
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);

        let softmax = SoftmaxAttention;
        let yoso = YosoAttention::new(8, 32, false);
        let mut r1 = Rng::new(1);
        let sm = bench(&format!("softmax n={n}"), 1, iters, || {
            std::hint::black_box(softmax.forward(&q, &k, &v, &mut r1));
        });
        let mut r2 = Rng::new(2);
        let yo = bench(&format!("yoso n={n}"), 1, iters, || {
            std::hint::black_box(yoso.forward(&q, &k, &v, &mut r2));
        });
        writeln!(csv, "softmax,{n},1,-,{},{}", sm.summary.mean * 1e3,
                 softmax.workspace_bytes(n, d))
            .unwrap();
        writeln!(csv, "yoso_32,{n},1,-,{},{}", yo.summary.mean * 1e3,
                 yoso.workspace_bytes(n, d))
            .unwrap();
        let mut engine_ms = Vec::new();
        for engine in &engines {
            let r3 = Rng::new(2);
            let yo_par = bench(&format!("yoso engine n={n}"), 1, iters, || {
                std::hint::black_box(engine.forward_yoso(&yoso, &q, &k, &v, &r3));
            });
            let ms = yo_par.summary.mean * 1e3;
            writeln!(
                csv,
                "yoso_32_engine,{n},{threads},{},{ms},{}",
                engine.chunk_policy().label(),
                engine.workspace_bytes(&yoso, n, d)
            )
            .unwrap();
            engine_ms.push(ms);
        }
        println!(
            "{:>6} {:>16.3} {:>14} {:>16.3} {:>16.3} {:>16.3} {:>14}",
            n,
            sm.summary.mean * 1e3,
            human_bytes(softmax.workspace_bytes(n, d)),
            yo.summary.mean * 1e3,
            engine_ms[0],
            engine_ms[1],
            human_bytes(yoso.workspace_bytes(n, d)),
        );
        sm_times.push(sm.summary.mean);
        yo_times.push(yo.summary.mean);
    }

    let sm_alpha = fit_exponent(&ns, &sm_times);
    let yo_alpha = fit_exponent(&ns, &yo_times);
    println!("\nfitted scaling exponents (t ~ n^alpha):");
    println!("  softmax: alpha = {sm_alpha:.2}   (paper: 2 — O(n^2 d))");
    println!("  yoso   : alpha = {yo_alpha:.2}   (paper: 1 — O(n m d))");
    println!("\nmemory model: softmax O(n^2) grows {}x from n=512 to 4096; \
              yoso table O(m 2^tau + codes) is n-independent (table) + O(n) codes",
             (4096 * 4096) / (512 * 512));
    println!("\n-> results/table1_complexity.csv");
    if smoke() {
        println!("YOSO_BENCH_SMOKE: skipping scaling-exponent assertions");
        return;
    }
    assert!(sm_alpha > 1.6, "softmax should scale ~quadratically: {sm_alpha}");
    assert!(yo_alpha < 1.45, "yoso should scale ~linearly: {yo_alpha}");
}
