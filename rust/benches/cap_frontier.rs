//! Capacity-planning frontier off the scheduler-exact simulator.
//!
//! The sharded scheduling core is payload-generic, so `serve::sim` runs
//! the *same* decision procedures as the live gateway (the bit-identity
//! gate in `tests/sim_gateway.rs` and `serve::gateway`'s
//! `live_schedule_matches_the_sim_bit_for_bit` pin this) — which turns
//! the simulator into a capacity-planning instrument: a million-request
//! day costs zero wall-clock service time, so the whole replica-count
//! sweep runs in CI.
//!
//! Two synthetic traces exercise the two planning regimes:
//!
//! * **diurnal** — arrival rate swings sinusoidally 19:1 over a "day";
//!   sizing for the peak vs the mean is the frontier's whole story;
//! * **flash-crowd** — a steady baseline with a contiguous 8x burst
//!   mid-trace; the regime where cross-replica stealing earns its keep
//!   by draining the wedge instead of letting one lane absorb it.
//!
//! Each trace runs at every replica count, stealing off and on, and the
//! resulting [`FrontierPoint`]s land in results/cap_frontier.csv with
//! columns `trace,steal,replicas,offered,accepted,rejected,completed,
//! goodput,shed_deadline,mean_ms,p99_ms,peak_depth,stolen` — the
//! replica-count vs p99/goodput curves EXPERIMENTS.md reads deployment
//! sizes off.
//!
//! Gates (hard in `YOSO_BENCH_SMOKE=1`, warn on full runs, matching the
//! fig9 pattern): the no-request-lost accounting identity `accepted ==
//! completed + shed_deadline` must hold at every point (the sim injects
//! no faults here), and goodput at the largest deployment must not fall
//! below goodput at one replica — a frontier that bends down with
//! added capacity means the scheduler, not the capacity, is the
//! bottleneck.

use std::io::Write;
use std::time::Duration;
use yoso::bench_support::{smoke, smoke_or};
use yoso::serve::sim::{
    diurnal_trace, flash_crowd_trace, frontier, Arrival, FrontierPoint,
    ServiceModel, SimConfig,
};
use yoso::serve::{
    BatchPolicy, BatchPolicyTable, BucketLayout, DegradeLadder, SchedPolicy,
};

fn base_cfg(steal: bool) -> SimConfig {
    SimConfig {
        replicas: 1,
        queue_capacity: 4096,
        sched: SchedPolicy::Conserve,
        buckets: BucketLayout::pow2(8, 64),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(1),
        }),
        // calibrated so one replica's ceiling sits near 6k rps against
        // the ~12k rps diurnal mean: the sweep crosses the knee instead
        // of starting past it
        service: ServiceModel {
            batch_overhead: Duration::from_micros(400),
            per_width: Duration::from_micros(4),
        },
        degrade: DegradeLadder::none(),
        m_full: 16,
        admission_edf: false,
        steal,
        ..SimConfig::default()
    }
}

fn main() {
    yoso::util::log::init_from_env();
    let n = smoke_or(1_000_000, 2_000_000);
    let replica_counts = smoke_or(vec![1, 2, 4, 8], vec![1, 2, 3, 4, 6, 8]);
    let deadline = Some(Duration::from_millis(25));
    let diurnal = diurnal_trace(
        n,
        Duration::from_micros(80),
        Duration::from_secs(20),
        deadline,
    );
    let crowd = flash_crowd_trace(
        n,
        Duration::from_micros(120),
        0.15,
        8.0,
        deadline,
    );
    let traces: [(&str, &[Arrival]); 2] =
        [("diurnal", &diurnal), ("flash_crowd", &crowd)];

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/cap_frontier.csv").unwrap();
    writeln!(
        csv,
        "trace,steal,replicas,offered,accepted,rejected,completed,goodput,\
         shed_deadline,mean_ms,p99_ms,peak_depth,stolen"
    )
    .unwrap();

    println!("Capacity frontier — {n} simulated requests per trace\n");
    println!(
        "{:>12} {:>6} {:>5} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "trace", "steal", "repl", "accepted", "rejected", "goodput",
        "shed_ddl", "p99_ms", "peak_q", "stolen"
    );
    let mut failed = false;
    for (name, trace) in traces {
        for steal in [false, true] {
            let cfg = base_cfg(steal);
            let points: Vec<FrontierPoint> =
                frontier(&cfg, trace, &replica_counts);
            for p in &points {
                // no faults injected: every admitted request completes
                // or sheds on deadline, at every deployment size
                assert_eq!(
                    p.accepted,
                    p.completed + p.shed_deadline,
                    "{name} steal={steal} replicas={}: \
                     accounting identity broke",
                    p.replicas
                );
                writeln!(
                    csv,
                    "{name},{},{},{},{},{},{},{},{},{:.3},{:.3},{},{}",
                    if steal { "on" } else { "off" },
                    p.replicas,
                    p.offered,
                    p.accepted,
                    p.rejected,
                    p.completed,
                    p.goodput,
                    p.shed_deadline,
                    p.mean_ms,
                    p.p99_ms,
                    p.peak_depth,
                    p.stolen,
                )
                .unwrap();
                println!(
                    "{name:>12} {:>6} {:>5} {:>9} {:>9} {:>9} {:>9} \
                     {:>9.3} {:>9} {:>10}",
                    if steal { "on" } else { "off" },
                    p.replicas,
                    p.accepted,
                    p.rejected,
                    p.goodput,
                    p.shed_deadline,
                    p.p99_ms,
                    p.peak_depth,
                    p.stolen,
                );
            }
            let first = points.first().expect("non-empty sweep");
            let last = points.last().expect("non-empty sweep");
            if last.goodput < first.goodput {
                println!(
                    "WARNING: {name} steal={steal}: goodput fell from \
                     {} at {} replicas to {} at {} — capacity is not \
                     the bottleneck",
                    first.goodput,
                    first.replicas,
                    last.goodput,
                    last.replicas
                );
                failed = failed || smoke();
            }
        }
    }
    println!("-> results/cap_frontier.csv");
    if failed {
        // the bench-smoke CI job is the regression gate
        std::process::exit(1);
    }
}
