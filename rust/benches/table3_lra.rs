//! Table 3: LRA-style long-sequence accuracy across attention variants.
//!
//! Five synthetic LRA tasks (real ListOps grammar, byte-level text,
//! retrieval pairs, pixel images, pathfinder grids — data/lra.rs) at
//! n = 256, trained per (task, variant) through the fused HLO train
//! steps. Shape to reproduce: attention helps over "none"; YOSO is
//! comparable to softmax/Nyströmformer/Longformer and ahead of
//! Performer/Reformer at this scale.
//!
//! Env: YOSO_T3_STEPS (default 40), YOSO_T3_FULL=1 for all 13 variants.

use std::io::Write;
use std::path::Path;
use yoso::data::lra::{LraGenerator, LraTask};
use yoso::metrics::Recorder;
use yoso::runtime::Runtime;
use yoso::train::{ClsSource, Trainer};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    yoso::util::log::init_from_env();
    if yoso::bench_support::smoke_skip_without_artifacts("artifacts") {
        return Ok(());
    }
    let steps = env_usize("YOSO_T3_STEPS", yoso::bench_support::smoke_or(4, 40));
    let full = std::env::var("YOSO_T3_FULL").is_ok();
    let variants: Vec<&str> = if full {
        vec!["none", "softmax", "yoso_e", "yoso_32", "star_yoso_16",
             "yoso_c_16", "star_yoso_c_16", "nystrom", "longformer",
             "linformer", "reformer", "performer", "linear"]
    } else {
        vec!["none", "softmax", "yoso_e", "yoso_32", "nystrom", "performer"]
    };
    let tasks = LraTask::all();

    let rt = Runtime::open(Path::new("artifacts"))?;
    std::fs::create_dir_all("results")?;
    let mut csv = std::fs::File::create("results/table3_lra.csv")?;
    writeln!(csv, "variant,task,accuracy")?;

    println!("Table 3 — LRA-style accuracy ({steps} steps per cell, n = 256)\n");
    print!("{:<16}", "variant");
    for t in &tasks {
        print!("{:>11}", t.name());
    }
    println!("{:>9}", "avg");

    for variant in &variants {
        print!("{variant:<16}");
        let mut sum = 0.0;
        for task in &tasks {
            let mut trainer = Trainer::new(
                &rt,
                &format!("train_lra_{variant}"),
                Some(&format!("eval_lra_{variant}")),
                42,
                None,
            )?;
            let src = ClsSource::Lra(LraGenerator::new(*task, 256, 42));
            let mut rec = Recorder::new();
            trainer.run(&src, steps, 2e-3, 0, 0, 0, &mut rec)?;
            let eval = trainer.evaluate(&src, 4)?;
            writeln!(csv, "{variant},{},{}", task.name(), eval.accuracy)?;
            print!("{:>11.3}", eval.accuracy);
            sum += eval.accuracy;
        }
        println!("{:>9.3}", sum / tasks.len() as f64);
    }
    println!("\n-> results/table3_lra.csv");
    Ok(())
}
