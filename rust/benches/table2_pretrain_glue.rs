//! Table 2 + Figure 4: MLM/SOP pretraining across attention variants,
//! then GLUE-style fine-tuning from each pretrained checkpoint.
//!
//! Scaled to this testbed (synthetic corpus, small encoder — DESIGN.md):
//! absolute numbers differ from the paper, but the comparisons Table 2
//! makes — YOSO-E ~ softmax, YOSO-m approaching YOSO-E as m grows —
//! are reproduced. Loss curves (Figure 4) land in results/fig4_*.csv.
//!
//! Env: YOSO_T2_STEPS (default 60), YOSO_T2_FULL=1 (all 9 variants +
//! all 5 GLUE tasks), YOSO_T2_GLUE_STEPS (default 40).

use std::path::Path;
use yoso::data::corpus::{CorpusConfig, CorpusGenerator};
use yoso::data::glue_synth::{GlueGenerator, GlueTask};
use yoso::data::mlm::{MlmConfig, PretrainStream};
use yoso::data::tokenizer::WordTokenizer;
use yoso::metrics::Recorder;
use yoso::runtime::Runtime;
use yoso::train::{ClsSource, PretrainSource, Trainer};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn source(seed: u64) -> PretrainSource {
    PretrainSource {
        stream: PretrainStream::new(
            CorpusGenerator::new(CorpusConfig::default()),
            WordTokenizer { n_words: 2000 },
            MlmConfig::default(),
            seed,
        ),
    }
}

fn main() -> anyhow::Result<()> {
    yoso::util::log::init_from_env();
    if yoso::bench_support::smoke_skip_without_artifacts("artifacts") {
        return Ok(());
    }
    let steps = env_usize("YOSO_T2_STEPS", yoso::bench_support::smoke_or(6, 60));
    let glue_steps = env_usize("YOSO_T2_GLUE_STEPS", yoso::bench_support::smoke_or(4, 40));
    let full = std::env::var("YOSO_T2_FULL").is_ok();

    let variants: Vec<&str> = if full {
        vec!["softmax", "yoso_e", "star_yoso_e", "yoso_64", "yoso_32",
             "yoso_16", "star_yoso_32", "star_yoso_16", "yoso_c_16"]
    } else {
        vec!["softmax", "yoso_e", "yoso_32", "yoso_16", "star_yoso_16"]
    };
    let glue_tasks: Vec<GlueTask> = if full {
        GlueTask::all().to_vec()
    } else {
        vec![GlueTask::Mrpc, GlueTask::Sst2]
    };
    let glue_variants: Vec<&str> = if full {
        vec!["softmax", "yoso_e", "yoso_64", "yoso_32", "yoso_16",
             "star_yoso_32", "star_yoso_16"]
    } else {
        vec!["softmax", "yoso_e", "yoso_32"]
    };

    let rt = Runtime::open(Path::new("artifacts"))?;
    let src = source(42);
    std::fs::create_dir_all("results")?;

    println!("Table 2 — pretraining ({steps} steps, batch 16, seq 128)\n");
    println!("{:<14} {:>10} {:>9} {:>9}", "variant", "MLM ppl", "MLM acc",
             "SOP acc");
    let mut snapshots = Vec::new();
    for variant in &variants {
        // *YOSO variants differ from YOSO only in the backward pass, so
        // they share the plain eval artifact (same forward, same ABI).
        let eval_variant = variant.strip_prefix("star_").unwrap_or(variant);
        let mut trainer = Trainer::new(
            &rt,
            &format!("train_pretrain_{variant}"),
            Some(&format!("eval_pretrain_{eval_variant}")),
            42,
            None,
        )?;
        let mut rec = Recorder::new();
        trainer.run(&src, steps, 1e-3, 0, 0, 0, &mut rec)?;
        let eval = trainer.evaluate(&src, 4)?;
        println!(
            "{:<14} {:>10.2} {:>9.3} {:>9.3}",
            variant, eval.mlm_perplexity, eval.accuracy, eval.sop_accuracy
        );
        rec.write_csv(Path::new(&format!("results/fig4_{variant}.csv")))?;
        snapshots.push((variant.to_string(), trainer.snapshot()?));
    }

    println!("\nGLUE-style fine-tuning ({glue_steps} steps each, dev accuracy)\n");
    print!("{:<14}", "variant");
    for t in &glue_tasks {
        print!("{:>9}", t.name());
    }
    println!();
    for variant in &glue_variants {
        let init = snapshots
            .iter()
            .find(|(v, _)| v == variant)
            .map(|(_, s)| s.clone());
        print!("{variant:<14}");
        for task in &glue_tasks {
            let mut trainer = Trainer::new(
                &rt,
                &format!("train_glue_{variant}"),
                Some(&format!("eval_glue_{variant}")),
                42,
                init.clone(),
            )?;
            let gsrc = ClsSource::Glue(GlueGenerator::new(*task, 128, 42));
            let mut rec = Recorder::new();
            trainer.run(&gsrc, glue_steps, 2e-3, 0, 0, 0, &mut rec)?;
            let eval = trainer.evaluate(&gsrc, 4)?;
            print!("{:>9.3}", eval.accuracy);
        }
        println!();
    }
    println!("\ncurves -> results/fig4_<variant>.csv (series train_loss / \
              train_mlm_ppl)");
    Ok(())
}
