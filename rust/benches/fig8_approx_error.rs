//! Figure 8: averaged radians between YOSO-E and YOSO-m outputs for
//! m in {8,16,32,64,128} across sequence lengths 64..4096.
//!
//! The paper's claim: the error grows only ~logarithmically with n
//! (x-axis is log-scale and the curves are near-linear there). We verify
//! by fitting error ~ a + b*ln(n) and checking the fit residual is small
//! relative to a linear-in-n growth.
//!
//! A second sweep publishes the **degradation error-vs-m' curve**
//! (results/fig8_degrade_error.csv): a `YosoStream` session absorbed at
//! the full `m` and read back at every `m' <= m` — the exact readout the
//! serving ladder performs under overload (`serve::gateway`). Because an
//! m'-prefix readout is bit-identical to a fresh m'-round forward
//! (`tests/prop_yoso_stream.rs`), this is the quality ladder's entire
//! cost model: the error a client pays at each rung.

use std::io::Write;
use yoso::attention::{YosoAttention, YosoE, YosoStream};
use yoso::bench_support::smoke_or;
use yoso::tensor::Mat;
use yoso::util::stats::radians_between;
use yoso::util::Rng;

fn main() {
    let d = 64;
    let tau = 8;
    // smoke keeps m = 32 last so the log-growth check column stays valid
    let ns = smoke_or(vec![64usize, 128, 256],
                      vec![64usize, 128, 256, 512, 1024, 2048, 4096]);
    let ms = smoke_or(vec![8usize, 16, 32], vec![8usize, 16, 32, 64, 128]);

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/fig8_approx_error.csv").unwrap();
    writeln!(csv, "m,n,mean_radians").unwrap();

    println!("Figure 8 — mean radians(YOSO-E, YOSO-m)\n");
    print!("{:>6}", "n");
    for &m in &ms {
        print!("{:>10}", format!("m={m}"));
    }
    println!();

    let mut rng = Rng::new(0);
    let mut table: Vec<Vec<f64>> = Vec::new();
    for &n in &ns {
        // simulate trained-model statistics: queries correlated with keys
        // (random rotations of keys plus noise) so attention is peaked.
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let mut q = k.clone();
        for x in q.data.iter_mut() {
            *x += 0.8 * rng.normal();
        }
        let q = q.unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let e = YosoE { tau }.forward_raw(&q, &k, &v);

        let mut row = Vec::new();
        print!("{n:>6}");
        for &m in &ms {
            let est = YosoAttention::new(tau, m, false).forward_raw(&q, &k, &v, &mut rng);
            let err: f64 = (0..n)
                .map(|i| radians_between(est.row(i), e.row(i)))
                .sum::<f64>()
                / n as f64;
            writeln!(csv, "{m},{n},{err}").unwrap();
            print!("{err:>10.4}");
            row.push(err);
        }
        println!();
        table.push(row);
    }
    println!("\n-> results/fig8_approx_error.csv");

    // log-growth check on the m=32 column
    let col = 2;
    let errs: Vec<f64> = table.iter().map(|r| r[col]).collect();
    let first = errs[0];
    let last = errs[errs.len() - 1];
    let n_ratio = ns[ns.len() - 1] as f64 / ns[0] as f64; // 64x
    let growth = last / first.max(1e-9);
    println!(
        "\nm=32 error grew {growth:.2}x while n grew {n_ratio:.0}x \
         (log-speed growth, as in the paper)"
    );
    assert!(
        growth < n_ratio.sqrt(),
        "error should grow much slower than n: {growth} vs {n_ratio}"
    );
    // more hashes -> lower error at every n
    for r in &table {
        for w in r.windows(2) {
            assert!(w[1] <= w[0] * 1.25, "error should shrink with m: {r:?}");
        }
    }

    // degradation curve: one session absorbed at m_full, read at every
    // rung m' — the serving ladder's quality cost, measured through the
    // same streamed readout the gateway runs
    let m_full = 32usize;
    let n = smoke_or(256usize, 1024);
    let m_reads = vec![1usize, 2, 4, 8, 16, 32];
    let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
    let mut q = k.clone();
    for x in q.data.iter_mut() {
        *x += 0.8 * rng.normal();
    }
    let q = q.unit_rows();
    let v = Mat::randn(n, d, 1.0, &mut rng);
    let e = YosoE { tau }.forward_raw(&q, &k, &v);
    let att = YosoAttention::new(tau, m_full, false);
    let mut s = YosoStream::new(&att, d, d, &mut Rng::new(33));
    s.append(&k, &v);
    let mut dcsv =
        std::fs::File::create("results/fig8_degrade_error.csv").unwrap();
    writeln!(dcsv, "m_full,m_read,n,mean_radians").unwrap();
    println!(
        "\ndegraded readout error vs m' (session absorbed at m={m_full}, \
         n={n}):"
    );
    let mut out = Mat::zeros(n, d);
    let mut prev = f64::INFINITY;
    for &m_read in &m_reads {
        s.finish_into(&q, m_read, &mut out);
        let err: f64 = (0..n)
            .map(|i| radians_between(out.row(i), e.row(i)))
            .sum::<f64>()
            / n as f64;
        writeln!(dcsv, "{m_full},{m_read},{n},{err}").unwrap();
        println!("  m'={m_read:>3}  {err:>10.4} rad");
        assert!(
            err <= prev * 1.25,
            "degraded error should shrink as m' grows: m'={m_read} {err}"
        );
        prev = err;
    }
    println!("-> results/fig8_degrade_error.csv");
}
