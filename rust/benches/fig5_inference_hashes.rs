//! Figure 5: the effect of the number of hashes *at inference time*.
//!
//! Train once with YOSO-32, then evaluate the same parameters with
//! m in {8, 16, 32, 64, 128} and with YOSO-E (expectation — "infinite
//! hashes"). The paper's shape: MLM perplexity / SOP loss decrease
//! monotonically toward the YOSO-E value as m grows.
//!
//! Env: YOSO_F5_STEPS (default 80).

use std::io::Write;
use std::path::Path;
use yoso::data::corpus::{CorpusConfig, CorpusGenerator};
use yoso::data::mlm::{MlmConfig, PretrainStream};
use yoso::data::tokenizer::WordTokenizer;
use yoso::metrics::Recorder;
use yoso::runtime::Runtime;
use yoso::train::trainer::eval_artifact;
use yoso::train::{PretrainSource, Trainer};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> anyhow::Result<()> {
    yoso::util::log::init_from_env();
    if yoso::bench_support::smoke_skip_without_artifacts("artifacts") {
        return Ok(());
    }
    let steps = env_usize("YOSO_F5_STEPS", yoso::bench_support::smoke_or(8, 80));
    let rt = Runtime::open(Path::new("artifacts"))?;
    let src = PretrainSource {
        stream: PretrainStream::new(
            CorpusGenerator::new(CorpusConfig::default()),
            WordTokenizer { n_words: 2000 },
            MlmConfig::default(),
            42,
        ),
    };

    println!("Figure 5 — training yoso_32 for {steps} steps, then sweeping \
              inference-time hashes\n");
    let mut trainer = Trainer::new(&rt, "train_pretrain_yoso_32", None, 42, None)?;
    let mut rec = Recorder::new();
    trainer.run(&src, steps, 1e-3, 0, 0, steps / 4, &mut rec)?;

    std::fs::create_dir_all("results")?;
    let mut csv = std::fs::File::create("results/fig5_inference_hashes.csv")?;
    writeln!(csv, "eval_setting,mlm_ppl,mlm_acc,sop_acc")?;

    println!("{:<12} {:>10} {:>9} {:>9}", "inference", "MLM ppl", "MLM acc",
             "SOP acc");
    let mut ppls = Vec::new();
    for setting in ["yoso_8", "yoso_16", "yoso_32", "yoso_64", "yoso_128",
                    "yoso_e"] {
        let art = rt.artifact(&format!("eval_pretrain_{setting}"))?;
        let eval = eval_artifact(&art, &trainer.params, &src, 6)?;
        println!(
            "{:<12} {:>10.2} {:>9.3} {:>9.3}",
            setting, eval.mlm_perplexity, eval.accuracy, eval.sop_accuracy
        );
        writeln!(csv, "{setting},{},{},{}", eval.mlm_perplexity, eval.accuracy,
                 eval.sop_accuracy)?;
        ppls.push(eval.mlm_perplexity);
    }
    println!("\n-> results/fig5_inference_hashes.csv");

    // shape check: ppl at m=128 should beat ppl at m=8
    assert!(
        ppls[4] <= ppls[0] * 1.05,
        "more hashes at inference should not hurt: {ppls:?}"
    );
    Ok(())
}
