//! Figure 7: running time and peak memory vs sequence length for YOSO
//! and every baseline, per instance (one head, d = 64), with each
//! method's paper hyperparameters (§4.2/§4.3).
//!
//! Writes results/fig7_efficiency.csv
//! (method,n,threads,chunk_policy,sched,kernel,time_ms,peak_bytes,model_bytes)
//! and prints the panels. Zoo baselines run serially (threads = 1,
//! sched = serial); the YOSO parallel engine rows sweep thread counts
//! (powers of two up to the core count, capped by `YOSO_BENCH_THREADS`)
//! crossed with the scheduler (work-stealing `steal` vs the legacy
//! channel pool `chan`) and the chunk policy (`fixed4` vs `adaptiveW`),
//! so both the scheduler delta and the chunking delta land in the CSV
//! rather than being asserted. The `kernel` column carries the
//! seed-vs-fused kernel A/B (`attention::kernel`): dedicated
//! `yoso_32_kernel` serial rows time both variants on identical inputs,
//! and in `YOSO_BENCH_SMOKE=1` mode the run **fails** if the fused
//! kernel loses to the seed kernel by more than the standard 5% noise
//! margin at any smoke size, or if it is below 1.2x seed throughput at
//! the largest smoke n — bench-smoke is the kernel-regression gate. The paper's
//! shape to reproduce: softmax grows quadratically and runs out of
//! budget first; the efficient methods stay near-linear; YOSO has the
//! lowest memory profile.

use std::io::Write;
use yoso::attention::{
    by_name, Attention, ChunkPolicy, Engine, KernelVariant, YosoAttention,
};
use yoso::bench_support::{
    bench, bench_threads, human_bytes, peak_bytes, reset_peak, smoke, smoke_or,
    CountingAlloc,
};
use yoso::tensor::Mat;
use yoso::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// 1, 2, 4, ... up to the `bench_threads()` budget.
fn thread_counts() -> Vec<usize> {
    let max_threads = bench_threads();
    let mut counts = vec![1usize];
    let mut t = 2;
    while t < max_threads {
        counts.push(t);
        t *= 2;
    }
    if max_threads > 1 {
        counts.push(max_threads);
    }
    counts
}

/// One engine measurement: mean ms + peak bytes over `iters` runs.
fn time_engine(
    engine: &Engine,
    att: &YosoAttention,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    iters: usize,
) -> (f64, usize) {
    let run_rng = Rng::new(9);
    reset_peak();
    let r = bench("engine", 1, iters, || {
        std::hint::black_box(engine.forward_yoso(att, q, k, v, &run_rng));
    });
    (r.summary.mean * 1e3, peak_bytes())
}

/// One serial trait-forward measurement: mean ms + peak bytes.
fn time_attention(
    attn: &dyn Attention,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    iters: usize,
) -> (f64, usize) {
    let mut run_rng = Rng::new(9);
    reset_peak();
    let r = bench("kernel", 1, iters, || {
        std::hint::black_box(attn.forward(q, k, v, &mut run_rng));
    });
    (r.summary.mean * 1e3, peak_bytes())
}

/// Best (minimum mean) of `rounds` unconditional repetitions of a
/// measurement — the same symmetric noise damping for every side of
/// every A/B (scheduler, kernel), so comparisons stay unbiased: the
/// stopping rule never looks at which side is winning.
fn best_of(rounds: usize, mut measure: impl FnMut() -> (f64, usize)) -> (f64, usize) {
    let mut best = measure();
    for _ in 1..rounds {
        let r = measure();
        if r.0 < best.0 {
            best = r;
        }
    }
    best
}

fn main() {
    let d = 64;
    let methods = ["softmax", "yoso_32", "yoso_e", "nystrom", "longformer",
                   "linformer", "reformer", "performer"];
    let ns = smoke_or(vec![256usize, 512], vec![512usize, 1024, 2048, 4096]);
    let engine_ns = smoke_or(vec![512usize], vec![1024usize, 4096]);

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/fig7_efficiency.csv").unwrap();
    writeln!(
        csv,
        "method,n,threads,chunk_policy,sched,kernel,time_ms,peak_bytes,model_bytes"
    )
    .unwrap();

    println!("Figure 7 — per-instance forward time (ms) and peak memory\n");
    print!("{:<12}", "method");
    for &n in &ns {
        print!("{:>9}n={n:<6}", "");
    }
    println!();

    let mut rng = Rng::new(0);
    for method in methods {
        let mut time_row = format!("{method:<12}");
        let mut mem_row = format!("{:<12}", "");
        for &n in &ns {
            // quadratic methods get expensive; still measurable at 4096
            let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
            let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
            let v = Mat::randn(n, d, 1.0, &mut rng);
            let mut ctor_rng = Rng::new(7);
            let attn = by_name(method, &mut ctor_rng, d);
            let mut run_rng = Rng::new(9);
            reset_peak();
            let iters = if n >= 2048 { 3 } else { 5 };
            let r = bench(method, 1, iters, || {
                std::hint::black_box(attn.forward(&q, &k, &v, &mut run_rng));
            });
            let peak = peak_bytes();
            // yoso-family rows run the env-selected kernel; the rest of
            // the zoo has no kernel knob
            let kcol = if method.starts_with("yoso") && method != "yoso_e" {
                KernelVariant::from_env().label()
            } else {
                "-"
            };
            writeln!(
                csv,
                "{method},{n},1,-,serial,{kcol},{},{},{}",
                r.summary.mean * 1e3,
                peak,
                attn.workspace_bytes(n, d)
            )
            .unwrap();
            time_row += &format!(" {:>13.2}", r.summary.mean * 1e3);
            mem_row += &format!(" {:>13}", human_bytes(attn.workspace_bytes(n, d)));
        }
        println!("{time_row}");
        println!("{mem_row}");
    }

    // Seed-vs-fused kernel A/B (the PR-4 tentpole): identical inputs,
    // bit-identical outputs (property-tested), so the delta is pure
    // constant factor — arena reuse, matmul-backed hashing, bucket-
    // sorted streaming scatter. Symmetric best-of-3 per variant.
    println!("\nYOSO kernel A/B (yoso_32, serial trait forward)\n");
    println!("{:>6} {:>8} {:>12} {:>12} {:>9}", "n", "kernel", "seed_ms", "fused_ms", "speedup");
    let mut fused_losses = 0usize;
    let mut kernel_speedup_last_n = 0.0f64;
    for &n in &ns {
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let iters = smoke_or(3, if n >= 2048 { 3 } else { 5 });
        let seed_att =
            YosoAttention::new(8, 32, false).with_kernel(KernelVariant::Seed);
        let fused_att =
            YosoAttention::new(8, 32, false).with_kernel(KernelVariant::Fused);
        let (seed_ms, seed_peak) =
            best_of(3, || time_attention(&seed_att, &q, &k, &v, iters));
        let (fused_ms, fused_peak) =
            best_of(3, || time_attention(&fused_att, &q, &k, &v, iters));
        for (att, ms, peak) in [
            (&seed_att, seed_ms, seed_peak),
            (&fused_att, fused_ms, fused_peak),
        ] {
            // distinct method label: the zoo loop already emits a
            // 'yoso_32' serial row (env-selected kernel, single round);
            // reusing the name would put two conflicting timings under
            // the same (method,n,threads,policy,sched,kernel) key
            writeln!(
                csv,
                "yoso_32_kernel,{n},1,-,serial,{},{ms},{peak},{}",
                att.kernel.label(),
                att.workspace_bytes(n, d)
            )
            .unwrap();
        }
        let speedup = seed_ms / fused_ms.max(1e-9);
        println!(
            "{n:>6} {:>8} {seed_ms:>12.2} {fused_ms:>12.2} {speedup:>8.2}x",
            "a/b"
        );
        // 5% tolerance, same as the scheduler and fig9 gates: catch a
        // kernel regression, not a noisy-neighbor blip on a shared
        // runner (the expected fused margin is far larger than 5%)
        if fused_ms > seed_ms * 1.05 {
            fused_losses += 1;
        }
        if ns.last().copied().unwrap_or(0) == n {
            kernel_speedup_last_n = speedup;
        }
    }
    if smoke() {
        // bench-smoke is the kernel-regression gate: the fused kernel
        // must never lose to the seed kernel at any smoke size, and must
        // hold >= 1.2x at the largest smoke n (both damped best-of-3)
        if fused_losses > 0 {
            println!(
                "FAIL: fused kernel lost to the seed kernel at \
                 {fused_losses} smoke size(s)"
            );
            std::process::exit(1);
        }
        if kernel_speedup_last_n < 1.2 {
            println!(
                "FAIL: fused kernel speedup {kernel_speedup_last_n:.2}x < 1.2x \
                 at the largest smoke n"
            );
            std::process::exit(1);
        }
    } else if fused_losses > 0 {
        println!(
            "WARNING: fused kernel slower than seed at {fused_losses} sweep point(s)"
        );
    }

    // YOSO parallel engine: per-hash fan-out, (threads x scheduler x
    // chunk policy) sweep. The t = 1 row is the serial engine (no pool)
    // — the speed-up baseline for both schedulers.
    let counts = thread_counts();
    let adaptive = ChunkPolicy::adaptive(counts.last().copied().unwrap_or(1));
    println!("\nYOSO parallel engine scaling (yoso_32, per-hash fan-out)\n");
    println!(
        "{:>6} {:>8} {:>11} {:>7} {:>12} {:>10}",
        "n", "threads", "chunk", "sched", "time_ms", "speedup"
    );
    let att = YosoAttention::new(8, 32, false);
    let kern = att.kernel.label(); // env-selected; CI sweeps both
    let mut serial_ms_last_n = 0.0f64;
    let mut best_speedup_last_n = 1.0f64;
    let mut steal_losses = 0usize;
    for &n in &engine_ns {
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let iters = smoke_or(3, if n >= 2048 { 3 } else { 5 });
        let mut serial_ms = 0.0f64;
        let last_n = engine_ns.last().copied().unwrap_or(0) == n;
        for &t in &counts {
            if t == 1 {
                // no pool on either scheduler — one shared baseline row
                let engine = Engine::serial();
                let (ms, peak) = time_engine(&engine, &att, &q, &k, &v, iters);
                serial_ms = ms;
                if last_n {
                    serial_ms_last_n = ms;
                }
                writeln!(
                    csv,
                    "yoso_32_engine,{n},1,{},serial,{kern},{ms},{peak},{}",
                    engine.chunk_policy().label(),
                    engine.workspace_bytes(&att, n, d)
                )
                .unwrap();
                println!(
                    "{n:>6} {t:>8} {:>11} {:>7} {ms:>12.2} {:>9.2}x",
                    engine.chunk_policy().label(),
                    "serial",
                    1.0
                );
                continue;
            }
            // scheduler A/B at fixed chunking: symmetric best-of-3 per
            // scheduler (unconditional — see best_of) so noisy
            // shared-CI boxes are damped without biasing the comparison
            let chan = Engine::new_channel(t);
            let steal = Engine::new(t);
            let (chan_ms, chan_peak) =
                best_of(3, || time_engine(&chan, &att, &q, &k, &v, iters));
            let (steal_ms, steal_peak) =
                best_of(3, || time_engine(&steal, &att, &q, &k, &v, iters));
            // 5% tolerance: the smoke gate must catch a scheduler
            // regression, not a noisy-neighbor blip on a shared runner
            if steal_ms > chan_ms * 1.05 {
                steal_losses += 1;
            }
            // workspace model depends on (threads, policy) only — same
            // number for both schedulers
            let model_bytes = steal.workspace_bytes(&att, n, d);
            for (sched, ms, peak) in
                [("chan", chan_ms, chan_peak), ("steal", steal_ms, steal_peak)]
            {
                writeln!(
                    csv,
                    "yoso_32_engine,{n},{t},{},{sched},{kern},{ms},{peak},{model_bytes}",
                    steal.chunk_policy().label()
                )
                .unwrap();
                let speedup = serial_ms / ms.max(1e-9);
                println!(
                    "{n:>6} {t:>8} {:>11} {sched:>7} {ms:>12.2} {speedup:>9.2}x",
                    steal.chunk_policy().label()
                );
                if sched == "steal" && last_n {
                    best_speedup_last_n = best_speedup_last_n.max(speedup);
                }
            }
            // adaptive chunking on the stealing pool — the policy delta,
            // with the same best-of-3 damping as the fixed-policy rows
            let engine = Engine::with_policy(t, adaptive);
            let (ms, peak) = best_of(3, || time_engine(&engine, &att, &q, &k, &v, iters));
            let speedup = serial_ms / ms.max(1e-9);
            writeln!(
                csv,
                "yoso_32_engine,{n},{t},{},steal,{kern},{ms},{peak},{}",
                adaptive.label(),
                engine.workspace_bytes(&att, n, d)
            )
            .unwrap();
            println!(
                "{n:>6} {t:>8} {:>11} {:>7} {ms:>12.2} {speedup:>9.2}x",
                adaptive.label(),
                "steal"
            );
            if last_n {
                best_speedup_last_n = best_speedup_last_n.max(speedup);
            }
        }
    }
    let last_n = engine_ns.last().copied().unwrap_or(0);
    println!(
        "\nengine speedup at n={last_n}: {best_speedup_last_n:.2}x over serial \
         ({serial_ms_last_n:.2} ms) with up to {} threads",
        counts.last().copied().unwrap_or(1)
    );
    if steal_losses > 0 {
        println!(
            "WARNING: work-stealing pool slower than the channel pool at \
             {steal_losses} sweep point(s) (best-of-3 per scheduler)"
        );
        if smoke() {
            // the bench-smoke CI job is the regression gate: a stealing
            // scheduler that loses to the channel baseline at any point
            // of the smoke sweep must fail the job, not warn into a log
            std::process::exit(1);
        }
    }
    if !smoke() && counts.last().copied().unwrap_or(1) >= 4 && best_speedup_last_n < 2.0 {
        println!(
            "WARNING: expected >= 2x engine speedup on >= 4 cores, \
             measured {best_speedup_last_n:.2}x"
        );
    }
    println!("\n-> results/fig7_efficiency.csv");

    // the headline shape assertions (full runs only: at smoke sizes the
    // quadratic term does not dominate yet)
    if smoke() {
        println!("\nYOSO_BENCH_SMOKE: skipping softmax/yoso headline ratio");
        return;
    }
    let mut check = |method: &str, n: usize| -> f64 {
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let mut ctor_rng = Rng::new(7);
        let attn = by_name(method, &mut ctor_rng, d);
        let mut run_rng = Rng::new(9);
        bench(method, 1, 3, || {
            std::hint::black_box(attn.forward(&q, &k, &v, &mut run_rng));
        })
        .summary
        .mean
    };
    let sm = check("softmax", 4096);
    let yo = check("yoso_32", 4096);
    println!("\nsoftmax/yoso-32 time ratio at n=4096: {:.1}x (paper: ~10x class)",
             sm / yo);
}
