//! Figure 7: running time and peak memory vs sequence length for YOSO
//! and every baseline, per instance (one head, d = 64), with each
//! method's paper hyperparameters (§4.2/§4.3).
//!
//! Writes results/fig7_efficiency.csv (method,n,ms,peak_bytes,model_bytes)
//! and prints the two panels. The paper's shape to reproduce: softmax
//! grows quadratically and runs out of budget first; the efficient
//! methods stay near-linear; YOSO has the lowest memory profile.

use std::io::Write;
use yoso::attention::by_name;
use yoso::bench_support::{bench, human_bytes, peak_bytes, reset_peak, CountingAlloc};
use yoso::tensor::Mat;
use yoso::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn main() {
    let d = 64;
    let methods = ["softmax", "yoso_32", "yoso_e", "nystrom", "longformer",
                   "linformer", "reformer", "performer"];
    let ns = [512usize, 1024, 2048, 4096];

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/fig7_efficiency.csv").unwrap();
    writeln!(csv, "method,n,time_ms,peak_bytes,model_bytes").unwrap();

    println!("Figure 7 — per-instance forward time (ms) and peak memory\n");
    print!("{:<12}", "method");
    for n in ns {
        print!("{:>9}n={n:<6}", "");
    }
    println!();

    let mut rng = Rng::new(0);
    for method in methods {
        let mut time_row = format!("{method:<12}");
        let mut mem_row = format!("{:<12}", "");
        for &n in &ns {
            // quadratic methods get expensive; still measurable at 4096
            let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
            let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
            let v = Mat::randn(n, d, 1.0, &mut rng);
            let mut ctor_rng = Rng::new(7);
            let attn = by_name(method, &mut ctor_rng, d);
            let mut run_rng = Rng::new(9);
            reset_peak();
            let iters = if n >= 2048 { 3 } else { 5 };
            let r = bench(method, 1, iters, || {
                std::hint::black_box(attn.forward(&q, &k, &v, &mut run_rng));
            });
            let peak = peak_bytes();
            writeln!(
                csv,
                "{method},{n},{},{},{}",
                r.summary.mean * 1e3,
                peak,
                attn.workspace_bytes(n, d)
            )
            .unwrap();
            time_row += &format!(" {:>13.2}", r.summary.mean * 1e3);
            mem_row += &format!(" {:>13}", human_bytes(attn.workspace_bytes(n, d)));
        }
        println!("{time_row}");
        println!("{mem_row}");
    }
    println!("\n-> results/fig7_efficiency.csv");

    // the headline shape assertions
    let mut check = |method: &str, n: usize| -> f64 {
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let mut ctor_rng = Rng::new(7);
        let attn = by_name(method, &mut ctor_rng, d);
        let mut run_rng = Rng::new(9);
        bench(method, 1, 3, || {
            std::hint::black_box(attn.forward(&q, &k, &v, &mut run_rng));
        })
        .summary
        .mean
    };
    let sm = check("softmax", 4096);
    let yo = check("yoso_32", 4096);
    println!("\nsoftmax/yoso-32 time ratio at n=4096: {:.1}x (paper: ~10x class)",
             sm / yo);
}
