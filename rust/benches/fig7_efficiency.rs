//! Figure 7: running time and peak memory vs sequence length for YOSO
//! and every baseline, per instance (one head, d = 64), with each
//! method's paper hyperparameters (§4.2/§4.3).
//!
//! Writes results/fig7_efficiency.csv
//! (method,n,threads,time_ms,peak_bytes,model_bytes) and prints the two
//! panels. Zoo baselines run serially (threads = 1); the YOSO parallel
//! engine rows sweep thread counts (powers of two up to the core count,
//! capped by `YOSO_BENCH_THREADS`) so the multi-thread speed-up is
//! measured, not asserted. The paper's shape to reproduce: softmax grows
//! quadratically and runs out of budget first; the efficient methods
//! stay near-linear; YOSO has the lowest memory profile.

use std::io::Write;
use yoso::attention::{by_name, Engine, YosoAttention};
use yoso::bench_support::{
    bench, bench_threads, human_bytes, peak_bytes, reset_peak, CountingAlloc,
};
use yoso::tensor::Mat;
use yoso::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// 1, 2, 4, ... up to the `bench_threads()` budget.
fn thread_counts() -> Vec<usize> {
    let max_threads = bench_threads();
    let mut counts = vec![1usize];
    let mut t = 2;
    while t < max_threads {
        counts.push(t);
        t *= 2;
    }
    if max_threads > 1 {
        counts.push(max_threads);
    }
    counts
}

fn main() {
    let d = 64;
    let methods = ["softmax", "yoso_32", "yoso_e", "nystrom", "longformer",
                   "linformer", "reformer", "performer"];
    let ns = [512usize, 1024, 2048, 4096];

    std::fs::create_dir_all("results").unwrap();
    let mut csv = std::fs::File::create("results/fig7_efficiency.csv").unwrap();
    writeln!(csv, "method,n,threads,time_ms,peak_bytes,model_bytes").unwrap();

    println!("Figure 7 — per-instance forward time (ms) and peak memory\n");
    print!("{:<12}", "method");
    for n in ns {
        print!("{:>9}n={n:<6}", "");
    }
    println!();

    let mut rng = Rng::new(0);
    for method in methods {
        let mut time_row = format!("{method:<12}");
        let mut mem_row = format!("{:<12}", "");
        for &n in &ns {
            // quadratic methods get expensive; still measurable at 4096
            let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
            let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
            let v = Mat::randn(n, d, 1.0, &mut rng);
            let mut ctor_rng = Rng::new(7);
            let attn = by_name(method, &mut ctor_rng, d);
            let mut run_rng = Rng::new(9);
            reset_peak();
            let iters = if n >= 2048 { 3 } else { 5 };
            let r = bench(method, 1, iters, || {
                std::hint::black_box(attn.forward(&q, &k, &v, &mut run_rng));
            });
            let peak = peak_bytes();
            writeln!(
                csv,
                "{method},{n},1,{},{},{}",
                r.summary.mean * 1e3,
                peak,
                attn.workspace_bytes(n, d)
            )
            .unwrap();
            time_row += &format!(" {:>13.2}", r.summary.mean * 1e3);
            mem_row += &format!(" {:>13}", human_bytes(attn.workspace_bytes(n, d)));
        }
        println!("{time_row}");
        println!("{mem_row}");
    }

    // YOSO parallel engine: per-hash fan-out, thread-count sweep. The
    // t = 1 row is the serial engine (no pool) — the speed-up baseline.
    println!("\nYOSO parallel engine scaling (yoso_32, per-hash fan-out)\n");
    println!("{:>6} {:>8} {:>12} {:>10}", "n", "threads", "time_ms", "speedup");
    let att = YosoAttention::new(8, 32, false);
    let counts = thread_counts();
    let mut serial_ms_n4096 = 0.0f64;
    let mut best_speedup_n4096 = 1.0f64;
    for n in [1024usize, 4096] {
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let mut serial_ms = 0.0f64;
        for &t in &counts {
            let engine = Engine::new(t);
            let run_rng = Rng::new(9);
            reset_peak();
            let iters = if n >= 2048 { 3 } else { 5 };
            let r = bench(&format!("yoso_32_engine n={n} t={t}"), 1, iters, || {
                std::hint::black_box(
                    engine.forward_yoso(&att, &q, &k, &v, &run_rng),
                );
            });
            let peak = peak_bytes();
            let ms = r.summary.mean * 1e3;
            if t == 1 {
                serial_ms = ms;
                if n == 4096 {
                    serial_ms_n4096 = ms;
                }
            }
            let speedup = serial_ms / ms.max(1e-9);
            if n == 4096 {
                best_speedup_n4096 = best_speedup_n4096.max(speedup);
            }
            writeln!(
                csv,
                "yoso_32_engine,{n},{t},{ms},{peak},{}",
                engine.workspace_bytes(&att, n, d)
            )
            .unwrap();
            println!("{n:>6} {t:>8} {ms:>12.2} {speedup:>9.2}x");
        }
    }
    println!(
        "\nengine speedup at n=4096: {best_speedup_n4096:.2}x over serial \
         ({serial_ms_n4096:.2} ms) with up to {} threads",
        counts.last().copied().unwrap_or(1)
    );
    if counts.last().copied().unwrap_or(1) >= 4 && best_speedup_n4096 < 2.0 {
        println!(
            "WARNING: expected >= 2x engine speedup on >= 4 cores, \
             measured {best_speedup_n4096:.2}x"
        );
    }
    println!("\n-> results/fig7_efficiency.csv");

    // the headline shape assertions
    let mut check = |method: &str, n: usize| -> f64 {
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let mut ctor_rng = Rng::new(7);
        let attn = by_name(method, &mut ctor_rng, d);
        let mut run_rng = Rng::new(9);
        bench(method, 1, 3, || {
            std::hint::black_box(attn.forward(&q, &k, &v, &mut run_rng));
        })
        .summary
        .mean
    };
    let sm = check("softmax", 4096);
    let yo = check("yoso_32", 4096);
    println!("\nsoftmax/yoso-32 time ratio at n=4096: {:.1}x (paper: ~10x class)",
             sm / yo);
}
