//! Seed-vs-fused kernel equivalence properties (the PR-4 tentpole's
//! oracle): the fused arena-backed kernel (`attention::kernel`) must be
//! **bit-identical** to the preserved seed kernel across zoo shapes x
//! tau x m x uniform/skewed keys x both hashers x thread counts — the
//! stable counting-sort scatter keeps each bucket's additions in
//! ascending-j order and every hash projection is exactly `linalg::dot`,
//! so this is an equality the implementation owes, not a tolerance.
//! Also: the Remark-3 property (the fused `WorkspaceTrace` is a pure
//! function of shape, never of bucket skew) and the analytic
//! `workspace_model` matching the runtime trace under both kernels.
//! Pool widths honor `YOSO_TEST_THREADS`; CI sweeps `YOSO_KERNEL` too,
//! which these tests deliberately ignore by pinning variants.

use yoso::attention::{Engine, KernelVariant, YosoAttention};
use yoso::tensor::Mat;
use yoso::testing::test_threads;
use yoso::util::Rng;

fn bits_equal(a: &Mat, b: &Mat) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data
            .iter()
            .zip(&b.data)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

/// (nq, nk, d, dv) — deliberately asymmetric shapes: cross-attention
/// counts, dv != d (the workspace-model regression), odd sizes that
/// exercise the chunks_exact(8) remainders and the matmul row tiling.
const SHAPES: [(usize, usize, usize, usize); 4] = [
    (64, 64, 32, 32),
    (48, 80, 16, 24),
    (33, 57, 16, 40),
    (64, 64, 32, 8),
];

/// Uniform random keys, or maximally skewed (every key identical — one
/// bucket holds everything).
fn keys(nk: usize, d: usize, skewed: bool, rng: &mut Rng) -> Mat {
    if skewed {
        Mat::from_fn(nk, d, |_, j| if j == 0 { 1.0 } else { 0.0 })
    } else {
        Mat::randn(nk, d, 1.0, rng).unit_rows()
    }
}

#[test]
fn fused_bit_identical_to_seed_across_shapes_hashers_and_skew() {
    for &(nq, nk, d, dv) in &SHAPES {
        for fast in [false, true] {
            if fast && !d.is_power_of_two() {
                continue;
            }
            for (tau, m) in [(3usize, 1usize), (5, 8), (8, 32)] {
                for skewed in [false, true] {
                    let mut gen = Rng::new(
                        (nq * 31 + d * 7 + tau * 3 + m) as u64
                            ^ ((skewed as u64) << 40),
                    );
                    let q = Mat::randn(nq, d, 1.0, &mut gen).unit_rows();
                    let k = keys(nk, d, skewed, &mut gen);
                    let v = Mat::randn(nk, dv, 1.0, &mut gen);
                    let seed_att = YosoAttention::new(tau, m, fast)
                        .with_kernel(KernelVariant::Seed);
                    let fused_att = YosoAttention::new(tau, m, fast)
                        .with_kernel(KernelVariant::Fused);
                    let mut r1 = Rng::new(0xBEEF ^ m as u64);
                    let (ys, ts) = seed_att.forward_raw_traced(&q, &k, &v, &mut r1);
                    let mut r2 = Rng::new(0xBEEF ^ m as u64);
                    let (yf, tf) = fused_att.forward_raw_traced(&q, &k, &v, &mut r2);
                    assert!(
                        bits_equal(&ys, &yf),
                        "fused != seed at nq={nq} nk={nk} d={d} dv={dv} \
                         tau={tau} m={m} fast={fast} skewed={skewed}"
                    );
                    // analytic model == runtime trace, both kernels
                    assert_eq!(seed_att.workspace_model(nq, nk, d, dv), ts.total());
                    assert_eq!(fused_att.workspace_model(nq, nk, d, dv), tf.total());
                    // and the normalized (N-YOSO) trait forward agrees too
                    let mut r3 = Rng::new(0xF00D);
                    let mut r4 = Rng::new(0xF00D);
                    use yoso::attention::Attention;
                    let ns = seed_att.forward(&q, &k, &v, &mut r3);
                    let nf = fused_att.forward(&q, &k, &v, &mut r4);
                    assert!(bits_equal(&ns, &nf), "normalized forward diverged");
                }
            }
        }
    }
}

#[test]
fn fused_trace_is_skew_independent() {
    // Remark 3 under the fused kernel: identical keys (one bucket holds
    // every value row) must not change the arena footprint the pass
    // requires — the counting sort's buffers are sized by shape alone.
    for &(nq, nk, d, dv) in &SHAPES {
        for fast in [false, true] {
            if fast && !d.is_power_of_two() {
                continue;
            }
            let att = YosoAttention::new(6, 4, fast).with_kernel(KernelVariant::Fused);
            let mut gen = Rng::new(77);
            let q = Mat::randn(nq, d, 1.0, &mut gen).unit_rows();
            let k_uniform = keys(nk, d, false, &mut gen);
            let k_skewed = keys(nk, d, true, &mut gen);
            let v = Mat::randn(nk, dv, 1.0, &mut gen);
            let mut r1 = Rng::new(3);
            let (_, trace_u) = att.forward_raw_traced(&q, &k_uniform, &v, &mut r1);
            let mut r2 = Rng::new(3);
            let (_, trace_s) = att.forward_raw_traced(&q, &k_skewed, &v, &mut r2);
            assert_eq!(
                trace_u, trace_s,
                "fused workspace varied with skew (nq={nq} nk={nk} fast={fast})"
            );
        }
    }
}

#[test]
fn engine_fused_bit_identical_to_engine_seed_across_thread_counts() {
    // the per-hash engine fan-out must preserve the equivalence at every
    // pool width: fused rounds run out of per-worker arenas, and arena
    // placement (which worker ran which round) must never leak into the
    // bytes
    let mut gen = Rng::new(5);
    let q = Mat::randn(72, 32, 1.0, &mut gen).unit_rows();
    let k = Mat::randn(72, 32, 1.0, &mut gen).unit_rows();
    let v = Mat::randn(72, 32, 1.0, &mut gen);
    for fast in [false, true] {
        let seed_att = YosoAttention::new(6, 12, fast).with_kernel(KernelVariant::Seed);
        let fused_att =
            YosoAttention::new(6, 12, fast).with_kernel(KernelVariant::Fused);
        let rng = Rng::new(31);
        let reference = Engine::serial().forward_yoso(&seed_att, &q, &k, &v, &rng);
        for threads in [1usize, 2, test_threads(4)] {
            let s = Engine::new(threads).forward_yoso(&seed_att, &q, &k, &v, &rng);
            let f = Engine::new(threads).forward_yoso(&fused_att, &q, &k, &v, &rng);
            assert!(bits_equal(&reference, &s), "seed engine t={threads} fast={fast}");
            assert!(bits_equal(&reference, &f), "fused engine t={threads} fast={fast}");
        }
    }
}

#[test]
fn arena_reuse_across_geometries_is_stateless() {
    // one thread serving mixed shapes back-to-back: the thread-local
    // arena grows to the high-water mark and every pass slices buffers
    // to its own logical size — stale tails from a larger earlier pass
    // must never leak into a smaller later pass. Run the whole sweep
    // twice and require pass-2 bytes == pass-1 bytes.
    let run_all = || -> Vec<Mat> {
        SHAPES
            .iter()
            .map(|&(nq, nk, d, dv)| {
                let mut gen = Rng::new((nq + nk + dv) as u64);
                let q = Mat::randn(nq, d, 1.0, &mut gen).unit_rows();
                let k = Mat::randn(nk, d, 1.0, &mut gen).unit_rows();
                let v = Mat::randn(nk, dv, 1.0, &mut gen);
                let att =
                    YosoAttention::new(6, 6, false).with_kernel(KernelVariant::Fused);
                let mut r = Rng::new(13);
                att.forward_raw(&q, &k, &v, &mut r)
            })
            .collect()
    };
    let first = run_all();
    let second = run_all();
    for (a, b) in first.iter().zip(&second) {
        assert!(bits_equal(a, b), "arena reuse changed bytes");
    }
}
