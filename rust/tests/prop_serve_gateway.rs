//! The gateway determinism contract, end to end: CPU-path logits are a
//! pure function of (config seed, request content). The gateway must be
//! **bit-identical** to the single-loop `ServerHandle::spawn_cpu` path
//! for the same seed/content across replica counts {1, 2, 4}, every
//! bucket layout (single-bucket baseline and two power-of-two layouts),
//! **both scheduling policies** (the work-conserving deadline-aware
//! `Conserve` and the FIFO A/B baseline), and shuffled arrival order —
//! bucketing, batching, scheduling, and replication are wall-clock
//! knobs only. Requests include hostile tokens so the shared
//! canonicalization is part of the tested contract. Pool widths honor
//! `YOSO_TEST_THREADS` so CI sweeps them.

use std::time::Duration;
use yoso::attention::{ChunkPolicy, KernelVariant};
use yoso::model::encoder::EncoderConfig;
use yoso::serve::{
    BatchPolicy, BatchPolicyTable, BucketLayout, CpuServeConfig, Gateway,
    GatewayConfig, SchedPolicy, ServerHandle, ShedPolicy,
};
use yoso::testing::test_threads;
use yoso::util::Rng;

/// Small geometry so the debug-build encoder forward stays in the
/// millisecond range; d_head = 32 (power of two) suits every variant.
fn tiny_cfg(seed: u64) -> CpuServeConfig {
    CpuServeConfig {
        attention: "yoso_8".into(),
        encoder: EncoderConfig {
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            vocab_size: 2005,
            max_len: 32,
            n_classes: 2,
        },
        threads: test_threads(2),
        chunk_policy: ChunkPolicy::default(),
        // env default: CI's scheduler-stress sweep runs this whole
        // contract under both kernels via YOSO_KERNEL
        kernel: KernelVariant::from_env(),
        seed,
    }
}

/// Variable-length requests spanning several buckets, with hostile
/// tokens (negative / out-of-vocab ids, bad segments) mixed in.
fn request_set(rng: &mut Rng) -> Vec<(Vec<i32>, Vec<i32>)> {
    (0..8)
        .map(|_| {
            let len = 3 + rng.below(29);
            let ids: Vec<i32> = (0..len)
                .map(|_| match rng.below(12) {
                    0 => -5,
                    1 => 999_999,
                    _ => 5 + rng.below(1990) as i32,
                })
                .collect();
            let segs: Vec<i32> =
                (0..len).map(|_| rng.below(3) as i32 - 1).collect();
            (ids, segs)
        })
        .collect()
}

#[test]
fn gateway_bit_identical_to_single_loop_path() {
    let seed = 17u64;
    let mut rng = Rng::new(0xBEEF);
    let reqs = request_set(&mut rng);

    // reference bytes: the single-loop CPU serve path
    let handle = ServerHandle::spawn_cpu(
        tiny_cfg(seed),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
    );
    let reference: Vec<Vec<f32>> = reqs
        .iter()
        .map(|(ids, segs)| {
            handle
                .submit(ids.clone(), segs.clone())
                .recv()
                .expect("reference reply")
                .logits
        })
        .collect();
    let ref_stats = handle.shutdown().expect("reference stats");
    assert_eq!(ref_stats.requests, reqs.len());

    let layouts = [
        BucketLayout::single(32),
        BucketLayout::pow2(8, 32),
        BucketLayout::pow2(16, 32),
    ];
    for replicas in [1usize, 2, 4] {
        for (li, layout) in layouts.iter().enumerate() {
            for (si, sched) in
                [SchedPolicy::Fifo, SchedPolicy::Conserve].into_iter().enumerate()
            {
                let mut cfg = GatewayConfig::new(tiny_cfg(seed));
                cfg.replicas = replicas;
                cfg.queue_capacity = 64;
                cfg.shed = ShedPolicy::Reject;
                cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
                    max_batch: 4,
                    max_wait: Duration::from_millis(1),
                });
                cfg.buckets = layout.clone();
                cfg.sched = sched;
                cfg.bucketing = true;
                let gw = Gateway::spawn(cfg);

                // arrival order shuffled differently per
                // (replicas, layout, sched)
                let mut order: Vec<usize> = (0..reqs.len()).collect();
                Rng::new(
                    0xD1CE
                        ^ ((replicas as u64) << 8)
                        ^ ((si as u64) << 4)
                        ^ li as u64,
                )
                .shuffle(&mut order);
                let mut rxs: Vec<Option<_>> =
                    (0..reqs.len()).map(|_| None).collect();
                for &i in &order {
                    let (ids, segs) = &reqs[i];
                    rxs[i] = Some(
                        gw.submit(ids.clone(), segs.clone()).expect("admitted"),
                    );
                }
                for (i, rx) in rxs.into_iter().enumerate() {
                    let got = rx
                        .unwrap()
                        .recv()
                        .expect("one reply per request")
                        .expect("served, not shed")
                        .logits;
                    assert_eq!(reference[i].len(), got.len());
                    for (a, b) in reference[i].iter().zip(&got) {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "request {i} diverged from the single-loop path \
                             (replicas={replicas}, layout={:?}, sched={})",
                            layout.widths(),
                            sched.label()
                        );
                    }
                }
                let stats = gw.shutdown();
                assert_eq!(stats.completed, reqs.len() as u64);
                assert_eq!(
                    stats.accepted,
                    stats.completed + stats.shed_deadline
                );
                if layout.widths().len() > 1 {
                    // the variable-length set must actually exercise
                    // multiple buckets, or the layout sweep proves nothing
                    let used = stats
                        .per_bucket
                        .iter()
                        .filter(|h| h.count() > 0)
                        .count();
                    assert!(
                        used > 1,
                        "layout {:?} served everything from one bucket",
                        layout.widths()
                    );
                }
            }
        }
    }
}

#[test]
fn gateway_repeated_identical_inputs_reproduce() {
    // same gateway, same content, different batches/arrival positions:
    // the width-keyed serving RNG must reproduce the logits exactly —
    // including across prefix-cache hits (the repeat is a cache hit)
    let gw = Gateway::spawn(GatewayConfig::new(tiny_cfg(9)));
    let ids = vec![9i32; 20];
    let segs = vec![0i32; 20];
    let a = gw
        .submit(ids.clone(), segs.clone())
        .expect("admitted")
        .recv()
        .unwrap()
        .expect("served");
    // interleave some other traffic so the repeat lands elsewhere
    let noise = gw.submit(vec![7i32; 5], vec![0i32; 5]).expect("admitted");
    let b = gw
        .submit(ids, segs)
        .expect("admitted")
        .recv()
        .unwrap()
        .expect("served");
    assert_eq!(a.logits, b.logits);
    noise.recv().unwrap().expect("noise served");
    gw.shutdown();
}
