//! Property tests for the serving observability primitives
//! (`metrics::Histogram`, `util::stats::Welford`) — the merge-at-
//! shutdown machinery the gateway's per-replica/per-bucket stats lean
//! on. Proptest-style randomized loops (like `prop_kernel_equiv.rs`):
//!
//! * **merge == concatenation**: splitting any value stream into
//!   arbitrary parts, recording each part into its own histogram, and
//!   merging must reproduce the whole-stream histogram *exactly* —
//!   counts, mean, min/max, and every quantile bit-for-bit (the layout
//!   is fixed, so bucket-wise addition is lossless);
//! * **quantile error bound**: the 8-sub-buckets-per-octave layout
//!   promises any quantile within ~9% relative error of the exact
//!   order statistic; checked against sorted-select ground truth over
//!   randomized heavy-tailed streams (a 10% assertion leaves margin
//!   over the analytic 2^(1/8)-geometry bound);
//! * **`Welford::merge` == single stream**: mean/variance after merging
//!   arbitrary splits match pushing every sample into one accumulator.

use yoso::metrics::Histogram;
use yoso::util::stats::{quantile_exact, Welford};
use yoso::util::Rng;

const QS: [f64; 7] = [0.01, 0.1, 0.5, 0.9, 0.95, 0.99, 1.0];

/// A latency-shaped sample: log-uniform over ~6 orders of magnitude,
/// with occasional heavy-tail outliers — the distribution shape the
/// log-bucketed layout exists for.
fn sample(rng: &mut Rng) -> f64 {
    let base = (rng.uniform_f64() * 20.0 - 4.0).exp2();
    if rng.below(50) == 0 {
        base * 1e4 // tail spike
    } else {
        base
    }
}

#[test]
fn prop_histogram_merge_equals_concatenation() {
    let mut rng = Rng::new(0x4157);
    for case in 0..50u64 {
        let n = 100 + rng.below(2900);
        let parts = 2 + rng.below(5);
        let mut whole = Histogram::new();
        let mut shards: Vec<Histogram> =
            (0..parts).map(|_| Histogram::new()).collect();
        for _ in 0..n {
            let v = sample(&mut rng);
            whole.record(v);
            shards[rng.below(parts)].record(v);
        }
        // merge in a random order (merge must be order-independent)
        let mut merged = Histogram::new();
        let mut order: Vec<usize> = (0..parts).collect();
        rng.shuffle(&mut order);
        for i in order {
            merged.merge(&shards[i]);
        }
        assert_eq!(merged.count(), whole.count(), "case {case}");
        assert!(
            (merged.mean() - whole.mean()).abs()
                <= 1e-9 * whole.mean().abs().max(1.0),
            "case {case}: merged mean {} vs whole {}",
            merged.mean(),
            whole.mean()
        );
        assert_eq!(merged.min(), whole.min(), "case {case}");
        assert_eq!(merged.max(), whole.max(), "case {case}");
        for q in QS {
            assert_eq!(
                merged.quantile(q).to_bits(),
                whole.quantile(q).to_bits(),
                "case {case}: quantile({q}) diverged after merge"
            );
        }
    }
}

#[test]
fn prop_histogram_quantiles_within_resolution_bound() {
    let mut rng = Rng::new(0xB0B);
    for case in 0..30u64 {
        let n = 500 + rng.below(2500);
        let mut h = Histogram::new();
        let mut xs: Vec<f64> = Vec::with_capacity(n);
        for _ in 0..n {
            // log-uniform across 30 octaves, strictly inside the
            // resolvable range [2^-16, 2^24): the resolution promise
            // only covers values the geometric buckets can represent
            // (out-of-range values fall into under/overflow slots, which
            // the merge test still covers exactly)
            let v = (rng.uniform_f64() * 30.0 - 10.0).exp2();
            h.record(v);
            xs.push(v);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.1, 0.5, 0.9, 0.95, 0.99] {
            let exact = quantile_exact(&xs, q);
            let approx = h.quantile(q);
            assert!(
                (approx - exact).abs() / exact < 0.10,
                "case {case}: q={q} exact {exact} vs histogram {approx} \
                 (n={n}) — outside the ~9% log-bucket bound"
            );
        }
        // quantiles stay monotone in q on every random stream
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=20 {
            let v = h.quantile(i as f64 / 20.0);
            assert!(v >= prev, "case {case}: quantile not monotone");
            prev = v;
        }
    }
}

#[test]
fn prop_welford_merge_matches_single_stream() {
    let mut rng = Rng::new(0x3EF);
    for case in 0..50u64 {
        let n = 10 + rng.below(2000);
        let parts = 2 + rng.below(6);
        // signed, multi-scale samples: Welford has no sign restriction
        let xs: Vec<f64> = (0..n)
            .map(|_| (rng.normal() as f64) * (rng.uniform_f64() * 1e3 + 1e-3))
            .collect();
        let mut whole = Welford::default();
        for &x in &xs {
            whole.push(x);
        }
        let mut shards: Vec<Welford> =
            (0..parts).map(|_| Welford::default()).collect();
        for &x in &xs {
            shards[rng.below(parts)].push(x);
        }
        let mut merged = Welford::default();
        for s in &shards {
            merged.merge(s); // empty shards must merge as no-ops
        }
        assert_eq!(merged.count(), whole.count(), "case {case}");
        let scale = whole.mean().abs().max(whole.variance()).max(1.0);
        assert!(
            (merged.mean() - whole.mean()).abs() <= 1e-9 * scale,
            "case {case}: mean {} vs {}",
            merged.mean(),
            whole.mean()
        );
        assert!(
            (merged.variance() - whole.variance()).abs() <= 1e-6 * scale,
            "case {case}: variance {} vs {}",
            merged.variance(),
            whole.variance()
        );
    }
}
