//! The streamed-encoding bit-identity contract, property-tested at
//! every layer:
//!
//! * `attention::YosoStream` — appending keys/values in *any* random
//!   chunking produces byte-identical output to one batch forward at
//!   the same total width, across shapes × tau × m × both hashers ×
//!   both kernels (the additive-sketch invariant the prefix cache
//!   rests on);
//! * interleaved sessions on separate streams never cross-contaminate,
//!   and a `reset` stream replays a fresh one byte-for-byte (the
//!   arena-reuse statelessness surface);
//! * `model::encoder::EncoderStream` — a session grown in random
//!   chunks classifies byte-identically to the bucketed batch serving
//!   path at every intermediate prefix;
//! * the gateway prefix cache — hits return the same bytes the cold
//!   path computes, and the hit/miss counters account for every
//!   streamed request;
//! * the m'-prefix degradation contract — a session absorbed at `m`
//!   hash rounds and read at any `m' <= m` produces byte-identical
//!   output to a fresh `m'`-round forward, across shapes × tau × both
//!   hashers × both kernels (`m_prefix_readout_matches_fresh_m_forward`),
//!   and a gateway request pinned to `Quality::Degraded(m')` returns
//!   the exact bytes of a server configured at `m'` end to end —
//!   through both the prefix-cache readout and the batch fallback.

use std::sync::Arc;
use std::time::Duration;
use yoso::attention::{
    Attention, ChunkPolicy, KernelVariant, MultiHeadAttention,
    YosoAttention, YosoStream,
};
use yoso::model::encoder::{
    encoder_abi_spec, serving_rng, Encoder, EncoderConfig, EncoderStream,
};
use yoso::model::ParamSet;
use yoso::serve::{
    BatchPolicy, CpuServeConfig, Gateway, GatewayConfig, Quality,
    ServerHandle,
};
use yoso::tensor::Mat;
use yoso::util::Rng;

fn slice_rows(m: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, m.cols, |i, j| m.at(lo + i, j))
}

fn qkv(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
    let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
    let v = Mat::randn(n, d, 1.0, &mut rng);
    (q, k, v)
}

fn assert_bits(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: element {i}");
    }
}

#[test]
fn chunked_appends_match_batch_forward() {
    let mut chunk_rng = Rng::new(0xC0FFEE);
    for &(n, d) in &[(17usize, 16usize), (40, 32)] {
        for &tau in &[4usize, 8] {
            for &m in &[1usize, 8] {
                for fast in [false, true] {
                    for kernel in [KernelVariant::Seed, KernelVariant::Fused]
                    {
                        let att = YosoAttention::new(tau, m, fast)
                            .with_kernel(kernel);
                        let (q, k, v) =
                            qkv(n, d, 7 + n as u64 * 31 + tau as u64);
                        let expected =
                            att.forward(&q, &k, &v, &mut Rng::new(99));
                        let mut s =
                            YosoStream::new(&att, d, d, &mut Rng::new(99));
                        let mut off = 0;
                        while off < n {
                            let step = (1 + chunk_rng.below(5) as usize)
                                .min(n - off);
                            s.append(
                                &slice_rows(&k, off, off + step),
                                &slice_rows(&v, off, off + step),
                            );
                            off += step;
                        }
                        assert_eq!(s.n_keys(), n);
                        let mut out = Mat::zeros(n, d);
                        s.finish_into(&q, s.m(), &mut out);
                        let ctx = format!(
                            "n={n} d={d} tau={tau} m={m} fast={fast} \
                             kernel={}",
                            kernel.label()
                        );
                        assert_bits(&out.data, &expected.data, &ctx);
                    }
                }
            }
        }
    }
}

#[test]
fn interleaved_sessions_do_not_cross_contaminate() {
    for fast in [false, true] {
        let att = YosoAttention::new(5, 4, fast);
        let d = 16;
        let (qa, ka, va) = qkv(20, d, 1);
        let (qb, kb, vb) = qkv(28, d, 2);
        let ea = att.forward(&qa, &ka, &va, &mut Rng::new(5));
        let eb = att.forward(&qb, &kb, &vb, &mut Rng::new(6));

        let mut sa = YosoStream::new(&att, d, d, &mut Rng::new(5));
        let mut sb = YosoStream::new(&att, d, d, &mut Rng::new(6));
        // interleave appends chunk by chunk: each stream must see only
        // its own session
        let (mut oa, mut ob) = (0usize, 0usize);
        while oa < 20 || ob < 28 {
            if oa < 20 {
                let hi = (oa + 3).min(20);
                sa.append(&slice_rows(&ka, oa, hi), &slice_rows(&va, oa, hi));
                oa = hi;
            }
            if ob < 28 {
                let hi = (ob + 5).min(28);
                sb.append(&slice_rows(&kb, ob, hi), &slice_rows(&vb, ob, hi));
                ob = hi;
            }
        }
        let mut out = Mat::zeros(20, d);
        sa.finish_into(&qa, sa.m(), &mut out);
        assert_bits(&out.data, &ea.data, &format!("A fast={fast}"));
        let mut out = Mat::zeros(28, d);
        sb.finish_into(&qb, sb.m(), &mut out);
        assert_bits(&out.data, &eb.data, &format!("B fast={fast}"));

        // arena-reuse statelessness: resetting A onto B's seed and
        // content must replay B's bytes off A's recycled buffers
        sa.reset(&mut Rng::new(6));
        sa.append(&kb, &vb);
        let mut out = Mat::zeros(28, d);
        sa.finish_into(&qb, sa.m(), &mut out);
        assert_bits(&out.data, &eb.data, &format!("reset fast={fast}"));
    }
}

#[test]
fn encoder_stream_prefix_growth_matches_bucketed_path() {
    let cfg = EncoderConfig::base(64, 32, 3);
    let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 11);
    let enc = Encoder::new(cfg, &params);
    let att = YosoAttention::new(5, 8, false);
    let shared: Arc<dyn Attention> = Arc::new(att.clone());
    let mh = MultiHeadAttention::serial_with_policy(ChunkPolicy::default());
    let seed = 21u64;
    let width = 32usize;
    let ids: Vec<i32> = (0..30).map(|i| (i % 60) + 4).collect();
    let segs: Vec<i32> = (0..30).map(|i| i % 2).collect();

    let mut stream = EncoderStream::new(&enc, &att, seed, width);
    let mut chunk_rng = Rng::new(0xFACE);
    let mut done = 0usize;
    while done < ids.len() {
        let step =
            (1 + chunk_rng.below(6) as usize).min(ids.len() - done);
        stream.append(&enc, &ids[done..done + step], &segs[done..done + step]);
        done += step;
        // every intermediate prefix must match a cold batch encode of
        // exactly that prefix — the invariant that makes a cache hit
        // indistinguishable from a recompute
        let got = stream.classify(&enc);
        let expect = enc.classify_bucketed(
            &ids[..done],
            &segs[..done],
            width,
            &shared,
            &mh,
            &mut serving_rng(seed, width),
        );
        assert_bits(&got, &expect, &format!("prefix len {done}"));
    }
}

fn stream_cfg(seed: u64) -> CpuServeConfig {
    CpuServeConfig {
        attention: "yoso_8".into(),
        encoder: EncoderConfig {
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            vocab_size: 2005,
            max_len: 32,
            n_classes: 2,
        },
        threads: 1,
        chunk_policy: ChunkPolicy::default(),
        kernel: KernelVariant::from_env(),
        seed,
    }
}

#[test]
fn gateway_prefix_cache_hits_preserve_logits_and_count() {
    let seed = 23u64;
    let prefix: Vec<i32> = (0..10).map(|i| 5 + i).collect();
    let full: Vec<i32> = (0..14).map(|i| 5 + i).collect();
    let seg = |n: usize| vec![0i32; n];

    // reference bytes: the single-loop batch path, no cache anywhere
    let handle = ServerHandle::spawn_cpu(
        stream_cfg(seed),
        BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
    );
    let ref_prefix =
        handle.submit(prefix.clone(), seg(10)).recv().unwrap().logits;
    let ref_full =
        handle.submit(full.clone(), seg(14)).recv().unwrap().logits;
    handle.shutdown().expect("reference stats");

    // both lengths share bucket_len == 16, so the session for `prefix`
    // is a checkout candidate for `full`
    let gw = Gateway::spawn(GatewayConfig::new(stream_cfg(seed)));
    let a = gw
        .submit(prefix.clone(), seg(10))
        .expect("admitted")
        .recv()
        .unwrap()
        .expect("served");
    assert_bits(&a.logits, &ref_prefix, "cold prefix");
    let b = gw
        .submit(full.clone(), seg(14))
        .expect("admitted")
        .recv()
        .unwrap()
        .expect("served");
    assert_bits(&b.logits, &ref_full, "extend cached prefix");
    let c = gw
        .submit(full, seg(14))
        .expect("admitted")
        .recv()
        .unwrap()
        .expect("served");
    assert_bits(&c.logits, &ref_full, "exact repeat hit");
    let stats = gw.shutdown();
    assert_eq!(
        (stats.cache_hits, stats.cache_misses),
        (2, 1),
        "prefix extension and exact repeat must both hit"
    );
}

#[test]
fn m_prefix_readout_matches_fresh_m_forward() {
    // the contract the degradation ladder rides: a session absorbed at
    // m = 8 rounds and read at any m' <= m — including a non-divisor
    // m' = 3 — is bit-identical to a fresh m'-round forward from the
    // same seed, because hashers draw hash-major so the m'-hasher is a
    // literal prefix of the m-hasher. Checked for the plain readout and
    // the tail-overlay readout, across shapes × tau × hashers × kernels.
    let tail = 5usize;
    for &(n, d) in &[(12usize, 16usize), (33, 32)] {
        for &tau in &[4usize, 6] {
            for fast in [false, true] {
                for kernel in [KernelVariant::Seed, KernelVariant::Fused] {
                    let att =
                        YosoAttention::new(tau, 8, fast).with_kernel(kernel);
                    let (q, k, v) = qkv(n, d, 3 + n as u64 + tau as u64 * 7);
                    let mut full = YosoStream::new(&att, d, d, &mut Rng::new(41));
                    full.append(&k, &v);
                    let real = n - tail;
                    let mut part = YosoStream::new(&att, d, d, &mut Rng::new(41));
                    part.append(&slice_rows(&k, 0, real), &slice_rows(&v, 0, real));
                    for m_read in [1usize, 2, 3, 8] {
                        let small = YosoAttention::new(tau, m_read, fast)
                            .with_kernel(kernel);
                        let expected =
                            small.forward(&q, &k, &v, &mut Rng::new(41));
                        let ctx = format!(
                            "n={n} d={d} tau={tau} fast={fast} kernel={} \
                             m_read={m_read}",
                            kernel.label()
                        );
                        let mut out = Mat::zeros(n, d);
                        full.finish_into(&q, m_read, &mut out);
                        assert_bits(&out.data, &expected.data, &ctx);
                        part.finish_with_tail_into(
                            &q,
                            &slice_rows(&k, real, n),
                            &slice_rows(&v, real, n),
                            m_read,
                            &mut out,
                        );
                        assert_bits(
                            &out.data,
                            &expected.data,
                            &format!("{ctx} (tail overlay)"),
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn gateway_degraded_quality_matches_a_fresh_lower_m_gateway() {
    let seed = 29u64;
    let ids: Vec<i32> = (0..12).map(|i| 7 + i).collect();
    let seg = vec![0i32; 12];

    // reference bytes: a server configured at m' = 4 outright (same
    // tau — `yoso_4` and `yoso_8` both fix tau = 8)
    let mut ref_cfg = stream_cfg(seed);
    ref_cfg.attention = "yoso_4".into();
    let handle = ServerHandle::spawn_cpu(
        ref_cfg,
        BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
    );
    let reference =
        handle.submit(ids.clone(), seg.clone()).recv().unwrap().logits;
    handle.shutdown().expect("reference stats");

    // the gateway runs at m_full = 8; a request pinned to Degraded(4)
    // must return the m' = 4 bytes exactly — first through the prefix
    // cache's m'-prefix readout, then with the cache disabled so the
    // degraded batch fallback (a cloned m'-attention) is exercised
    for cache_bytes in [64usize << 20, 0] {
        let mut cfg = GatewayConfig::new(stream_cfg(seed));
        cfg.prefix_cache_bytes = cache_bytes;
        let gw = Gateway::spawn(cfg);
        let got = gw
            .submitter()
            .submit_with(ids.clone(), seg.clone(), None, Quality::Degraded(4))
            .expect("admitted")
            .recv()
            .unwrap()
            .expect("served");
        assert_bits(
            &got.logits,
            &reference,
            &format!("cache_bytes={cache_bytes}"),
        );
        let stats = gw.shutdown();
        assert_eq!(
            (stats.served_degraded, stats.served_full),
            (1, 0),
            "cache_bytes={cache_bytes}"
        );
    }
}
