//! The gateway scheduling contract, proven on the deterministic
//! discrete-event simulator (`serve::sim`) — the same scheduling core
//! the live replicas run, driven on a virtual clock with **zero
//! wall-clock sleeps**:
//!
//! * **work conservation** — under `SchedPolicy::Conserve`, no replica
//!   ever idles (or parks on a partial-batch aging wait) while any
//!   bucket holds live work, on randomized adversarial traces;
//! * the audit has **teeth** — the PR-3 `Fifo` baseline demonstrably
//!   violates it on a skewed-bucket trace (an idle replica parked on a
//!   sparse foreign bucket), and pays for it in mean latency;
//! * **deadline-earliest-first** dequeue within a bucket — exact batch
//!   compositions, in order, on a scripted trace;
//! * **exact shed accounting** — `accepted == completed + shed_deadline`
//!   and `offered == accepted + rejected`, with hand-computed counts on
//!   scripted deadline/capacity traces and as an invariant on random
//!   traces under both policies;
//! * **the degradation ladder earns its keep** — on a hand-computed
//!   overload trace, a ladder-enabled run serves strictly more
//!   within-deadline requests (goodput) than the shed-only baseline,
//!   with exact per-batch m' and completion-tick assertions.
//!
//! The other half of the contract — logits bit-identical to the
//! single-loop path under every `SchedPolicy` x bucket layout x arrival
//! shuffle — runs against the *real* gateway in
//! `tests/prop_serve_gateway.rs`. Scheduling decisions are independent
//! of `YOSO_TEST_THREADS` and `YOSO_KERNEL` by construction (the sim
//! spawns no threads and builds no attention); CI's scheduler-stress
//! sweep runs this suite across both to enforce exactly that.

use std::time::Duration;
use yoso::serve::sim::{run, run_classed, Arrival, ServiceModel, SimConfig};
use yoso::serve::{
    BatchPolicy, BatchPolicyTable, BucketLayout, DegradeLadder, Quality,
    SchedPolicy, Sharding,
};
use yoso::util::Rng;

fn ms(v: u64) -> Duration {
    Duration::from_millis(v)
}

fn us(v: u64) -> Duration {
    Duration::from_micros(v)
}

#[test]
fn conserve_is_work_conserving_on_random_adversarial_traces() {
    // proptest-style loop: random replica counts, capacities, batch
    // policies, service models, and arrival traces (bursts, skewed
    // lengths, scattered deadlines). Under Conserve the simulator's
    // audit must record zero idle-while-backlogged ticks, and the
    // accounting identities must hold exactly. Fifo runs the same
    // traces for the accounting half (its conservation violations are
    // expected — that is the A/B point).
    let mut rng = Rng::new(0x51A7);
    for case in 0..60u64 {
        let n = 20 + rng.below(60);
        let trace: Vec<Arrival> = (0..n)
            .map(|_| Arrival {
                at: us(rng.below(150_000) as u64),
                len: 1 + rng.below(64),
                deadline: (rng.below(4) == 0)
                    .then(|| ms(1 + rng.below(40) as u64)),
            })
            .collect();
        let base = BatchPolicy {
            max_batch: 1 + rng.below(7),
            max_wait: ms(1 + rng.below(20) as u64),
        };
        let mut cfg = SimConfig {
            replicas: 1 + rng.below(3),
            queue_capacity: 4 + rng.below(60),
            sched: SchedPolicy::Conserve,
            buckets: BucketLayout::pow2(8, 64),
            batch: if rng.below(2) == 0 {
                BatchPolicyTable::uniform(base)
            } else {
                BatchPolicyTable::scaled(base)
            },
            service: ServiceModel {
                batch_overhead: us(200 + rng.below(2000) as u64),
                per_width: us(1 + rng.below(50) as u64),
            },
            degrade: DegradeLadder::none(),
            m_full: 16,
            ..SimConfig::default()
        };
        let report = run(&cfg, &trace);
        assert!(
            report.conservation_violations.is_empty(),
            "case {case}: replica idled while a bucket held work at ticks \
             {:?}",
            report.conservation_violations
        );
        assert_eq!(report.accepted + report.rejected, n as u64, "case {case}");
        assert!(
            report.reconciles(),
            "case {case}: accepted {} != completed {} + shed {}",
            report.accepted,
            report.completed,
            report.shed_deadline
        );
        assert_eq!(
            report.latencies_ms.len() as u64,
            report.completed,
            "case {case}"
        );
        // batches partition the completed set: every seq exactly once
        let mut seqs: Vec<u64> =
            report.batches.iter().flat_map(|b| b.seqs.clone()).collect();
        let total = seqs.len();
        assert_eq!(total as u64, report.completed, "case {case}");
        seqs.sort_unstable();
        seqs.dedup();
        assert_eq!(seqs.len(), total, "case {case}: a request ran twice");
        // every batch stays within its bucket's policy bound
        let widest = *cfg.buckets.widths().last().unwrap();
        for b in &report.batches {
            let cap = cfg.batch.policy_for(b.width, widest).max_batch;
            assert!(
                b.seqs.len() <= cap,
                "case {case}: batch of {} in a width-{} bucket capped at {cap}",
                b.seqs.len(),
                b.width
            );
        }
        // same trace under Fifo: accounting still exact (conservation
        // violations are allowed — Fifo is the baseline that has them)
        cfg.sched = SchedPolicy::Fifo;
        let fifo = run(&cfg, &trace);
        assert!(fifo.reconciles(), "case {case} (fifo)");
        assert_eq!(fifo.accepted + fifo.rejected, n as u64, "case {case}");
    }
}

#[test]
fn fifo_parks_on_foreign_buckets_and_conserve_does_not() {
    // the skewed-bucket scenario the tentpole exists for: one sparse
    // wide request plus a deep narrow bucket, single replica. Fifo
    // picks the wide head (oldest seq), parks its 1-of-4 batch on the
    // 50 ms aging wait while six narrow requests sit queued — the audit
    // must catch it. Conserve drains the deep bucket first and never
    // idles against backlog.
    let mut trace = vec![Arrival { at: ms(0), len: 40, deadline: None }];
    for _ in 0..6 {
        trace.push(Arrival { at: ms(0), len: 4, deadline: None });
    }
    let mk = |sched| SimConfig {
        replicas: 1,
        queue_capacity: 64,
        sched,
        buckets: BucketLayout::pow2(8, 64),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 4,
            max_wait: ms(50),
        }),
        service: ServiceModel { batch_overhead: ms(1), per_width: us(10) },
        degrade: DegradeLadder::none(),
        m_full: 16,
        ..SimConfig::default()
    };
    let fifo = run(&mk(SchedPolicy::Fifo), &trace);
    let conserve = run(&mk(SchedPolicy::Conserve), &trace);

    assert!(
        !fifo.conservation_violations.is_empty(),
        "the audit lost its teeth: FIFO no longer parks on foreign buckets"
    );
    assert!(conserve.conservation_violations.is_empty());
    assert_eq!(fifo.completed, 7);
    assert_eq!(conserve.completed, 7);
    assert!(fifo.reconciles() && conserve.reconciles());
    // and the parking shows up where it hurts: every narrow request
    // waited out the wide bucket's aging under FIFO
    assert!(
        conserve.mean_ms() < fifo.mean_ms(),
        "work conservation did not improve mean latency: conserve {:.2} ms \
         vs fifo {:.2} ms",
        conserve.mean_ms(),
        fifo.mean_ms()
    );
    assert!(
        conserve.p99_ms() <= fifo.p99_ms(),
        "conserve p99 {:.2} ms regressed past fifo p99 {:.2} ms",
        conserve.p99_ms(),
        fifo.p99_ms()
    );
}

#[test]
fn dequeue_within_bucket_is_deadline_earliest_first() {
    // single bucket, single replica. seq0 ships alone at t=0 and holds
    // the replica busy for ~20 ms; five same-bucket requests arrive at
    // t=1..5 with shuffled deadlines. When the replica frees, Conserve
    // must dequeue strictly by (deadline, seq): batch [3, 5, 4] (100,
    // 200, 300 ms), then [2, 1] (500 ms, none). Fifo on the identical
    // trace dequeues by arrival: [1, 2, 3], then [4, 5].
    let deadlines: [Option<Duration>; 5] =
        [None, Some(ms(500)), Some(ms(100)), Some(ms(300)), Some(ms(200))];
    let mut trace = vec![Arrival { at: ms(0), len: 8, deadline: None }];
    for (i, d) in deadlines.into_iter().enumerate() {
        trace.push(Arrival { at: ms(1 + i as u64), len: 8, deadline: d });
    }
    let mk = |sched| SimConfig {
        replicas: 1,
        queue_capacity: 64,
        sched,
        buckets: BucketLayout::single(8),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::ZERO,
        }),
        service: ServiceModel { batch_overhead: ms(20), per_width: us(10) },
        degrade: DegradeLadder::none(),
        m_full: 16,
        ..SimConfig::default()
    };
    let edf = run(&mk(SchedPolicy::Conserve), &trace);
    assert_eq!(edf.completed, 6);
    assert!(edf.reconciles());
    let orders: Vec<&[u64]> =
        edf.batches.iter().map(|b| b.seqs.as_slice()).collect();
    assert_eq!(
        orders,
        vec![&[0][..], &[3, 5, 4][..], &[2, 1][..]],
        "Conserve must dequeue by (deadline, seq) within the bucket"
    );

    let fifo = run(&mk(SchedPolicy::Fifo), &trace);
    let orders: Vec<&[u64]> =
        fifo.batches.iter().map(|b| b.seqs.as_slice()).collect();
    assert_eq!(
        orders,
        vec![&[0][..], &[1, 2, 3][..], &[4, 5][..]],
        "Fifo must dequeue in arrival order within the bucket"
    );
}

#[test]
fn shed_accounting_is_exact_on_scripted_deadline_traces() {
    // hand-computed outcome, nanosecond-deterministic: seq0 occupies
    // the only replica for ~30 ms; seq1 (deadline 10 ms) and seq2
    // (deadline 5 ms) expire in-queue before it frees; seq3 has no
    // deadline and executes. Exactly 2 deadline sheds, 2 completions.
    let trace = vec![
        Arrival { at: ms(0), len: 8, deadline: None },
        Arrival { at: ms(1), len: 8, deadline: Some(ms(10)) },
        Arrival { at: ms(2), len: 8, deadline: Some(ms(5)) },
        Arrival { at: ms(3), len: 8, deadline: None },
    ];
    let mut cfg = SimConfig {
        replicas: 1,
        queue_capacity: 64,
        sched: SchedPolicy::Conserve,
        buckets: BucketLayout::single(8),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
        }),
        service: ServiceModel { batch_overhead: ms(30), per_width: us(10) },
        degrade: DegradeLadder::none(),
        m_full: 16,
        ..SimConfig::default()
    };
    let report = run(&cfg, &trace);
    assert_eq!(report.accepted, 4);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.shed_deadline, 2);
    assert_eq!(report.completed, 2);
    assert!(report.reconciles());
    let orders: Vec<&[u64]> =
        report.batches.iter().map(|b| b.seqs.as_slice()).collect();
    assert_eq!(orders, vec![&[0][..], &[3][..]]);

    // same trace against a capacity-2 queue: seq3 now rejects at
    // admission instead, and both queued deadlines still expire
    cfg.queue_capacity = 2;
    let report = run(&cfg, &trace);
    assert_eq!(report.accepted, 3);
    assert_eq!(report.rejected, 1);
    assert_eq!(report.shed_deadline, 2);
    assert_eq!(report.completed, 1);
    assert!(report.reconciles());
    assert_eq!(report.batches.len(), 1);
}

#[test]
fn per_bucket_policies_shape_batches_in_the_sim() {
    // scaled table on a [8, 64] layout with base max_batch 2: the
    // narrow bucket's cap scales up (2 -> 16 at 3 halvings... capped at
    // 8x = 16), the wide bucket keeps 2. Eight narrow + three wide
    // requests at t=0, one replica: the narrow bucket drains in ONE
    // wide batch, the wide bucket needs two base-cap batches.
    let mut trace = Vec::new();
    for _ in 0..8 {
        trace.push(Arrival { at: ms(0), len: 4, deadline: None });
    }
    for _ in 0..3 {
        trace.push(Arrival { at: ms(0), len: 64, deadline: None });
    }
    let cfg = SimConfig {
        replicas: 1,
        queue_capacity: 64,
        sched: SchedPolicy::Conserve,
        buckets: BucketLayout::pow2(8, 64),
        batch: BatchPolicyTable::scaled(BatchPolicy {
            max_batch: 2,
            max_wait: ms(8),
        }),
        service: ServiceModel { batch_overhead: ms(1), per_width: us(10) },
        degrade: DegradeLadder::none(),
        m_full: 16,
        ..SimConfig::default()
    };
    let report = run(&cfg, &trace);
    assert_eq!(report.completed, 11);
    assert!(report.conservation_violations.is_empty());
    let narrow: Vec<usize> = report
        .batches
        .iter()
        .filter(|b| b.width == 8)
        .map(|b| b.seqs.len())
        .collect();
    let wide: Vec<usize> = report
        .batches
        .iter()
        .filter(|b| b.width == 64)
        .map(|b| b.seqs.len())
        .collect();
    assert_eq!(narrow, vec![8], "narrow bucket must drain in one batch");
    assert_eq!(wide, vec![2, 1], "wide bucket keeps the base cap of 2");
}

#[test]
fn degradation_ladder_beats_shed_only_on_an_overload_burst() {
    // The tentpole's existence proof, hand-computed on the virtual
    // clock. One replica, width-8 bucket, one request per batch, 4 ms
    // full-quality service (m=8), no batch overhead. A warm-up request
    // at t=0 (no deadline) calibrates the EWMA to exactly 4 ms; six
    // requests land at t=4, each with a 12 ms deadline (absolute 16 ms).
    //
    // Shed-only: requests serve at 4 ms each — seq1..3 complete at 8,
    // 12, 16 ms (all within deadline, 16 exactly on it), and seq4..6
    // expire in-queue at t=16. Goodput 4, three users shed.
    //
    // Ladder (step to m'=2 at >=10 ms of backlog): the rung is picked
    // off the post-pop backlog, so seq1..3 see 20/16/12 ms of pressure
    // and serve at m'=2 (1 ms each, done at 5/6/7 ms); the backlog the
    // controller measures then falls to 8 ms, below the rung, and
    // seq4..6 serve at full quality (done 11/15/19 ms). Only seq6
    // misses its deadline — and it still completes rather than
    // shedding. Goodput 6 > 4: the ladder turned two would-be sheds
    // into on-time (cheaper) answers and a third into a late answer.
    let mk = |degrade: DegradeLadder| SimConfig {
        replicas: 1,
        queue_capacity: 64,
        sched: SchedPolicy::Conserve,
        buckets: BucketLayout::single(8),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
        }),
        service: ServiceModel {
            batch_overhead: Duration::ZERO,
            per_width: us(500), // 8 x 500 us = 4 ms per request at full m
        },
        degrade,
        m_full: 8,
        ..SimConfig::default()
    };
    let mut trace = vec![Arrival { at: ms(0), len: 8, deadline: None }];
    for _ in 0..6 {
        trace.push(Arrival { at: ms(4), len: 8, deadline: Some(ms(12)) });
    }

    let shed_only = run(&mk(DegradeLadder::none()), &trace);
    assert_eq!(shed_only.accepted, 7);
    assert_eq!(shed_only.completed, 4);
    assert_eq!(shed_only.shed_deadline, 3);
    assert_eq!(shed_only.goodput, 4);
    assert_eq!(shed_only.served_degraded, 0);
    assert!(shed_only.reconciles());
    assert!(shed_only.batches.iter().all(|b| b.m_eff == 8));
    let done: Vec<f64> = shed_only
        .batches
        .iter()
        .map(|b| b.done_at.ms_since(yoso::serve::Tick::ZERO))
        .collect();
    assert_eq!(done, vec![4.0, 8.0, 12.0, 16.0]);

    let ladder = run(&mk(DegradeLadder::steps(vec![(10, 2)])), &trace);
    assert_eq!(ladder.accepted, 7);
    assert_eq!(ladder.completed, 7, "nothing sheds under the ladder");
    assert_eq!(ladder.shed_deadline, 0);
    assert_eq!(ladder.goodput, 6);
    assert_eq!(ladder.served_degraded, 3);
    assert!(ladder.reconciles());
    let m_effs: Vec<usize> =
        ladder.batches.iter().map(|b| b.m_eff).collect();
    assert_eq!(
        m_effs,
        vec![8, 2, 2, 2, 8, 8, 8],
        "rungs engage while backlog >= 10 ms and release as it drains"
    );
    let done: Vec<f64> = ladder
        .batches
        .iter()
        .map(|b| b.done_at.ms_since(yoso::serve::Tick::ZERO))
        .collect();
    assert_eq!(done, vec![4.0, 5.0, 6.0, 7.0, 11.0, 15.0, 19.0]);

    // the headline inequality the bench smoke-gates at scale
    assert!(
        ladder.goodput > shed_only.goodput,
        "degradation must serve strictly more within-deadline requests \
         than shedding: {} vs {}",
        ladder.goodput,
        shed_only.goodput
    );
    assert!(ladder.conservation_violations.is_empty());
    assert!(shed_only.conservation_violations.is_empty());
}

#[test]
fn step_up_hysteresis_damps_rung_flapping_on_an_oscillating_trace() {
    // An oscillating load: three bursts of four requests, each burst
    // fully drained before the next lands. Same cost model as the
    // overload test (4 ms full service at m_full=8, 1 ms at m'=2,
    // EWMA pinned at 4 ms by the full-quality restatement), rung at
    // 10 ms of backlog. Within a burst the post-pop backlog runs
    // 12/8/4/0 ms — so a zero-lag ladder steps down for exactly the
    // first batch of every burst and right back up for the second:
    // two rung transitions per burst, the flapping the hysteresis
    // exists to damp.
    //
    // With a step-up lag longer than the run, the first step-down
    // holds: every later batch serves at the held rung (the raw
    // target never stays above it long enough), and the whole trace
    // has exactly one transition. Hysteresis trades those five extra
    // full-quality batches for rung stability — completions and
    // accounting are untouched.
    let mk = |degrade: DegradeLadder| SimConfig {
        replicas: 1,
        queue_capacity: 64,
        sched: SchedPolicy::Conserve,
        buckets: BucketLayout::single(8),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
        }),
        service: ServiceModel {
            batch_overhead: Duration::ZERO,
            per_width: us(500),
        },
        degrade,
        m_full: 8,
        ..SimConfig::default()
    };
    // warm-up calibrates the EWMA; bursts at 4/20/36 ms (the slowest
    // arm drains a burst by +13 ms, so the replica is idle again and
    // the backlog is back to zero before every burst)
    let mut trace = vec![Arrival { at: ms(0), len: 8, deadline: None }];
    for burst in 0..3u64 {
        for _ in 0..4 {
            trace.push(Arrival {
                at: ms(4 + 16 * burst),
                len: 8,
                deadline: None,
            });
        }
    }
    let transitions = |report: &yoso::serve::sim::SimReport| {
        report
            .batches
            .windows(2)
            .filter(|w| w[0].m_eff != w[1].m_eff)
            .count()
    };

    let flappy = run(&mk(DegradeLadder::steps(vec![(10, 2)])), &trace);
    assert_eq!(flappy.completed, 13);
    assert!(flappy.reconciles());
    let m_effs: Vec<usize> = flappy.batches.iter().map(|b| b.m_eff).collect();
    assert_eq!(
        m_effs,
        vec![8, 2, 8, 8, 8, 2, 8, 8, 8, 2, 8, 8, 8],
        "zero lag must flap once per burst (the baseline this test damps)"
    );
    assert_eq!(transitions(&flappy), 6);
    assert_eq!(flappy.served_degraded, 3);

    let damped = run(
        &mk(DegradeLadder::steps(vec![(10, 2)]).with_step_up_lag(ms(1000))),
        &trace,
    );
    assert_eq!(damped.completed, 13, "hysteresis must not change accounting");
    assert!(damped.reconciles());
    let m_effs: Vec<usize> = damped.batches.iter().map(|b| b.m_eff).collect();
    assert_eq!(
        m_effs,
        vec![8, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2, 2],
        "a held rung serves every batch until the lag elapses"
    );
    assert_eq!(transitions(&damped), 1);
    assert_eq!(damped.served_degraded, 12);
    assert!(
        transitions(&damped) < transitions(&flappy),
        "step-up lag must strictly reduce rung transitions"
    );
    assert!(flappy.conservation_violations.is_empty());
    assert!(damped.conservation_violations.is_empty());
}

#[test]
fn best_effort_reserve_admits_exact_per_class_counts() {
    // capacity 4 with reserve 0.5: guaranteed (Full) traffic admits
    // only while the queue is under 4 - round(4 * 0.5) = 2, best-effort
    // into the full 4. A slow replica (100 ms batches, singleton
    // batches) keeps the queue static across the burst, so every
    // admit/reject below is hand-computable.
    let cfg = SimConfig {
        replicas: 1,
        queue_capacity: 4,
        sched: SchedPolicy::Conserve,
        buckets: BucketLayout::single(8),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
        }),
        service: ServiceModel {
            batch_overhead: ms(100),
            per_width: us(1),
        },
        degrade: DegradeLadder::none(),
        m_full: 8,
        ..SimConfig::default()
    };
    // t=0: one Full request, immediately picked up (queue drops back to
    // empty). t=1ms, in trace order against the now-busy replica:
    //   F1 (q=0 < 2, admit) F2 (q=1 < 2, admit) F3, F4 (q=2 -> reject)
    //   B1 (q=2 < 4, admit) B2 (q=3 < 4, admit) B3 (q=4 -> reject)
    let mut trace = vec![Arrival { at: ms(0), len: 8, deadline: None }];
    trace.extend((0..7).map(|_| Arrival {
        at: ms(1),
        len: 8,
        deadline: None,
    }));
    let classes = [
        Quality::Full,
        Quality::Full,
        Quality::Full,
        Quality::Full,
        Quality::Full,
        Quality::BestEffort,
        Quality::BestEffort,
        Quality::BestEffort,
    ];
    let report = run_classed(&cfg, &trace, &classes, 0.5);
    assert_eq!(report.accepted, 5);
    assert_eq!(report.rejected, 3);
    assert_eq!(report.accepted_best_effort, 2);
    assert_eq!(report.rejected_best_effort, 1);
    assert_eq!(report.completed, 5, "everything admitted is served");
    assert!(report.reconciles());

    // reserve 0 is the pre-quota behavior: one shared cap, first come
    // first served — the three late arrivals shed regardless of class
    let flat = run_classed(&cfg, &trace, &classes, 0.0);
    assert_eq!(flat.accepted, 5);
    assert_eq!(flat.rejected, 3);
    assert_eq!(flat.accepted_best_effort, 0, "Full filled the queue first");
    assert_eq!(flat.rejected_best_effort, 3);
    assert!(flat.reconciles());
}

#[test]
fn sharded_lanes_schedule_bit_identically_on_adversarial_traces() {
    // the tentpole's license: the per-bucket-locked lane layout the
    // live gateway runs must reproduce the single-lock schedule bit
    // for bit. Same 60 randomized adversarial traces as the
    // conservation property (same seed, same generation), both
    // schedulers, whole-report equality — batch compositions, ticks,
    // latencies, and every counter.
    let mut rng = Rng::new(0x51A7);
    for case in 0..60u64 {
        let n = 20 + rng.below(60);
        let trace: Vec<Arrival> = (0..n)
            .map(|_| Arrival {
                at: us(rng.below(150_000) as u64),
                len: 1 + rng.below(64),
                deadline: (rng.below(4) == 0)
                    .then(|| ms(1 + rng.below(40) as u64)),
            })
            .collect();
        let base = BatchPolicy {
            max_batch: 1 + rng.below(7),
            max_wait: ms(1 + rng.below(20) as u64),
        };
        let mut cfg = SimConfig {
            replicas: 1 + rng.below(3),
            queue_capacity: 4 + rng.below(60),
            sched: SchedPolicy::Conserve,
            buckets: BucketLayout::pow2(8, 64),
            batch: if rng.below(2) == 0 {
                BatchPolicyTable::uniform(base)
            } else {
                BatchPolicyTable::scaled(base)
            },
            service: ServiceModel {
                batch_overhead: us(200 + rng.below(2000) as u64),
                per_width: us(1 + rng.below(50) as u64),
            },
            degrade: DegradeLadder::none(),
            m_full: 16,
            ..SimConfig::default()
        };
        for sched in [SchedPolicy::Conserve, SchedPolicy::Fifo] {
            cfg.sched = sched;
            cfg.shards = Sharding::Unsharded;
            let unsharded = run(&cfg, &trace);
            cfg.shards = Sharding::PerBucket;
            let sharded = run(&cfg, &trace);
            assert_eq!(
                unsharded, sharded,
                "case {case} ({sched:?}): sharding changed the schedule"
            );
        }
    }
}

#[test]
fn stealing_lifts_goodput_on_a_skewed_trace() {
    // the skewed shape stealing exists for: two deadline-bearing wide
    // requests park as a Fifo partial on replica 0 while replica 1
    // drains eight narrow requests and goes idle with nothing queued.
    // Without stealing the wide pair ages the full 50 ms park and
    // expires at dispatch; with stealing the idle peer splits the
    // parked pair the moment it drains, both halves ship immediately,
    // and every request completes within deadline.
    let mut trace = vec![
        Arrival { at: ms(0), len: 40, deadline: Some(ms(20)) },
        Arrival { at: ms(0), len: 40, deadline: Some(ms(20)) },
    ];
    for _ in 0..8 {
        trace.push(Arrival { at: ms(0), len: 4, deadline: None });
    }
    let mk = |steal: bool| SimConfig {
        replicas: 2,
        queue_capacity: 64,
        sched: SchedPolicy::Fifo,
        buckets: BucketLayout::pow2(8, 64),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 4,
            max_wait: ms(50),
        }),
        service: ServiceModel { batch_overhead: ms(1), per_width: us(10) },
        degrade: DegradeLadder::none(),
        m_full: 16,
        steal,
        ..SimConfig::default()
    };

    let parked = run(&mk(false), &trace);
    assert_eq!(parked.stolen, 0);
    assert_eq!(parked.shed_deadline, 2, "the parked wide pair must expire");
    assert_eq!(parked.completed, 8);
    assert_eq!(parked.goodput, 8);
    assert!(parked.reconciles());

    let stolen = run(&mk(true), &trace);
    assert_eq!(stolen.stolen, 1);
    assert_eq!(stolen.shed_deadline, 0);
    assert_eq!(stolen.completed, 10, "stealing must rescue the wide pair");
    assert_eq!(stolen.goodput, 10);
    assert!(stolen.reconciles());
    assert!(
        stolen.goodput > parked.goodput,
        "stealing must lift goodput on the skewed trace: {} vs {}",
        stolen.goodput,
        parked.goodput
    );
    // and the accounting identity holds under stealing with requests
    // crossing replicas mid-flight
    assert_eq!(
        stolen.accepted,
        stolen.completed + stolen.shed_deadline + stolen.failed_internal
    );
}
