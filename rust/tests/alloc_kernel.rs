//! Zero-allocation steady state for the fused kernel, asserted with the
//! counting global allocator (`bench_support::CountingAlloc` + the
//! `alloc_count` hook): after the arena and output buffer are warm, a
//! fused forward must perform **zero** heap allocations — the tentpole's
//! "steady-state serving does zero heap allocation per request" claim,
//! checked at the kernel layer where it is exact. The seed kernel's
//! per-forward allocation count is measured alongside (it must be > 0;
//! the delta is the A/B story EXPERIMENTS.md §Perf tells).
//!
//! The window runs twice: once with the flight recorder's kernel phase
//! probes hard-disabled (the baseline claim, immune to a stray
//! `YOSO_TRACE` in the environment) and once with them enabled — a warm
//! traced forward must *also* allocate zero (phase timers write to
//! preallocated atomics and a fixed-capacity span ring), or the
//! "tracing is cheap enough to leave on" story is false at the exact
//! layer it matters.
//!
//! Single #[test]: the allocation counter is process-global, and a
//! concurrent test thread's allocations would pollute the window.

use yoso::attention::{KernelArena, KernelVariant, YosoAttention};
use yoso::bench_support::{alloc_count, CountingAlloc};
use yoso::tensor::Mat;
use yoso::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn fused_steady_state_allocates_zero() {
    // pin the probe gate off regardless of the environment: the
    // baseline window measures the kernel alone
    yoso::obs::set_trace_enabled(false);
    let mut gen = Rng::new(1);
    let n = 96;
    let d = 32;
    let q = Mat::randn(n, d, 1.0, &mut gen).unit_rows();
    let k = Mat::randn(n, d, 1.0, &mut gen).unit_rows();
    let v = Mat::randn(n, d, 1.0, &mut gen);

    for fast in [false, true] {
        let att = YosoAttention::new(6, 8, fast).with_kernel(KernelVariant::Fused);
        let mut arena = KernelArena::new();
        let mut out = Mat::zeros(n, d);
        let mut rng = Rng::new(7);
        // warm-up: first pass allocates the arena to this geometry
        for _ in 0..2 {
            att.forward_fused_into(&q, &k, &v, &mut rng, &mut arena, &mut out);
        }
        let before = alloc_count();
        for _ in 0..5 {
            att.forward_fused_into(&q, &k, &v, &mut rng, &mut arena, &mut out);
        }
        let fused_allocs = alloc_count() - before;
        assert_eq!(
            fused_allocs, 0,
            "fused kernel allocated in steady state (fast={fast})"
        );
    }

    // the same window with the kernel phase probes live: the first
    // traced pass warms the one-time span-ring storage, after which a
    // profiled forward must still allocate nothing
    yoso::obs::set_trace_enabled(true);
    yoso::obs::reset_kernel_profile();
    {
        let att =
            YosoAttention::new(6, 8, true).with_kernel(KernelVariant::Fused);
        let mut arena = KernelArena::new();
        let mut out = Mat::zeros(n, d);
        let mut rng = Rng::new(7);
        for _ in 0..2 {
            att.forward_fused_into(&q, &k, &v, &mut rng, &mut arena, &mut out);
        }
        let before = alloc_count();
        for _ in 0..5 {
            att.forward_fused_into(&q, &k, &v, &mut rng, &mut arena, &mut out);
        }
        let traced_allocs = alloc_count() - before;
        assert_eq!(
            traced_allocs, 0,
            "fused kernel allocated in steady state with tracing enabled"
        );
    }
    yoso::obs::set_trace_enabled(false);
    // and the probes genuinely fired — the zero-alloc claim above is
    // about *live* instrumentation, not a silently-closed gate
    let snap = yoso::obs::kernel_snapshot();
    assert!(
        !snap.is_empty(),
        "trace-enabled window recorded no kernel phases"
    );
    assert!(
        !snap.spans.is_empty(),
        "trace-enabled window recorded no phase spans"
    );
    yoso::obs::reset_kernel_profile();

    // the seed kernel allocates every forward (codes, table, unit rows,
    // hasher, output) — the baseline the arena removes
    let seed_att = YosoAttention::new(6, 8, false).with_kernel(KernelVariant::Seed);
    let mut rng = Rng::new(7);
    let _ = seed_att.forward_raw(&q, &k, &v, &mut rng); // warm allocator caches
    let before = alloc_count();
    let iters = 5;
    for _ in 0..iters {
        std::hint::black_box(seed_att.forward_raw(&q, &k, &v, &mut rng));
    }
    let seed_allocs = alloc_count() - before;
    assert!(
        seed_allocs >= iters * 5,
        "seed kernel should allocate per forward (got {seed_allocs} over {iters})"
    );
    println!("seed kernel: {} allocs/forward; fused kernel: 0", seed_allocs / iters);
}
