//! Zero-allocation steady state for streamed YOSO sessions: after one
//! warm pass has grown every scratch buffer, per-token `append`s, full
//! `finish_into` gathers, and PAD-tail overlays must perform **zero**
//! heap allocations — the "appending a token is an O(m·dv) accumulator
//! update, not a rebuild" claim, checked where it is exact. A table
//! rebuild, hasher redraw, or per-chunk buffer would show up here as a
//! nonzero count.
//!
//! Single #[test]: the allocation counter is process-global, and a
//! concurrent test thread's allocations would pollute the window.

use yoso::attention::{YosoAttention, YosoStream};
use yoso::bench_support::{alloc_count, CountingAlloc};
use yoso::tensor::Mat;
use yoso::util::Rng;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn warm_stream_appends_and_gathers_allocate_zero() {
    // the streamed path carries no phase probes, but a stray
    // `YOSO_TRACE=1` in the environment must not be able to change what
    // this window measures — pin the gate off
    yoso::obs::set_trace_enabled(false);
    let d = 32;
    let n = 12;
    for fast in [false, true] {
        let att = YosoAttention::new(5, 4, fast);
        let mut gen = Rng::new(3);
        let k = Mat::randn(n, d, 1.0, &mut gen).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut gen);
        let q = Mat::randn(6, d, 1.0, &mut gen).unit_rows();
        let tail_k = Mat::randn(4, d, 1.0, &mut gen).unit_rows();
        let tail_v = Mat::randn(4, d, 1.0, &mut gen);
        // pre-split the session into single-token chunks so the
        // measured loop performs only appends, no Mat construction
        let chunks: Vec<(Mat, Mat)> = (0..n)
            .map(|i| {
                (
                    Mat::from_fn(1, d, |_, j| k.at(i, j)),
                    Mat::from_fn(1, d, |_, j| v.at(i, j)),
                )
            })
            .collect();

        let mut s = YosoStream::new(&att, d, d, &mut Rng::new(9));
        let mut out = Mat::zeros(q.rows, d);
        // warm-up: one full pass grows all scratch to steady size
        for (kc, vc) in &chunks {
            s.append(kc, vc);
        }
        s.finish_into(&q, s.m(), &mut out);
        s.finish_with_tail_into(&q, &tail_k, &tail_v, s.m(), &mut out);

        let before = alloc_count();
        for (kc, vc) in &chunks {
            s.append(kc, vc);
        }
        s.finish_into(&q, s.m(), &mut out);
        s.finish_with_tail_into(&q, &tail_k, &tail_v, s.m(), &mut out);
        // degraded m'-prefix readouts ride the same warm scratch: a
        // quality step-down must never cost an allocation
        s.finish_into(&q, 2, &mut out);
        s.finish_with_tail_into(&q, &tail_k, &tail_v, 2, &mut out);
        let allocs = alloc_count() - before;
        assert_eq!(
            allocs, 0,
            "warm streamed session allocated in steady state (fast={fast})"
        );
        assert_eq!(s.n_keys(), 2 * n, "both passes' tokens are in session");
    }
}
