//! End-to-end serving integration: spawn the server thread against the
//! forward artifact, drive concurrent clients, check every request is
//! answered with well-formed logits and the batcher actually batches.

use std::path::{Path, PathBuf};
use std::time::Duration;
use yoso::data::glue_synth::{GlueGenerator, GlueTask};
use yoso::serve::{BatchPolicy, ServerHandle};

fn artifacts_present() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

#[test]
fn serve_roundtrip_with_dynamic_batching() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let handle = ServerHandle::spawn(
        PathBuf::from("artifacts"),
        "fwd_glue_softmax".into(),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(3) },
        1,
        None,
    );
    let gen = GlueGenerator::new(GlueTask::Sst2, 128, 3);
    let n = 48;
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let ex = gen.example(i as u64);
            handle.submit(ex.input_ids, ex.segment_ids)
        })
        .collect();
    let mut n_ok = 0;
    for rx in receivers {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.logits.len(), 3, "3-class head");
        assert!(resp.logits.iter().all(|x| x.is_finite()));
        assert!(resp.total_ms >= resp.queue_ms);
        n_ok += 1;
    }
    assert_eq!(n_ok, n);
    let stats = handle.shutdown().expect("stats");
    assert_eq!(stats.requests, n);
    // batching must actually coalesce: far fewer batches than requests
    assert!(stats.batches < n, "batches {} vs requests {n}", stats.batches);
    assert!(stats.throughput_rps > 0.0);
}

#[test]
fn serve_deterministic_for_identical_inputs() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts/ missing");
        return;
    }
    let handle = ServerHandle::spawn(
        PathBuf::from("artifacts"),
        "fwd_glue_softmax".into(),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        7,
        None,
    );
    let ids = vec![9i32; 64];
    let segs = vec![0i32; 64];
    let a = handle.submit(ids.clone(), segs.clone()).recv().unwrap();
    let b = handle.submit(ids, segs).recv().unwrap();
    // softmax attention is deterministic; identical inputs + params give
    // identical logits regardless of which batch they landed in.
    assert_eq!(a.logits, b.logits);
    handle.shutdown().unwrap();
}
