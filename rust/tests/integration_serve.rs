//! End-to-end serving integration: spawn the server thread against the
//! forward artifact, drive concurrent clients, check every request is
//! answered with well-formed logits and the batcher actually batches.

use std::path::{Path, PathBuf};
use std::time::Duration;
use yoso::attention::{ChunkPolicy, KernelVariant};
use yoso::data::glue_synth::{GlueGenerator, GlueTask};
use yoso::model::encoder::EncoderConfig;
use yoso::serve::{BatchPolicy, CpuServeConfig, ServerHandle};
use yoso::testing::test_threads;

fn artifacts_present() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

#[test]
fn serve_roundtrip_with_dynamic_batching() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return;
    }
    let handle = ServerHandle::spawn(
        PathBuf::from("artifacts"),
        "fwd_glue_softmax".into(),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(3) },
        1,
        None,
    );
    let gen = GlueGenerator::new(GlueTask::Sst2, 128, 3);
    let n = 48;
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let ex = gen.example(i as u64);
            handle.submit(ex.input_ids, ex.segment_ids)
        })
        .collect();
    let mut n_ok = 0;
    for rx in receivers {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.logits.len(), 3, "3-class head");
        assert!(resp.logits.iter().all(|x| x.is_finite()));
        assert!(resp.total_ms >= resp.queue_ms);
        n_ok += 1;
    }
    assert_eq!(n_ok, n);
    let stats = handle.shutdown().expect("stats");
    assert_eq!(stats.requests, n);
    // batching must actually coalesce: far fewer batches than requests
    assert!(stats.batches < n, "batches {} vs requests {n}", stats.batches);
    assert!(stats.throughput_rps > 0.0);
}

#[test]
fn serve_deterministic_for_identical_inputs() {
    if !artifacts_present() {
        eprintln!("skipping: artifacts/ missing");
        return;
    }
    let handle = ServerHandle::spawn(
        PathBuf::from("artifacts"),
        "fwd_glue_softmax".into(),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
        7,
        None,
    );
    let ids = vec![9i32; 64];
    let segs = vec![0i32; 64];
    let a = handle.submit(ids.clone(), segs.clone()).recv().unwrap();
    let b = handle.submit(ids, segs).recv().unwrap();
    // softmax attention is deterministic; identical inputs + params give
    // identical logits regardless of which batch they landed in.
    assert_eq!(a.logits, b.logits);
    handle.shutdown().unwrap();
}

/// Small geometry so the debug-build encoder forward stays in the
/// millisecond range; d_head = 32 (power of two) suits every variant.
fn tiny_cpu_config(attention: &str, seed: u64) -> CpuServeConfig {
    CpuServeConfig {
        attention: attention.into(),
        encoder: EncoderConfig {
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            vocab_size: 2005,
            max_len: 32,
            n_classes: 2,
        },
        threads: test_threads(2),
        chunk_policy: ChunkPolicy::default(),
        kernel: KernelVariant::from_env(),
        seed,
    }
}

#[test]
fn cpu_fallback_stress_every_request_replied_exactly_once() {
    // No artifacts needed: the CPU fallback serves the pure-Rust encoder
    // with request-level fan-out on the parallel engine's pool. Many
    // concurrent producers; every request must get exactly one reply.
    let handle = ServerHandle::spawn_cpu(
        tiny_cpu_config("yoso_8", 5),
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
    );
    let producers = 6usize;
    let per_producer = 8usize;
    let mut joins = Vec::new();
    for p in 0..producers {
        let sub = handle.submitter();
        joins.push(std::thread::spawn(move || {
            let gen = GlueGenerator::new(GlueTask::Sst2, 32, p as u64);
            (0..per_producer)
                .map(|i| {
                    let ex = gen.example((p * per_producer + i) as u64);
                    sub.submit(ex.input_ids, ex.segment_ids)
                })
                .collect::<Vec<_>>()
        }));
    }
    let mut n_ok = 0usize;
    for j in joins {
        for rx in j.join().expect("producer thread") {
            let resp = rx.recv().expect("exactly one reply");
            assert_eq!(resp.logits.len(), 2, "2-class head");
            assert!(resp.logits.iter().all(|x| x.is_finite()));
            assert!(resp.total_ms >= resp.queue_ms);
            assert!(rx.recv().is_err(), "a request was replied to twice");
            n_ok += 1;
        }
    }
    assert_eq!(n_ok, producers * per_producer);
    let stats = handle.shutdown().expect("stats");
    assert_eq!(stats.requests, producers * per_producer);
    assert!(stats.batches >= 1);
    assert!(stats.throughput_rps > 0.0);
}

#[test]
fn cpu_fallback_deterministic_for_identical_inputs() {
    // Stochastic attention variant: the content-hash RNG stream makes
    // identical inputs reproducible regardless of batch placement.
    let handle = ServerHandle::spawn_cpu(
        tiny_cpu_config("yoso_8", 9),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
    );
    let ids = vec![9i32; 32];
    let segs = vec![0i32; 32];
    let a = handle.submit(ids.clone(), segs.clone()).recv().unwrap();
    let b = handle.submit(ids, segs).recv().unwrap();
    assert_eq!(a.logits, b.logits);
    // hostile input: out-of-vocab / negative ids and bad segments must be
    // sanitized (-> UNK / clamped), answered, and must not wedge a worker
    let hostile = handle
        .submit(vec![i32::MAX, -7, 999_999], vec![5, -3, 2])
        .recv()
        .expect("sanitized reply");
    assert_eq!(hostile.logits.len(), 2);
    assert!(hostile.logits.iter().all(|x| x.is_finite()));
    let stats = handle.shutdown().unwrap();
    assert_eq!(stats.requests, 3);
}

#[test]
fn shutdown_returns_while_submitter_clones_alive() {
    // the shutdown-liveness contract: `shutdown` closes the queue
    // itself; producers holding Submitter clones must not block it
    let handle = ServerHandle::spawn_cpu(
        tiny_cpu_config("yoso_8", 3),
        BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
    );
    let sub = handle.submitter();
    let rx = sub.submit(vec![5i32; 8], vec![0i32; 8]);
    rx.recv().expect("served before shutdown");
    // `sub` still alive here — shutdown must drain and return anyway
    let stats = handle.shutdown().expect("stats");
    assert_eq!(stats.requests, 1);
    // post-shutdown submits fail fast: dead receiver, no hang
    assert!(sub.submit(vec![5i32; 8], vec![0i32; 8]).recv().is_err());
}

#[test]
fn cpu_fallback_logits_independent_of_worker_width_and_policy() {
    // The scheduler determinism contract, end to end: the same request
    // served by 1-wide and 3-wide pools, under the fixed and the
    // adaptive chunk policy, must produce byte-identical logits (the
    // content-hash RNG pins randomness; head tasks go through the
    // trait's per-head fold_in streams).
    let ids = vec![17i32; 32];
    let segs = vec![0i32; 32];
    let mut reference: Option<Vec<f32>> = None;
    for threads in [1usize, 3] {
        for chunk_policy in [ChunkPolicy::fixed(4), ChunkPolicy::adaptive(4)] {
            let mut cfg = tiny_cpu_config("yoso_8", 11);
            cfg.threads = threads;
            cfg.chunk_policy = chunk_policy;
            let handle = ServerHandle::spawn_cpu(
                cfg,
                BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) },
            );
            let resp = handle.submit(ids.clone(), segs.clone()).recv().unwrap();
            handle.shutdown().unwrap();
            if let Some(want) = &reference {
                assert_eq!(
                    want,
                    &resp.logits,
                    "threads={threads} policy={}",
                    chunk_policy.label()
                );
            } else {
                reference = Some(resp.logits);
            }
        }
    }
}
