//! Integration tests over the PJRT runtime + artifacts. These require
//! `make artifacts`; they skip (with a notice) when the directory is
//! missing so `cargo test` works on a fresh checkout.

use std::path::Path;
use yoso::attention::YosoE;
use yoso::runtime::literal::{f32_literal, i32_literal, to_f32_vec};
use yoso::runtime::Runtime;
use yoso::tensor::Mat;
use yoso::util::Rng;

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open(dir).expect("open runtime"))
}

#[test]
fn attention_artifact_matches_pure_rust_expectation() {
    // The Pallas-lowered YOSO-E op and the pure-Rust YosoE must agree:
    // same math, two implementations, two layers of the stack.
    let Some(rt) = runtime() else { return };
    let art = rt.artifact("attn_yoso_e_n256").expect("compile");
    let (n, d) = (256usize, 64usize);
    let mut rng = Rng::new(5);
    let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
    let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
    let v = Mat::randn(n, d, 1.0, &mut rng);

    let inputs = vec![
        f32_literal(&q.data, &[n, d]).unwrap(),
        f32_literal(&k.data, &[n, d]).unwrap(),
        f32_literal(&v.data, &[n, d]).unwrap(),
        i32_literal(&[0], &[]).unwrap(),
    ];
    let out = art.execute(&inputs).expect("execute");
    let got = to_f32_vec(&out[0]).unwrap();

    let mut expect = YosoE { tau: 8 }.forward_raw(&q, &k, &v);
    expect.l2_normalize_rows();
    let max_diff = got
        .iter()
        .zip(&expect.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "pallas vs rust YOSO-E: max diff {max_diff}");
}

#[test]
fn softmax_artifact_matches_pure_rust() {
    let Some(rt) = runtime() else { return };
    let art = rt.artifact("attn_softmax_n256").expect("compile");
    let (n, d) = (256usize, 64usize);
    let mut rng = Rng::new(6);
    let q = Mat::randn(n, d, 1.0, &mut rng);
    let k = Mat::randn(n, d, 1.0, &mut rng);
    let v = Mat::randn(n, d, 1.0, &mut rng);
    let inputs = vec![
        f32_literal(&q.data, &[n, d]).unwrap(),
        f32_literal(&k.data, &[n, d]).unwrap(),
        f32_literal(&v.data, &[n, d]).unwrap(),
        i32_literal(&[0], &[]).unwrap(),
    ];
    let out = art.execute(&inputs).expect("execute");
    let got = to_f32_vec(&out[0]).unwrap();
    use yoso::attention::{Attention, SoftmaxAttention};
    let expect = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
    let max_diff = got
        .iter()
        .zip(&expect.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-4, "softmax artifact vs rust: {max_diff}");
}

#[test]
fn train_step_reduces_loss_and_roundtrips_checkpoint() {
    let Some(rt) = runtime() else { return };
    use yoso::data::corpus::{CorpusConfig, CorpusGenerator};
    use yoso::data::mlm::{MlmConfig, PretrainStream};
    use yoso::data::tokenizer::WordTokenizer;
    use yoso::train::{PretrainSource, Trainer};

    let src = PretrainSource {
        stream: PretrainStream::new(
            CorpusGenerator::new(CorpusConfig::default()),
            WordTokenizer { n_words: 2000 },
            MlmConfig::default(),
            11,
        ),
    };
    let mut trainer =
        Trainer::new(&rt, "train_pretrain_softmax", Some("eval_pretrain_softmax"),
                     11, None)
            .expect("trainer");
    let first = trainer.train_step(&src, 0, 1e-3).expect("step");
    let mut last = first;
    for s in 1..12 {
        last = trainer.train_step(&src, s, 1e-3).expect("step");
    }
    assert!(last.loss.is_finite());
    assert!(
        last.loss < first.loss,
        "loss should decrease: {} -> {}",
        first.loss,
        last.loss
    );

    // checkpoint roundtrip preserves exact values
    let snap = trainer.snapshot().unwrap();
    let path = std::env::temp_dir().join(format!("it_ckpt_{}.bin", std::process::id()));
    yoso::train::checkpoint::save(&snap, &path).unwrap();
    let loaded = yoso::train::checkpoint::load(&path).unwrap();
    assert_eq!(snap.values, loaded.values);
    let _ = std::fs::remove_file(path);

    // eval runs and produces finite metrics
    let eval = trainer.evaluate(&src, 2).expect("eval");
    assert!(eval.mlm_perplexity.is_finite() && eval.mlm_perplexity > 1.0);
}

#[test]
fn forward_artifact_serves_batches() {
    let Some(rt) = runtime() else { return };
    use yoso::model::ParamSet;
    let art = rt.artifact("fwd_glue_softmax").expect("compile");
    let spec = &art.spec;
    let params = ParamSet::init_for(spec, 3);
    let ids_slot = spec
        .inputs
        .iter()
        .find(|s| s.name == "batch:input_ids")
        .unwrap();
    let (b, n) = (ids_slot.shape[0], ids_slot.shape[1]);
    let mut inputs: Vec<xla::Literal> = params
        .values
        .iter()
        .zip(&params.shapes)
        .map(|(v, s)| f32_literal(v, s).unwrap())
        .collect();
    inputs.push(i32_literal(&vec![5i32; b * n], &[b, n]).unwrap());
    inputs.push(i32_literal(&vec![0i32; b * n], &[b, n]).unwrap());
    inputs.push(i32_literal(&[1], &[]).unwrap());
    let out = art.execute(&inputs).expect("execute");
    let logits = to_f32_vec(&out[0]).unwrap();
    assert_eq!(logits.len() % b, 0);
    assert!(logits.iter().all(|x| x.is_finite()));
}
