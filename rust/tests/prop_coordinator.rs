//! Property tests on coordinator invariants (routing/batching/state) and
//! the estimator math, via the in-crate property-testing framework.

use std::sync::mpsc::channel;
use std::time::Duration;
use yoso::attention::{YosoAttention, YosoE};
use yoso::data::{collate_cls, ClsExample};
use yoso::serve::{BatchPolicy, Batcher, Request, Tick};
use yoso::tensor::Mat;
use yoso::testing::{check, gen, PropConfig};
use yoso::util::Rng;

/// Batcher invariant: every submitted request lands in exactly one batch,
/// in FIFO order, and no batch exceeds max_batch.
#[test]
fn prop_batcher_partitions_requests_in_order() {
    check(
        PropConfig { cases: 24, seed: 1 },
        |rng, size| {
            let n_requests = 1 + size;
            let max_batch = gen::usize_in(rng, 1, 9);
            (n_requests, max_batch)
        },
        |&(n_requests, max_batch)| {
            let (tx, rx) = channel();
            let mut keep = Vec::new();
            for i in 0..n_requests {
                let (reply, krx) = channel();
                keep.push(krx);
                tx.send(Request {
                    input_ids: vec![i as i32],
                    segment_ids: vec![0],
                    reply,
                    enqueued: Tick::ZERO,
                })
                .unwrap();
            }
            drop(tx);
            let b = Batcher::new(BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(1),
            });
            let mut seen = Vec::new();
            while let Some(batch) = b.next_batch(&rx) {
                if batch.len() > max_batch {
                    return false;
                }
                for r in batch {
                    seen.push(r.input_ids[0]);
                }
            }
            seen == (0..n_requests as i32).collect::<Vec<_>>()
        },
    );
}

/// Collation invariant: batch tensors always have exactly b*n elements
/// and labels survive collation (state management).
#[test]
fn prop_collate_shapes_and_labels() {
    check(
        PropConfig { cases: 32, seed: 2 },
        |rng, size| {
            let b = 1 + size % 8;
            let n = gen::usize_in(rng, 4, 64);
            let examples: Vec<ClsExample> = (0..b)
                .map(|i| {
                    let len = gen::usize_in(rng, 1, 2 * n);
                    ClsExample {
                        input_ids: gen::vec_of(rng, len, |r| r.below(100) as i32),
                        segment_ids: vec![0; len],
                        label: i as i32,
                    }
                })
                .collect();
            (examples, n)
        },
        |(examples, n)| {
            let batch = collate_cls(examples, *n);
            batch.input_ids.len() == examples.len() * n
                && batch.segment_ids.len() == examples.len() * n
                && batch.labels == (0..examples.len() as i32).collect::<Vec<_>>()
        },
    );
}

/// Estimator invariant: YOSO-m attention weights are in [0, 1] in
/// expectation — outputs of B-hat V are convex-combination-bounded by
/// sum of |V| rows.
#[test]
fn prop_yoso_output_bounded_by_value_mass() {
    check(
        PropConfig { cases: 12, seed: 3 },
        |rng, size| {
            let n = 8 + 4 * size.min(16);
            let q = gen::unit_mat(rng, n, 16);
            let k = gen::unit_mat(rng, n, 16);
            let v = Mat::randn(n, 8, 1.0, rng);
            (q, k, v)
        },
        |(q, k, v)| {
            let mut rng = Rng::new(77);
            let out = YosoAttention::new(6, 8, false).forward_raw(q, k, v, &mut rng);
            // each output entry <= sum_j |v_jl| (all weights in [0,1])
            for l in 0..v.cols {
                let mass: f32 = (0..v.rows).map(|j| v.at(j, l).abs()).sum();
                for i in 0..out.rows {
                    if out.at(i, l).abs() > mass + 1e-4 {
                        return false;
                    }
                }
            }
            true
        },
    );
}

/// Monte-Carlo consistency: averaging two independent YOSO-m runs is at
/// least as close to YOSO-E as the worse single run (variance reduction).
#[test]
fn prop_averaging_reduces_error() {
    check(
        PropConfig { cases: 8, seed: 4 },
        |rng, _size| {
            let n = 32;
            let q = gen::unit_mat(rng, n, 16);
            let k = gen::unit_mat(rng, n, 16);
            let v = Mat::randn(n, 8, 1.0, rng);
            (q, k, v)
        },
        |(q, k, v)| {
            let e = YosoE { tau: 4 }.forward_raw(q, k, v);
            let mut rng = Rng::new(5);
            let a = YosoAttention::new(4, 4, false).forward_raw(q, k, v, &mut rng);
            let b = YosoAttention::new(4, 4, false).forward_raw(q, k, v, &mut rng);
            let mut avg = a.clone();
            avg.add_assign(&b);
            avg.scale(0.5);
            let err = |m: &Mat| -> f64 {
                m.data
                    .iter()
                    .zip(&e.data)
                    .map(|(x, y)| ((x - y) as f64).powi(2))
                    .sum::<f64>()
            };
            err(&avg) <= err(&a).max(err(&b)) + 1e-9
        },
    );
}
