//! The fault-tolerance contract, end to end: **no admitted request is
//! lost**. Under a deterministic [`FaultPlan`] — poisoned requests that
//! panic mid-forward, replicas killed while holding a batch, injected
//! stalls, abandoned prefix-cache leases — every admitted seq reaches
//! exactly one terminal outcome (replied, shed on deadline, or a
//! terminal `Shed::InternalError`), and every reply that *is* delivered
//! is bit-identical to the fault-free run. The same plan drives both
//! executors: the virtual-clock simulator (exact counter assertions,
//! zero wall-clock sleeps) and the live supervised gateway (panics,
//! restarts, and lease discards really happen).
//!
//! CI's scheduler-stress job sweeps this suite across `YOSO_KERNEL`,
//! `YOSO_TEST_THREADS`, and fault schedules via `YOSO_FAULT_SEED`
//! (folded into every generated plan by [`env_seed`]).

use std::collections::BTreeSet;
use std::sync::mpsc::channel;
use std::sync::Once;
use std::time::{Duration, Instant};
use yoso::attention::{ChunkPolicy, KernelVariant};
use yoso::model::encoder::EncoderConfig;
use yoso::obs::{EventKind, ShedTag, TraceLog, TraceSink};
use yoso::serve::fault::env_seed;
use yoso::serve::sim::{
    run, run_faulted, run_faulted_traced, Arrival, ServiceModel, SimConfig,
};
use yoso::serve::{
    await_reply, BatchPolicy, BatchPolicyTable, BucketLayout,
    CpuServeConfig, DegradeLadder, FaultKind, FaultPlan, Gateway,
    GatewayConfig, GatewayReply, SchedPolicy, ServerHandle, Shed,
    ShedPolicy,
};
use yoso::testing::test_threads;
use yoso::util::Rng;

/// Injected faults panic on purpose; the default hook would spray every
/// expected panic's message and backtrace into the test log. Suppress
/// exactly those (the payloads this suite plants all contain
/// "injected fault") and delegate everything else untouched.
fn silence_injected_panics() {
    static SILENCE: Once = Once::new();
    SILENCE.call_once(|| {
        let default = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let injected = payload
                .downcast_ref::<&str>()
                .map(|s| s.contains("injected fault"))
                .or_else(|| {
                    payload
                        .downcast_ref::<String>()
                        .map(|s| s.contains("injected fault"))
                })
                .unwrap_or(false);
            if !injected {
                default(info);
            }
        }));
    });
}

fn ms(v: u64) -> Duration {
    Duration::from_millis(v)
}

fn us(v: u64) -> Duration {
    Duration::from_micros(v)
}

fn tiny_cfg(seed: u64) -> CpuServeConfig {
    CpuServeConfig {
        attention: "yoso_8".into(),
        encoder: EncoderConfig {
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            vocab_size: 2005,
            max_len: 32,
            n_classes: 2,
        },
        threads: test_threads(2),
        chunk_policy: ChunkPolicy::default(),
        kernel: KernelVariant::from_env(),
        seed,
    }
}

fn seqs_of(log: &TraceLog, kind: EventKind, shed: ShedTag) -> Vec<u64> {
    log.events
        .iter()
        .filter(|e| e.kind == kind && e.shed == shed)
        .map(|e| e.seq)
        .collect()
}

/// Asserts a seq list has no duplicates and returns it as a set.
fn unique(seqs: Vec<u64>, what: &str) -> BTreeSet<u64> {
    let n = seqs.len();
    let set: BTreeSet<u64> = seqs.into_iter().collect();
    assert_eq!(set.len(), n, "{what} carries a seq twice");
    set
}

/// The headline chaos property, in the simulator: across randomized
/// traces x seeded fault plans x both schedulers, the admitted set is
/// exactly partitioned by replied / expired / failed-internal, every
/// report counter equals its event count, and the whole run is
/// deterministic (same `(trace, plan)` -> same report, bit for bit).
#[test]
fn sim_chaos_every_admitted_seq_reaches_exactly_one_terminal_outcome() {
    let mut rng = Rng::new(0xC4A0 ^ env_seed());
    for case in 0..20u64 {
        let n = 15 + rng.below(50);
        let trace: Vec<Arrival> = (0..n)
            .map(|_| Arrival {
                at: us(rng.below(100_000) as u64),
                len: 1 + rng.below(60),
                deadline: (rng.below(4) == 0)
                    .then(|| ms(1 + rng.below(30) as u64)),
            })
            .collect();
        let plan =
            FaultPlan::seeded(env_seed() ^ (0xFA0 + case), n as u64);
        let retry_budget = rng.below(3) as u32;
        let replicas = 1 + rng.below(3);
        for sched in [SchedPolicy::Conserve, SchedPolicy::Fifo] {
            let cfg = SimConfig {
                replicas,
                queue_capacity: 2 + rng.below(30),
                sched,
                buckets: BucketLayout::pow2(8, 64),
                batch: BatchPolicyTable::uniform(BatchPolicy {
                    max_batch: 1 + rng.below(5),
                    max_wait: ms(rng.below(12) as u64),
                }),
                service: ServiceModel {
                    batch_overhead: us(100 + rng.below(1000) as u64),
                    per_width: us(1 + rng.below(30) as u64),
                },
                degrade: DegradeLadder::none(),
                m_full: 16,
                ..SimConfig::default()
            };
            let sink = TraceSink::new(
                replicas + 1,
                TraceSink::DEFAULT_LANE_CAPACITY,
                0,
            );
            let report = run_faulted_traced(
                &cfg,
                &trace,
                &plan,
                retry_budget,
                Some(&sink),
            );
            let log = sink.drain();
            assert_eq!(log.dropped, 0, "case {case}: ring overflowed");

            // the accounting identity, then counter == event count for
            // every fault-path series
            assert!(report.reconciles(), "case {case}");
            assert_eq!(log.count(EventKind::Admitted), report.accepted);
            assert_eq!(log.count(EventKind::Replied), report.completed);
            assert_eq!(
                log.count_shed(ShedTag::Expired),
                report.shed_deadline
            );
            assert_eq!(
                log.count_shed(ShedTag::Internal),
                report.failed_internal,
                "case {case}"
            );
            assert_eq!(log.count(EventKind::Requeued), report.requeued);
            assert_eq!(
                log.count(EventKind::ReplicaDied),
                report.replica_restarts
            );
            assert_eq!(
                log.count(EventKind::ReplicaRestarted),
                report.replica_restarts
            );
            assert_eq!(
                log.count(EventKind::BatchFormed),
                report.batches.len() as u64
            );

            // per-seq lifecycles: terminal outcomes are unique per seq
            // and together partition the admitted set exactly
            let admitted = unique(
                seqs_of(&log, EventKind::Admitted, ShedTag::Unspecified),
                "Admitted",
            );
            let replied = unique(
                seqs_of(&log, EventKind::Replied, ShedTag::Unspecified),
                "Replied",
            );
            let expired = unique(
                seqs_of(&log, EventKind::Shed, ShedTag::Expired),
                "Shed(Expired)",
            );
            let failed = unique(
                seqs_of(&log, EventKind::Shed, ShedTag::Internal),
                "Shed(Internal)",
            );
            assert!(replied.is_disjoint(&expired), "case {case}");
            assert!(replied.is_disjoint(&failed), "case {case}");
            assert!(expired.is_disjoint(&failed), "case {case}");
            let mut union = replied;
            union.extend(&expired);
            union.extend(&failed);
            assert_eq!(union, admitted, "case {case}: a request leaked");

            // chaos is reproducible: the same (trace, plan) again is
            // bit-identical, and the empty plan is exactly `run`
            let again = run_faulted(&cfg, &trace, &plan, retry_budget);
            assert_eq!(again, report, "case {case}: chaos not reproducible");
            let clean =
                run_faulted(&cfg, &trace, &FaultPlan::none(), retry_budget);
            assert_eq!(clean, run(&cfg, &trace), "case {case}");
        }
    }
}

/// The same property on the live supervised gateway: submit a request
/// set fault-free for reference logits, then re-run it under a seeded
/// plan. Every receiver resolves within the deadline-bounded wait —
/// never a lost reply — as either logits bit-identical to the reference
/// or a terminal `InternalError` carrying its own seq; the directly
/// faulted seqs all fail; stats reconcile with the trace stream.
#[test]
fn live_gateway_chaos_never_loses_an_admitted_request() {
    silence_injected_panics();
    let n = 32usize;
    let mut rng = Rng::new(0xB0B);
    let reqs: Vec<(Vec<i32>, Vec<i32>)> = (0..n)
        .map(|_| {
            let len = 3 + rng.below(29);
            let ids: Vec<i32> =
                (0..len).map(|_| 5 + rng.below(1990) as i32).collect();
            let segs = vec![0i32; len];
            (ids, segs)
        })
        .collect();
    let gw_cfg = |fault: FaultPlan| {
        let mut cfg = GatewayConfig::new(tiny_cfg(23));
        cfg.replicas = 2;
        cfg.queue_capacity = 64;
        cfg.shed = ShedPolicy::Reject;
        cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_millis(1),
        });
        cfg.buckets = BucketLayout::pow2(8, 32);
        cfg.trace = true;
        cfg.fault = fault;
        cfg
    };

    // fault-free reference logits, submitted sequentially so admission
    // seq == request index in both runs
    let gw = Gateway::spawn(gw_cfg(FaultPlan::none()));
    let reference: Vec<Vec<f32>> = reqs
        .iter()
        .map(|(ids, segs)| {
            let rx = gw.submit(ids.clone(), segs.clone()).expect("admitted");
            await_reply(&rx, Duration::from_secs(120))
                .expect("fault-free run serves everything")
                .logits
        })
        .collect();
    gw.shutdown();

    let plan = FaultPlan::seeded(env_seed() ^ 0x11FE, n as u64);
    let mut panics = BTreeSet::new();
    let mut kills = BTreeSet::new();
    let mut abandons = BTreeSet::new();
    for f in plan.faults() {
        match *f {
            FaultKind::PanicOnSeq(s) => {
                panics.insert(s);
            }
            FaultKind::KillReplicaOnSeq(s) => {
                kills.insert(s);
            }
            FaultKind::AbandonLeaseOnSeq(s) => {
                abandons.insert(s);
            }
            FaultKind::StallOnSeq { .. } => {}
        }
    }
    let must_fail: BTreeSet<u64> =
        panics.iter().chain(&kills).chain(&abandons).copied().collect();

    let gw = Gateway::spawn(gw_cfg(plan));
    let sink = gw.trace_sink().expect("trace was enabled");
    let rxs: Vec<_> = reqs
        .iter()
        .map(|(ids, segs)| {
            gw.submit(ids.clone(), segs.clone()).expect("admitted")
        })
        .collect();
    let mut failed = BTreeSet::new();
    for (i, rx) in rxs.into_iter().enumerate() {
        // the deadline-bounded client wait: a faulted gateway answers
        // with a terminal error — it never leaves a receiver hanging
        match await_reply(&rx, Duration::from_secs(120)) {
            Ok(resp) => {
                assert!(
                    !must_fail.contains(&(i as u64)),
                    "seq {i} was directly faulted but served"
                );
                assert_eq!(reference[i].len(), resp.logits.len());
                for (a, b) in reference[i].iter().zip(&resp.logits) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "seq {i}: delivered reply diverged from the \
                         fault-free run"
                    );
                }
            }
            Err(Shed::InternalError { seq, .. }) => {
                assert_eq!(seq, i as u64, "InternalError names the wrong seq");
                failed.insert(seq);
            }
            Err(other) => panic!("seq {i}: unexpected shed {other}"),
        }
    }
    assert!(
        failed.is_superset(&must_fail),
        "a directly faulted seq escaped terminal failure: \
         failed={failed:?} must_fail={must_fail:?}"
    );

    let stats = gw.shutdown();
    let log = sink.drain();
    assert_eq!(stats.accepted, n as u64);
    assert_eq!(stats.failed_internal, failed.len() as u64);
    assert_eq!(stats.completed, (n - failed.len()) as u64);
    assert_eq!(stats.shed_deadline, 0);
    assert_eq!(
        stats.accepted,
        stats.completed + stats.shed_deadline + stats.failed_internal,
        "no-request-lost accounting broke"
    );
    if !kills.is_empty() {
        assert!(stats.replica_restarts >= 1, "a kill left no restart");
        assert!(stats.requeued >= 1, "a kill requeued nothing");
    }
    // every abandoned lease is a discarded session; a kill can doom an
    // abandon seq before it ever checks out, so <= — and exactly ==
    // when no kill interferes
    assert!(stats.cache_abandoned <= abandons.len() as u64);
    if kills.is_empty() {
        assert_eq!(stats.cache_abandoned, abandons.len() as u64);
    }
    // stats reconcile with the flight recorder, fault kinds included
    assert_eq!(log.count(EventKind::Admitted), stats.accepted);
    assert_eq!(log.count(EventKind::Replied), stats.completed);
    assert_eq!(log.count_shed(ShedTag::Internal), stats.failed_internal);
    assert_eq!(log.count(EventKind::Requeued), stats.requeued);
    assert_eq!(log.count(EventKind::ReplicaDied), stats.replica_restarts);
    assert_eq!(
        log.count(EventKind::ReplicaRestarted),
        stats.replica_restarts
    );
}

/// The retry budget, exactly: one crashy seq on a single replica with
/// singleton batches dies `budget + 1` times (each pick kills the
/// replica; the last one dooms the seq), while its neighbors ride the
/// respawned worker to completion.
#[test]
fn retry_budget_bounds_the_crash_loop_exactly() {
    silence_injected_panics();
    let mut cfg = GatewayConfig::new(tiny_cfg(7));
    cfg.replicas = 1;
    cfg.queue_capacity = 8;
    cfg.shed = ShedPolicy::Reject;
    cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
        max_batch: 1,
        max_wait: Duration::ZERO,
    });
    cfg.buckets = BucketLayout::single(32);
    cfg.retry_budget = 2;
    cfg.fault =
        FaultPlan::from_faults(vec![FaultKind::KillReplicaOnSeq(1)]);
    let gw = Gateway::spawn(cfg);
    let rxs: Vec<_> = (0..3)
        .map(|i| {
            gw.submit(vec![10 + i; 8], vec![0; 8]).expect("admitted")
        })
        .collect();
    let outcomes: Vec<GatewayReply> = rxs
        .iter()
        .map(|rx| await_reply(rx, Duration::from_secs(120)))
        .collect();
    assert!(outcomes[0].is_ok(), "seq 0 rides the healthy replica");
    assert!(
        matches!(
            outcomes[1],
            Err(Shed::InternalError { seq: 1, retries: 2 })
        ),
        "seq 1 must fail terminally with its crash count: a budget-2 \
         loop reports exactly 2 retries, not the raw restart tally"
    );
    assert!(outcomes[2].is_ok(), "seq 2 rides the respawned replica");
    let stats = gw.shutdown();
    assert_eq!(stats.accepted, 3);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.failed_internal, 1);
    // budget 2: two requeues, then the third pick dooms it — and every
    // pick killed the replica once
    assert_eq!(stats.requeued, 2);
    assert_eq!(stats.replica_restarts, 3);
}

/// The stall-supervision fix, live: a replica wedged by an injected
/// stall posts its batch to the steal board, and the idle peer
/// whole-steals it within one heartbeat — the stalled seq's reply
/// arrives in steal time, not stall time, and the stolen batch is
/// executed (and counted) exactly once.
#[test]
fn stalled_batch_is_stolen_within_the_heartbeat_bound() {
    silence_injected_panics();
    let mut cfg = GatewayConfig::new(tiny_cfg(31));
    cfg.replicas = 2;
    cfg.queue_capacity = 8;
    cfg.shed = ShedPolicy::Reject;
    cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
        max_batch: 1,
        max_wait: Duration::ZERO,
    });
    cfg.buckets = BucketLayout::single(32);
    cfg.steal = true;
    cfg.heartbeat = ms(10);
    cfg.trace = true;
    // a 2 s wedge: without stealing, seq 0's reply waits out the whole
    // stall; with it, the idle peer lifts the posted batch after ~10 ms
    cfg.fault = FaultPlan::from_faults(vec![FaultKind::StallOnSeq {
        seq: 0,
        ns: 2_000_000_000,
    }]);
    let gw = Gateway::spawn(cfg);
    let sink = gw.trace_sink().expect("trace was enabled");
    let t0 = Instant::now();
    let rx0 = gw.submit(vec![10; 8], vec![0; 8]).expect("admitted");
    let rx1 = gw.submit(vec![11; 8], vec![0; 8]).expect("admitted");
    let r0 = await_reply(&rx0, Duration::from_secs(60));
    let r1 = await_reply(&rx1, Duration::from_secs(60));
    assert!(r0.is_ok(), "stalled seq must be served by the thief: {r0:?}");
    assert!(r1.is_ok(), "the healthy seq rides the other replica");
    assert!(
        t0.elapsed() < Duration::from_secs(1),
        "replies took steal time (heartbeat-bounded), not stall time"
    );
    let stats = gw.shutdown();
    assert_eq!(stats.accepted, 2);
    assert_eq!(stats.completed, 2);
    assert_eq!(stats.stolen, 1, "exactly the wedged batch was stolen");
    assert_eq!(stats.failed_internal, 0);
    assert_eq!(
        stats.accepted,
        stats.completed + stats.shed_deadline + stats.failed_internal,
        "accounting identity under stealing"
    );
    let log = sink.drain();
    assert_eq!(log.count(EventKind::Stolen), stats.stolen);
    assert_eq!(
        log.count(EventKind::BatchFormed),
        stats.batches,
        "a whole-stolen batch is formed (and counted) exactly once"
    );
}

/// The client-side hang fix: a reply wait is always deadline-bounded.
/// A dropped sender (dead server) errors immediately; a silent one
/// errors at the deadline; and the single-loop server's `submit_wait`
/// both serves within the bound and fails fast after shutdown.
#[test]
fn reply_waits_are_deadline_bounded_never_hangs() {
    // dropped sender: the regression this PR fixes — previously a bare
    // `recv()` here blocked forever on a replica that died un-supervised
    let (tx, rx) = channel::<GatewayReply>();
    drop(tx);
    let t0 = Instant::now();
    let got = await_reply(&rx, Duration::from_secs(30));
    assert!(matches!(got, Err(Shed::ReplyLost { .. })));
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "dropped sender must error immediately, not at the deadline"
    );

    // silent sender: bounded by the timeout, not unbounded
    let (_tx, rx) = channel::<GatewayReply>();
    let t0 = Instant::now();
    match await_reply(&rx, Duration::from_millis(50)) {
        Err(Shed::ReplyLost { waited_ms }) => assert_eq!(waited_ms, 50),
        other => panic!("expected ReplyLost, got {other:?}"),
    }
    assert!(t0.elapsed() >= Duration::from_millis(50));

    // live single-loop server: served within the bound...
    let handle = ServerHandle::spawn_cpu(
        tiny_cfg(5),
        BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(1) },
    );
    let sub = handle.submitter();
    let resp = sub
        .submit_wait(vec![7; 10], vec![0; 10], Duration::from_secs(120))
        .expect("a healthy server answers");
    assert!(!resp.logits.is_empty());
    handle.shutdown().expect("stats");
    // ...and a submit against the shut-down server errors promptly
    // (dead receiver), not after the full timeout
    let t0 = Instant::now();
    assert!(sub
        .submit_wait(vec![7; 10], vec![0; 10], Duration::from_secs(30))
        .is_err());
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "post-shutdown submit_wait must fail fast"
    );
}
