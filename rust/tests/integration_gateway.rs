//! Gateway overload, deadline, and shutdown-liveness behavior: drive the
//! gateway past capacity and assert sheds are *reported* (never silent),
//! every accepted request gets exactly one reply, and the stats
//! counters reconcile (`accepted == completed + shed_deadline`,
//! client-observed outcomes match the gateway's own counts).

use std::time::Duration;
use yoso::attention::{ChunkPolicy, KernelVariant};
use yoso::model::encoder::EncoderConfig;
use yoso::serve::{
    BatchPolicy, BatchPolicyTable, BucketLayout, CpuServeConfig,
    DegradeLadder, Gateway, GatewayConfig, Quality, SchedPolicy, Shed,
    ShedPolicy,
};
use yoso::testing::test_threads;

fn tiny_cfg(seed: u64) -> CpuServeConfig {
    CpuServeConfig {
        attention: "yoso_8".into(),
        encoder: EncoderConfig {
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            vocab_size: 2005,
            max_len: 32,
            n_classes: 2,
        },
        threads: 1,
        chunk_policy: ChunkPolicy::default(),
        kernel: KernelVariant::from_env(),
        seed,
    }
}

fn overload_cfg(seed: u64, capacity: usize, shed: ShedPolicy) -> GatewayConfig {
    let mut cfg = GatewayConfig::new(tiny_cfg(seed));
    cfg.replicas = 1;
    cfg.queue_capacity = capacity;
    cfg.shed = shed;
    cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
    });
    cfg.buckets = BucketLayout::pow2(8, 32);
    cfg
}

#[test]
fn overload_sheds_are_reported_and_stats_reconcile() {
    // 4 producers x 25 un-paced submits against capacity 4 and a single
    // 1-wide replica: admission must reject most of the burst
    let gw = Gateway::spawn(overload_cfg(5, 4, ShedPolicy::Reject));
    let producers = 4usize;
    let per_producer = 25usize;
    let mut joins = Vec::new();
    for p in 0..producers {
        let sub = gw.submitter();
        joins.push(std::thread::spawn(move || {
            let mut accepted = Vec::new();
            let mut rejected = 0u64;
            for i in 0..per_producer {
                let len = 4 + (p * per_producer + i) % 24;
                match sub.submit(vec![7i32; len], vec![0i32; len]) {
                    Ok(rx) => accepted.push(rx),
                    Err(Shed::QueueFull { retry_after_ms }) => {
                        assert!(retry_after_ms >= 1, "hint must be actionable");
                        rejected += 1;
                    }
                    Err(other) => panic!("unexpected shed: {other}"),
                }
            }
            (accepted, rejected)
        }));
    }
    let mut client_accepted = 0u64;
    let mut client_rejected = 0u64;
    for j in joins {
        let (accepted, rejected) = j.join().expect("producer thread");
        client_rejected += rejected;
        for rx in accepted {
            client_accepted += 1;
            let reply = rx.recv().expect("exactly one reply per accepted");
            let resp = reply.expect("no deadlines here, so no late sheds");
            assert_eq!(resp.logits.len(), 2);
            assert!(resp.logits.iter().all(|x| x.is_finite()));
            assert!(rx.recv().is_err(), "a request was replied to twice");
        }
    }
    let stats = gw.shutdown();
    assert!(client_rejected > 0, "overload never triggered admission sheds");
    assert_eq!(stats.rejected, client_rejected, "sheds must be reported");
    assert_eq!(stats.accepted, client_accepted);
    assert_eq!(
        stats.accepted,
        stats.completed + stats.shed_deadline,
        "accepted requests must be accounted for: completed or shed"
    );
    assert_eq!(stats.shed_deadline, 0);
    assert_eq!(stats.latency.count(), stats.completed);
    assert!(stats.peak_queue_depth >= 1 && stats.peak_queue_depth <= 4);
    assert!(stats.throughput_rps > 0.0);
}

#[test]
fn expired_deadlines_shed_before_execution_and_reconcile() {
    let gw = Gateway::spawn(overload_cfg(7, 64, ShedPolicy::Reject));
    // zero deadline: already expired whenever a replica dequeues it
    let doomed: Vec<_> = (0..3)
        .map(|_| {
            gw.submitter()
                .submit_with_deadline(
                    vec![9i32; 12],
                    vec![0i32; 12],
                    Some(Duration::ZERO),
                )
                .expect("admitted")
        })
        .collect();
    let healthy: Vec<_> = (0..5)
        .map(|_| gw.submit(vec![5i32; 12], vec![0i32; 12]).expect("admitted"))
        .collect();
    for rx in doomed {
        match rx.recv().expect("shed must be delivered, not dropped") {
            Err(Shed::DeadlineExpired) => {}
            other => panic!("expected DeadlineExpired, got {other:?}"),
        }
    }
    for rx in healthy {
        rx.recv().expect("reply").expect("healthy request served");
    }
    let stats = gw.shutdown();
    assert_eq!(stats.shed_deadline, 3);
    assert_eq!(stats.completed, 5);
    assert_eq!(stats.accepted, stats.completed + stats.shed_deadline);
}

#[test]
fn block_policy_applies_backpressure_without_sheds() {
    // closed-loop producer against a capacity-2 queue: Block admits
    // everything eventually, rejecting nothing. Pinned to the FIFO
    // baseline so the legacy scheduler keeps live-path coverage.
    let mut cfg = overload_cfg(11, 2, ShedPolicy::Block);
    cfg.sched = SchedPolicy::Fifo;
    let gw = Gateway::spawn(cfg);
    let sub = gw.submitter();
    let producer = std::thread::spawn(move || {
        (0..10)
            .map(|i| {
                sub.submit(vec![6i32; 4 + i], vec![0i32; 4 + i])
                    .expect("Block never rejects while open")
            })
            .collect::<Vec<_>>()
    });
    for rx in producer.join().expect("producer thread") {
        rx.recv().expect("reply").expect("served");
    }
    let stats = gw.shutdown();
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.accepted, 10);
    assert_eq!(stats.completed, 10);
}

#[test]
fn shutdown_returns_with_live_submitters_then_rejects() {
    let gw = Gateway::spawn(overload_cfg(13, 16, ShedPolicy::Reject));
    let sub = gw.submitter();
    let rx = sub.submit(vec![5i32; 8], vec![0i32; 8]).expect("admitted");
    rx.recv().expect("reply").expect("served");
    // `sub` is still alive: shutdown must drain and return anyway
    let stats = gw.shutdown();
    assert_eq!(stats.completed, 1);
    assert_eq!(
        sub.submit(vec![5i32; 8], vec![0i32; 8]).unwrap_err(),
        Shed::Closed
    );
}

#[test]
fn scaled_policy_table_and_conserve_serve_and_reconcile() {
    // the new defaults end to end on the live gateway: width-scaled
    // per-bucket batch policies + work-conserving deadline-aware
    // scheduling, mixed-length traffic with a deadline slice. Everything
    // must be answered exactly once and the counters must reconcile.
    let mut cfg = GatewayConfig::new(tiny_cfg(21));
    cfg.replicas = 2;
    cfg.queue_capacity = 64;
    cfg.shed = ShedPolicy::Reject;
    cfg.sched = SchedPolicy::Conserve;
    cfg.batch = BatchPolicyTable::scaled(BatchPolicy {
        max_batch: 2,
        max_wait: Duration::from_millis(4),
    })
    .with_override(8, BatchPolicy {
        max_batch: 16,
        max_wait: Duration::from_millis(1),
    });
    cfg.buckets = BucketLayout::pow2(8, 32);
    let gw = Gateway::spawn(cfg);
    let mut rxs = Vec::new();
    let mut doomed = 0u64;
    for i in 0..24usize {
        let len = 3 + (i * 7) % 30;
        // a slice of already-expired deadlines exercises EDF + sheds
        let deadline = (i % 6 == 5).then_some(Duration::ZERO);
        if deadline.is_some() {
            doomed += 1;
        }
        rxs.push((
            deadline.is_some(),
            gw.submitter()
                .submit_with_deadline(vec![4i32; len], vec![0i32; len], deadline)
                .expect("admitted"),
        ));
    }
    let (mut served, mut shed) = (0u64, 0u64);
    for (was_doomed, rx) in rxs {
        match rx.recv().expect("every request gets exactly one reply") {
            Ok(resp) => {
                assert!(!was_doomed, "an expired deadline reached execution");
                assert_eq!(resp.logits.len(), 2);
                served += 1;
            }
            Err(Shed::DeadlineExpired) => shed += 1,
            Err(other) => panic!("unexpected shed: {other}"),
        }
    }
    let stats = gw.shutdown();
    assert_eq!(shed, doomed);
    assert_eq!(stats.completed, served);
    assert_eq!(stats.shed_deadline, shed);
    assert_eq!(stats.accepted, stats.completed + stats.shed_deadline);
    assert_eq!(stats.accepted, 24);
}

#[test]
fn responses_carry_served_at_quality_for_all_three_classes() {
    // The client-visible half of the degradation contract: `Response`
    // reports the hash-round count the logits were *actually* computed
    // with, end to end for every quality class. A rung at threshold 0
    // pins the ladder permanently engaged (backlog >= 0 always holds),
    // so BestEffort deterministically serves at m'=4 — no load shaping
    // required. "yoso_8" puts the full round count at 8.
    let mut cfg = overload_cfg(17, 64, ShedPolicy::Reject);
    cfg.degrade = DegradeLadder::steps(vec![(0, 4)]);
    let gw = Gateway::spawn(cfg);
    let sub = gw.submitter();
    let submit = |q: Quality| {
        sub.submit_with(vec![5i32; 12], vec![0i32; 12], None, q)
            .expect("admitted")
    };
    let full = submit(Quality::Full);
    let pinned = submit(Quality::Degraded(2));
    let best = submit(Quality::BestEffort);

    // Full is immune to the engaged ladder
    let resp = full.recv().expect("reply").expect("served");
    assert_eq!(resp.m_served, 8);
    assert_eq!(resp.quality, Quality::Full);

    // a pinned request gets exactly its m', reported as such
    let resp = pinned.recv().expect("reply").expect("served");
    assert_eq!(resp.m_served, 2);
    assert_eq!(resp.quality, Quality::Degraded(2));

    // BestEffort takes the ladder's rung and reports the realized class
    // (not the class it was submitted under)
    let resp = best.recv().expect("reply").expect("served");
    assert_eq!(resp.m_served, 4);
    assert_eq!(resp.quality, Quality::Degraded(4));

    let stats = gw.shutdown();
    assert_eq!(stats.completed, 3);
    assert_eq!(stats.served_full, 1, "only the Quality::Full request");
    assert_eq!(stats.served_degraded, 2, "pinned + stepped-down");
}

#[test]
fn multi_replica_gateway_serves_concurrent_producers() {
    // the replicated path under concurrency: replicas {test_threads(2)}
    // with 1-wide pools, many producers, everything answered once
    let mut cfg = overload_cfg(3, 256, ShedPolicy::Reject);
    cfg.replicas = test_threads(2).clamp(1, 4);
    let gw = Gateway::spawn(cfg);
    let mut joins = Vec::new();
    for p in 0..4usize {
        let sub = gw.submitter();
        joins.push(std::thread::spawn(move || {
            (0..8usize)
                .map(|i| {
                    let len = 3 + (p + i * 5) % 28;
                    sub.submit(vec![11i32; len], vec![0i32; len])
                        .expect("capacity is ample")
                })
                .collect::<Vec<_>>()
        }));
    }
    let mut served = 0u64;
    for j in joins {
        for rx in j.join().expect("producer") {
            let resp = rx.recv().expect("one reply").expect("served");
            assert_eq!(resp.logits.len(), 2);
            assert!(resp.total_ms >= resp.queue_ms);
            served += 1;
        }
    }
    let stats = gw.shutdown();
    assert_eq!(served, 32);
    assert_eq!(stats.completed, 32);
    assert_eq!(stats.accepted, stats.completed + stats.shed_deadline);
    // every replica's stats are present in the merge
    assert_eq!(stats.per_replica.len(), test_threads(2).clamp(1, 4));
    let sum: u64 = stats.per_replica.iter().map(|r| r.requests).sum();
    assert_eq!(sum, stats.completed);
}
