//! Property tests over the whole attention zoo (`attention::by_name`)
//! via the in-crate `testing::{check, gen}` framework: output shapes and
//! finiteness on random inputs, monotonicity of the `workspace_bytes`
//! memory model in n (zoo variants and the engine under both chunk
//! policies), determinism of the parallel engine (1 thread vs N threads,
//! same seed => identical bytes — fixed and adaptive chunking, both
//! schedulers), and fixed/adaptive agreement whenever the adaptive
//! policy resolves to the same chunk size. Pool widths honor
//! `YOSO_TEST_THREADS` so CI can sweep them.

use std::sync::Arc;
use yoso::attention::{
    by_name, Attention, ChunkPolicy, Engine, HeadTask, MultiHeadAttention,
    YosoAttention,
};
use yoso::tensor::Mat;
use yoso::testing::{check, gen, test_threads, PropConfig};
use yoso::util::Rng;

/// Every constructible zoo variant (the §4.2 baselines + YOSO family).
const ZOO: [&str; 12] = [
    "softmax",
    "none",
    "yoso_e",
    "yoso_16",
    "yoso_fast_16",
    "yoso_c_16",
    "linear",
    "linformer",
    "performer",
    "longformer",
    "reformer",
    "nystrom",
];

/// Head dim for all cases; a power of two so `yoso_fast_*` (Hadamard)
/// is constructible.
const D: usize = 32;

fn bits_equal(a: &Mat, b: &Mat) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data
            .iter()
            .zip(&b.data)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_zoo_output_shape_and_finite() {
    check(
        PropConfig { cases: 10, seed: 0xA77E },
        |rng, size| {
            let n = 2 + size % 40;
            let q = gen::unit_mat(rng, n, D);
            let k = gen::unit_mat(rng, n, D);
            let v = Mat::randn(n, D, 1.0, rng);
            (q, k, v)
        },
        |(q, k, v)| {
            ZOO.iter().all(|name| {
                let mut ctor = Rng::new(1);
                let attn = by_name(name, &mut ctor, D);
                let mut run = Rng::new(2);
                let out = attn.forward(q, k, v, &mut run);
                out.rows == q.rows
                    && out.cols == D
                    && out.data.iter().all(|x| x.is_finite())
            })
        },
    );
}

#[test]
fn workspace_bytes_monotone_in_n() {
    for name in ZOO {
        let mut ctor = Rng::new(3);
        let attn = by_name(name, &mut ctor, D);
        let mut prev = 0usize;
        for n in [16usize, 64, 256, 1024, 4096, 16384] {
            let ws = attn.workspace_bytes(n, D);
            assert!(
                ws >= prev,
                "{name}: workspace_bytes shrank going to n={n} ({prev} -> {ws})"
            );
            prev = ws;
        }
    }
}

#[test]
fn engine_workspace_monotone_in_n_under_both_policies() {
    // the satellite property: the engine's analytic memory model must
    // stay monotone in n whichever policy resolves the task layout
    let att = YosoAttention::new(8, 32, false);
    for threads in [1usize, 4] {
        for policy in [
            ChunkPolicy::fixed(4),
            ChunkPolicy::fixed(16),
            ChunkPolicy::adaptive(2),
            ChunkPolicy::adaptive(8),
        ] {
            let engine = Engine::with_policy(threads, policy);
            let mut prev = 0usize;
            for n in [16usize, 64, 256, 1024, 4096, 16384] {
                let ws = engine.workspace_bytes(&att, n, D);
                assert!(
                    ws >= prev,
                    "{} threads={threads}: workspace shrank going to n={n} \
                     ({prev} -> {ws})",
                    policy.label()
                );
                prev = ws;
            }
        }
    }
}

#[test]
fn prop_adaptive_matches_fixed_at_same_resolved_chunk() {
    // whenever adaptive resolves (m, n·d, width) to chunk size c, its
    // output must be byte-for-byte the output of Fixed(c): the resolved
    // layout — not the policy variant — decides the reduction order
    check(
        PropConfig { cases: 8, seed: 0xCC0C },
        |rng, size| {
            let n = 8 + size % 48;
            let m = 1 + rng.below(24);
            let width = 1 + rng.below(8);
            let q = gen::unit_mat(rng, n, D);
            let k = gen::unit_mat(rng, n, D);
            let v = Mat::randn(n, D, 1.0, rng);
            (q, k, v, m, width)
        },
        |(q, k, v, m, width)| {
            let att = YosoAttention::new(5, *m, false);
            let adaptive = ChunkPolicy::adaptive(*width);
            let c = adaptive.chunk_size(*m, q.rows, q.cols);
            let rng = Rng::new(0xF00D ^ *m as u64);
            let t = test_threads(4);
            let a = Engine::with_policy(t, adaptive).forward_yoso(&att, q, k, v, &rng);
            let f = Engine::with_policy(t, ChunkPolicy::fixed(c))
                .forward_yoso(&att, q, k, v, &rng);
            bits_equal(&a, &f)
        },
    );
}

#[test]
fn zoo_parallel_heads_bit_identical_to_serial() {
    // MultiHeadAttention on a pool vs the trait's serial default: same
    // fold_in(head) streams, so every variant (stochastic or not) must
    // produce identical bytes.
    let mut rng = Rng::new(11);
    let heads: Vec<HeadTask> = (0..4)
        .map(|_| HeadTask {
            q: Mat::randn(24, D, 1.0, &mut rng).unit_rows(),
            k: Mat::randn(24, D, 1.0, &mut rng).unit_rows(),
            v: Mat::randn(24, D, 1.0, &mut rng),
        })
        .collect();
    let base = Rng::new(999);
    let mh = MultiHeadAttention::new(Engine::new(test_threads(4)));
    for name in ZOO {
        let mut ctor = Rng::new(7);
        let attn: Arc<dyn Attention> = Arc::from(by_name(name, &mut ctor, D));
        let serial = attn.forward_batch(&heads, &base);
        let par = mh.forward_batch(&attn, heads.clone(), &base);
        assert_eq!(serial.len(), par.len(), "{name}");
        for (a, b) in serial.iter().zip(&par) {
            assert!(bits_equal(a, b), "{name}: parallel heads diverged");
        }
    }
}

#[test]
fn engine_one_thread_vs_many_identical_bytes() {
    // 1 thread vs N threads, work-stealing vs channel scheduler, fixed
    // vs adaptive chunking: bytes may depend on the *policy*, never on
    // the thread count or the scheduler
    let mut rng = Rng::new(4);
    let q = Mat::randn(80, D, 1.0, &mut rng).unit_rows();
    let k = Mat::randn(80, D, 1.0, &mut rng).unit_rows();
    let v = Mat::randn(80, D, 1.0, &mut rng);
    let many = test_threads(8);
    for (tau, m, fast) in [(6usize, 8usize, false), (4, 16, true)] {
        let att = YosoAttention::new(tau, m, fast);
        let seed_rng = Rng::new(31);
        for policy in [ChunkPolicy::fixed(4), ChunkPolicy::adaptive(4)] {
            let one = Engine::with_policy(1, policy)
                .forward_yoso(&att, &q, &k, &v, &seed_rng);
            let steal = Engine::with_policy(many, policy)
                .forward_yoso(&att, &q, &k, &v, &seed_rng);
            assert!(
                bits_equal(&one, &steal),
                "tau={tau} m={m} fast={fast} {}: thread count changed the bytes",
                policy.label()
            );
            let chan = Engine::new_channel_with(many, policy)
                .forward_yoso(&att, &q, &k, &v, &seed_rng);
            assert!(
                bits_equal(&one, &chan),
                "tau={tau} m={m} fast={fast} {}: scheduler changed the bytes",
                policy.label()
            );
        }
    }
}
