//! Property tests over the whole attention zoo (`attention::by_name`)
//! via the in-crate `testing::{check, gen}` framework: output shapes and
//! finiteness on random inputs, monotonicity of the `workspace_bytes`
//! memory model in n, and determinism of the parallel engine (1 thread
//! vs N threads, same seed => identical bytes).

use std::sync::Arc;
use yoso::attention::{
    by_name, Attention, Engine, HeadTask, MultiHeadAttention, YosoAttention,
};
use yoso::tensor::Mat;
use yoso::testing::{check, gen, PropConfig};
use yoso::util::Rng;

/// Every constructible zoo variant (the §4.2 baselines + YOSO family).
const ZOO: [&str; 12] = [
    "softmax",
    "none",
    "yoso_e",
    "yoso_16",
    "yoso_fast_16",
    "yoso_c_16",
    "linear",
    "linformer",
    "performer",
    "longformer",
    "reformer",
    "nystrom",
];

/// Head dim for all cases; a power of two so `yoso_fast_*` (Hadamard)
/// is constructible.
const D: usize = 32;

fn bits_equal(a: &Mat, b: &Mat) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data
            .iter()
            .zip(&b.data)
            .all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn prop_zoo_output_shape_and_finite() {
    check(
        PropConfig { cases: 10, seed: 0xA77E },
        |rng, size| {
            let n = 2 + size % 40;
            let q = gen::unit_mat(rng, n, D);
            let k = gen::unit_mat(rng, n, D);
            let v = Mat::randn(n, D, 1.0, rng);
            (q, k, v)
        },
        |(q, k, v)| {
            ZOO.iter().all(|name| {
                let mut ctor = Rng::new(1);
                let attn = by_name(name, &mut ctor, D);
                let mut run = Rng::new(2);
                let out = attn.forward(q, k, v, &mut run);
                out.rows == q.rows
                    && out.cols == D
                    && out.data.iter().all(|x| x.is_finite())
            })
        },
    );
}

#[test]
fn workspace_bytes_monotone_in_n() {
    for name in ZOO {
        let mut ctor = Rng::new(3);
        let attn = by_name(name, &mut ctor, D);
        let mut prev = 0usize;
        for n in [16usize, 64, 256, 1024, 4096, 16384] {
            let ws = attn.workspace_bytes(n, D);
            assert!(
                ws >= prev,
                "{name}: workspace_bytes shrank going to n={n} ({prev} -> {ws})"
            );
            prev = ws;
        }
    }
}

#[test]
fn zoo_parallel_heads_bit_identical_to_serial() {
    // MultiHeadAttention on a pool vs the trait's serial default: same
    // fold_in(head) streams, so every variant (stochastic or not) must
    // produce identical bytes.
    let mut rng = Rng::new(11);
    let heads: Vec<HeadTask> = (0..4)
        .map(|_| HeadTask {
            q: Mat::randn(24, D, 1.0, &mut rng).unit_rows(),
            k: Mat::randn(24, D, 1.0, &mut rng).unit_rows(),
            v: Mat::randn(24, D, 1.0, &mut rng),
        })
        .collect();
    let base = Rng::new(999);
    let mh = MultiHeadAttention::new(Engine::new(4));
    for name in ZOO {
        let mut ctor = Rng::new(7);
        let attn: Arc<dyn Attention> = Arc::from(by_name(name, &mut ctor, D));
        let serial = attn.forward_batch(&heads, &base);
        let par = mh.forward_batch(&attn, heads.clone(), &base);
        assert_eq!(serial.len(), par.len(), "{name}");
        for (a, b) in serial.iter().zip(&par) {
            assert!(bits_equal(a, b), "{name}: parallel heads diverged");
        }
    }
}

#[test]
fn engine_one_thread_vs_many_identical_bytes() {
    let mut rng = Rng::new(4);
    let q = Mat::randn(80, D, 1.0, &mut rng).unit_rows();
    let k = Mat::randn(80, D, 1.0, &mut rng).unit_rows();
    let v = Mat::randn(80, D, 1.0, &mut rng);
    for (tau, m, fast) in [(6usize, 8usize, false), (4, 16, true)] {
        let att = YosoAttention::new(tau, m, fast);
        let seed_rng = Rng::new(31);
        let one = Engine::new(1).forward_yoso(&att, &q, &k, &v, &seed_rng);
        let many = Engine::new(8).forward_yoso(&att, &q, &k, &v, &seed_rng);
        assert!(
            bits_equal(&one, &many),
            "tau={tau} m={m} fast={fast}: thread count changed the bytes"
        );
    }
}
