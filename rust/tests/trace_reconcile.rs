//! The flight-recorder contract: the typed event stream both executors
//! emit is not advisory telemetry — it reconciles *exactly* with the
//! executor's own accounting, and it is schema-identical between the
//! live gateway and the simulator.
//!
//! * **sim reconciliation** — on randomized adversarial traces (both
//!   schedulers, ladder on/off, EDF admission on/off), every counter in
//!   `SimReport` equals the corresponding event count, per-seq
//!   lifecycles are complete (admitted = replied + expired, no seq
//!   twice), and the traced run's report is bit-identical to the
//!   untraced run — tracing never changes a scheduling decision;
//! * **live reconciliation** — the real gateway under an overload burst
//!   with a doomed-deadline slice: `GatewayStats` equals the event
//!   counts kind for kind, shed tag for shed tag, quality for quality,
//!   cache tag for cache tag;
//! * **schema identity** — the same request set through both executors
//!   produces identical per-seq event signatures (kind, quality, cache,
//!   shed, m', n), so the Chrome exporter and any downstream consumer
//!   run unchanged against either. Batch-scoped events and timing are
//!   executor-local (wall clock vs virtual ticks) and deliberately not
//!   compared.
//!
//! CI's scheduler-stress job sweeps this suite across `YOSO_KERNEL` and
//! `YOSO_TEST_THREADS` alongside the sim suite.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Duration;
use yoso::attention::{ChunkPolicy, KernelVariant};
use yoso::model::encoder::EncoderConfig;
use yoso::obs::{
    CacheTag, EventKind, QualityTag, ShedTag, TraceLog, TraceSink, NO_SEQ,
};
use yoso::serve::sim::{run, run_traced, Arrival, ServiceModel, SimConfig};
use yoso::serve::{
    BatchPolicy, BatchPolicyTable, BucketLayout, CpuServeConfig,
    DegradeLadder, Gateway, GatewayConfig, SchedPolicy, ShedPolicy,
};
use yoso::util::Rng;

fn ms(v: u64) -> Duration {
    Duration::from_millis(v)
}

fn us(v: u64) -> Duration {
    Duration::from_micros(v)
}

/// The schema-level identity of one event: everything executor-agnostic
/// (timing, worker index, and bucket width routing are executor-local).
type Sig = (EventKind, QualityTag, CacheTag, ShedTag, u32, u32);

/// Per-seq event signatures in drain order (drain sorts by tick, seq,
/// then lifecycle rank, so same-tick lifecycles stay in order).
fn per_seq_signatures(log: &TraceLog) -> BTreeMap<u64, Vec<Sig>> {
    let mut map: BTreeMap<u64, Vec<Sig>> = BTreeMap::new();
    for e in &log.events {
        if e.seq != NO_SEQ {
            map.entry(e.seq)
                .or_default()
                .push((e.kind, e.quality, e.cache, e.shed, e.m_eff, e.n));
        }
    }
    map
}

fn seqs_of(log: &TraceLog, kind: EventKind, shed: ShedTag) -> Vec<u64> {
    log.events
        .iter()
        .filter(|e| e.kind == kind && e.shed == shed)
        .map(|e| e.seq)
        .collect()
}

/// Asserts a seq list has no duplicates and returns it as a set.
fn unique(seqs: Vec<u64>, what: &str) -> BTreeSet<u64> {
    let n = seqs.len();
    let set: BTreeSet<u64> = seqs.into_iter().collect();
    assert_eq!(set.len(), n, "{what} carries a seq twice");
    set
}

fn tiny_cfg(seed: u64) -> CpuServeConfig {
    CpuServeConfig {
        attention: "yoso_8".into(),
        encoder: EncoderConfig {
            n_layers: 2,
            d_model: 64,
            n_heads: 2,
            d_ff: 128,
            vocab_size: 2005,
            max_len: 32,
            n_classes: 2,
        },
        threads: 1,
        chunk_policy: ChunkPolicy::default(),
        kernel: KernelVariant::from_env(),
        seed,
    }
}

#[test]
fn sim_event_stream_reconciles_with_the_report_on_random_traces() {
    let mut rng = Rng::new(0x0B5E);
    for case in 0..30u64 {
        let n = 15 + rng.below(50);
        let trace: Vec<Arrival> = (0..n)
            .map(|_| Arrival {
                at: us(rng.below(120_000) as u64),
                len: 1 + rng.below(60),
                deadline: (rng.below(4) == 0)
                    .then(|| ms(1 + rng.below(30) as u64)),
            })
            .collect();
        let replicas = 1 + rng.below(3);
        let capacity = 2 + rng.below(30); // small: queue-full sheds happen
        let base = BatchPolicy {
            max_batch: 1 + rng.below(6),
            max_wait: ms(rng.below(15) as u64),
        };
        let scaled = rng.below(2) == 0;
        let rungs = rng.below(3); // 0: ladder off, else rung count
        let lag = ms(rng.below(4) as u64);
        let admission_edf = rng.below(2) == 1;
        let overhead = us(100 + rng.below(1500) as u64);
        let per_width = us(1 + rng.below(40) as u64);
        for sched in [SchedPolicy::Conserve, SchedPolicy::Fifo] {
            let cfg = SimConfig {
                replicas,
                queue_capacity: capacity,
                sched,
                buckets: BucketLayout::pow2(8, 64),
                batch: if scaled {
                    BatchPolicyTable::scaled(base)
                } else {
                    BatchPolicyTable::uniform(base)
                },
                service: ServiceModel {
                    batch_overhead: overhead,
                    per_width,
                },
                degrade: match rungs {
                    0 => DegradeLadder::none(),
                    1 => DegradeLadder::steps(vec![(5, 8)])
                        .with_step_up_lag(lag),
                    _ => DegradeLadder::steps(vec![(3, 8), (10, 4)])
                        .with_step_up_lag(lag),
                },
                m_full: 16,
                admission_edf,
                ..SimConfig::default()
            };
            let sink =
                TraceSink::new(replicas + 1, TraceSink::DEFAULT_LANE_CAPACITY, 0);
            let report = run_traced(&cfg, &trace, Some(&sink));
            let log = sink.drain();
            assert_eq!(log.dropped, 0, "case {case}: ring overflowed");

            // every report counter equals its event count
            assert_eq!(log.count(EventKind::Admitted), report.accepted);
            assert_eq!(log.count(EventKind::Queued), report.accepted);
            assert_eq!(log.count(EventKind::Replied), report.completed);
            assert_eq!(log.count_shed(ShedTag::QueueFull), report.rejected);
            assert_eq!(
                log.count_shed(ShedTag::Infeasible),
                report.rejected_infeasible
            );
            assert_eq!(log.count_shed(ShedTag::Expired), report.shed_deadline);
            assert_eq!(log.count_shed(ShedTag::Closed), 0);
            let batches = report.batches.len() as u64;
            assert_eq!(log.count(EventKind::BatchFormed), batches);
            assert_eq!(log.count(EventKind::ExecStart), batches);
            assert_eq!(log.count(EventKind::ExecEnd), batches);
            assert_eq!(
                log.count_replied_quality(QualityTag::Degraded),
                report.served_degraded,
                "case {case}"
            );
            assert_eq!(
                log.count_replied_quality(QualityTag::Full),
                report.completed - report.served_degraded
            );
            assert_eq!(
                log.request_latencies_ms().len() as u64,
                report.completed
            );

            // per-seq lifecycle completeness: the admitted set is
            // partitioned by replies and in-queue expiries
            let admitted =
                unique(seqs_of(&log, EventKind::Admitted, ShedTag::Unspecified),
                    "Admitted");
            let replied =
                unique(seqs_of(&log, EventKind::Replied, ShedTag::Unspecified),
                    "Replied");
            let expired =
                unique(seqs_of(&log, EventKind::Shed, ShedTag::Expired),
                    "Shed(Expired)");
            assert!(replied.is_disjoint(&expired), "case {case}");
            let mut union = replied;
            union.extend(&expired);
            assert_eq!(union, admitted, "case {case}: a request leaked");
            assert!(report.reconciles(), "case {case}");

            // tracing is pure observation: the untraced run's report is
            // bit-identical, batch for batch
            let untraced = run(&cfg, &trace);
            assert_eq!(untraced.accepted, report.accepted);
            assert_eq!(untraced.rejected, report.rejected);
            assert_eq!(
                untraced.rejected_infeasible,
                report.rejected_infeasible
            );
            assert_eq!(untraced.shed_deadline, report.shed_deadline);
            assert_eq!(untraced.completed, report.completed);
            assert_eq!(untraced.goodput, report.goodput);
            assert_eq!(untraced.served_degraded, report.served_degraded);
            assert_eq!(untraced.latencies_ms, report.latencies_ms);
            let key = |b: &yoso::serve::sim::SimBatch| {
                (b.replica, b.bucket, b.width, b.m_eff, b.formed_at,
                 b.done_at, b.seqs.clone())
            };
            assert_eq!(
                untraced.batches.iter().map(key).collect::<Vec<_>>(),
                report.batches.iter().map(key).collect::<Vec<_>>(),
                "case {case}: tracing changed a scheduling decision"
            );
        }
    }
}

#[test]
fn live_gateway_event_stream_reconciles_with_stats() {
    let mut cfg = GatewayConfig::new(tiny_cfg(31));
    cfg.replicas = 1;
    cfg.queue_capacity = 4;
    cfg.shed = ShedPolicy::Reject;
    cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
    });
    cfg.buckets = BucketLayout::pow2(8, 32);
    cfg.trace = true;
    let gw = Gateway::spawn(cfg);
    let sink = gw.trace_sink().expect("trace was enabled");

    // a doomed slice first (queue is empty, so admission is certain):
    // zero deadlines always expire before execution
    let doomed: Vec<_> = (0..3)
        .map(|_| {
            gw.submitter()
                .submit_with_deadline(
                    vec![9i32; 12],
                    vec![0i32; 12],
                    Some(Duration::ZERO),
                )
                .expect("queue is empty at submit time")
        })
        .collect();
    // then an un-paced burst against the capacity-4 queue: most of it
    // sheds at admission (each shed must show up as a QueueFull event)
    let mut rxs = Vec::new();
    let mut client_rejected = 0u64;
    for i in 0..40usize {
        let len = 4 + (i * 5) % 24;
        match gw.submit(vec![7i32; len], vec![0i32; len]) {
            Ok(rx) => rxs.push(rx),
            Err(_) => client_rejected += 1,
        }
    }
    for rx in doomed {
        assert!(
            rx.recv().expect("shed is delivered").is_err(),
            "a zero-deadline request reached execution"
        );
    }
    let mut client_completed = 0u64;
    for rx in rxs {
        rx.recv().expect("one reply").expect("no deadline, must serve");
        client_completed += 1;
    }
    let stats = gw.shutdown();
    let log = sink.drain();
    assert_eq!(log.dropped, 0);

    assert_eq!(log.count(EventKind::Admitted), stats.accepted);
    assert_eq!(log.count(EventKind::Queued), stats.accepted);
    assert_eq!(log.count(EventKind::Replied), stats.completed);
    assert_eq!(stats.completed, client_completed);
    assert_eq!(log.count_shed(ShedTag::QueueFull), stats.rejected);
    assert_eq!(stats.rejected, client_rejected);
    assert_eq!(
        log.count_shed(ShedTag::Infeasible),
        stats.rejected_infeasible
    );
    assert_eq!(log.count_shed(ShedTag::Expired), stats.shed_deadline);
    assert_eq!(stats.shed_deadline, 3, "exactly the doomed slice");
    assert_eq!(log.count_shed(ShedTag::Closed), 0);
    assert_eq!(log.count(EventKind::BatchFormed), stats.batches);
    assert_eq!(log.count(EventKind::ExecStart), stats.batches);
    assert_eq!(log.count(EventKind::ExecEnd), stats.batches);
    assert_eq!(
        log.count_replied_quality(QualityTag::Full),
        stats.served_full
    );
    assert_eq!(
        log.count_replied_quality(QualityTag::Degraded),
        stats.served_degraded
    );
    // the default config runs the prefix cache, so every completion
    // carries a definite hit/miss tag
    assert_eq!(log.count_cache(CacheTag::Hit), stats.cache_hits);
    assert_eq!(log.count_cache(CacheTag::Miss), stats.cache_misses);
    assert_eq!(stats.cache_hits + stats.cache_misses, stats.completed);
    assert_eq!(log.request_latencies_ms().len() as u64, stats.completed);

    let admitted = unique(
        seqs_of(&log, EventKind::Admitted, ShedTag::Unspecified),
        "Admitted",
    );
    let replied = unique(
        seqs_of(&log, EventKind::Replied, ShedTag::Unspecified),
        "Replied",
    );
    let expired =
        unique(seqs_of(&log, EventKind::Shed, ShedTag::Expired), "Expired");
    assert!(replied.is_disjoint(&expired));
    let mut union = replied;
    union.extend(&expired);
    assert_eq!(union, admitted, "an accepted request left no final event");
}

#[test]
fn sim_and_live_per_request_streams_are_schema_identical() {
    // the same 12 requests through both executors. Ample capacity and
    // no deadlines keep every lifecycle on the happy path; the live
    // cache is disabled so reply events carry `Unspecified` cache tags
    // on both sides (the sim has no cache — the one live-only field).
    // Batch composition and timing differ between a wall clock and a
    // virtual one by design and are not part of the signature.
    let lens: Vec<usize> = (0..12).map(|i| 4 + (i * 3) % 24).collect();

    let sim_cfg = SimConfig {
        replicas: 2,
        queue_capacity: 64,
        sched: SchedPolicy::Conserve,
        buckets: BucketLayout::pow2(8, 32),
        batch: BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 4,
            max_wait: ms(1),
        }),
        service: ServiceModel { batch_overhead: ms(1), per_width: us(10) },
        degrade: DegradeLadder::none(),
        m_full: 8,
        admission_edf: false,
        ..SimConfig::default()
    };
    let trace: Vec<Arrival> = lens
        .iter()
        .enumerate()
        .map(|(i, &len)| Arrival { at: ms(2 * i as u64), len, deadline: None })
        .collect();
    let sink = TraceSink::new(3, TraceSink::DEFAULT_LANE_CAPACITY, 0);
    let report = run_traced(&sim_cfg, &trace, Some(&sink));
    assert_eq!(report.completed, 12);
    let sim_log = sink.drain();

    let mut cfg = GatewayConfig::new(tiny_cfg(37));
    cfg.replicas = 2;
    cfg.queue_capacity = 64;
    cfg.shed = ShedPolicy::Reject;
    cfg.sched = SchedPolicy::Conserve;
    cfg.batch = BatchPolicyTable::uniform(BatchPolicy {
        max_batch: 4,
        max_wait: ms(1),
    });
    cfg.buckets = BucketLayout::pow2(8, 32);
    cfg.prefix_cache_bytes = 0;
    cfg.trace = true;
    let gw = Gateway::spawn(cfg);
    let sink = gw.trace_sink().expect("trace was enabled");
    let rxs: Vec<_> = lens
        .iter()
        .map(|&len| {
            gw.submit(vec![5i32; len], vec![0i32; len])
                .expect("capacity is ample")
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("one reply").expect("served");
    }
    let stats = gw.shutdown();
    assert_eq!(stats.completed, 12);
    let live_log = sink.drain();

    let sim_sigs = per_seq_signatures(&sim_log);
    let live_sigs = per_seq_signatures(&live_log);
    assert_eq!(sim_sigs.len(), 12);
    assert_eq!(
        sim_sigs, live_sigs,
        "per-request event signatures diverged between executors"
    );
    // and the shared shape is the full happy-path lifecycle, served at
    // the configured m (yoso_8 -> 8 rounds), tagged best-effort at
    // admission and full at reply
    for (seq, sig) in &sim_sigs {
        let n = lens[*seq as usize] as u32;
        let expect: Vec<Sig> = vec![
            (
                EventKind::Admitted,
                QualityTag::BestEffort,
                CacheTag::Unspecified,
                ShedTag::Unspecified,
                0,
                n,
            ),
            (
                EventKind::Queued,
                QualityTag::BestEffort,
                CacheTag::Unspecified,
                ShedTag::Unspecified,
                0,
                n,
            ),
            (
                EventKind::Replied,
                QualityTag::Full,
                CacheTag::Unspecified,
                ShedTag::Unspecified,
                8,
                0,
            ),
        ];
        assert_eq!(sig, &expect, "seq {seq}");
    }
}
