//! Offline stand-in for the `anyhow` crate.
//!
//! The build container has no crates.io access, so this path dependency
//! provides the subset of anyhow the coordinator uses: `Result`,
//! a message-chain `Error`, the `Context` trait for `Result`/`Option`,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Semantics match the
//! real crate for these uses; swap the manifest entry for the registry
//! version when building online.

use std::fmt;

/// Error: an owned message plus an optional chain of causes.
///
/// Like the real anyhow, this type deliberately does NOT implement
/// `std::error::Error`, so the blanket `From<E: std::error::Error>`
/// below does not conflict with the reflexive `From<Error>`.
pub struct Error {
    msg: String,
    source: Option<Box<Error>>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: c.to_string(), source: Some(Box::new(self)) }
    }

    /// The causal chain, outermost first.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.source.as_deref();
        }
        out
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur = self.source.as_deref();
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {}", e.msg)?;
            cur = e.source.as_deref();
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // keep the std source chain visible in the message chain
        let mut msgs = Vec::new();
        let mut cur = e.source();
        while let Some(s) = cur {
            msgs.push(s.to_string());
            cur = s.source();
        }
        let mut chain: Option<Box<Error>> = None;
        for m in msgs.into_iter().rev() {
            chain = Some(Box::new(Error { msg: m, source: chain }));
        }
        Error { msg: e.to_string(), source: chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with a defaulted error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an `Error` from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert a condition, early-returning an error when it fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::Other, "disk on fire"))
    }

    #[test]
    fn context_chains() {
        let e = io_err().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        let chain = e.chain();
        assert!(chain.len() >= 2, "{chain:?}");
        assert!(chain[1].contains("disk on fire"));
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(5).context("unused").unwrap(), 5);
    }

    #[test]
    fn macros() {
        fn fails(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {}", x);
            }
            Ok(x)
        }
        assert_eq!(fails(3).unwrap(), 3);
        assert_eq!(fails(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(fails(7).unwrap_err().to_string(), "unlucky 7");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }
}
