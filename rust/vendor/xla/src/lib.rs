//! Host-side stub of the `xla` (PJRT) bindings used by the coordinator.
//!
//! The build container has no crates.io registry and no XLA shared
//! library, so this path dependency keeps the crate buildable and the
//! pure-Rust paths fully testable:
//!
//! * `Literal` is a REAL host implementation (typed storage + shape,
//!   scalar/vec1/reshape/to_vec/tuples) — the runtime literal tests and
//!   every host-side marshaling path work unchanged.
//! * `PjRtClient::compile` is GATED: it returns a descriptive error
//!   because no PJRT backend is linked. Artifact-driven paths already
//!   skip gracefully when `artifacts/` is absent; with artifacts present
//!   they fail with this message instead of segfaulting.
//!
//! Swap this path dependency for the real `xla` crate (same API subset)
//! to execute HLO artifacts.

use std::fmt;

/// Stub error type; converts into `anyhow::Error` at call sites via the
/// std `Error` impl.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn new(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the coordinator marshals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

/// Typed storage behind a literal (public only because `NativeType`
/// mentions it; construct literals via `scalar`/`vec1`/`tuple`).
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Storage {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host tensor literal: typed flat data + dims, or a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

/// Types storable in a `Literal`.
pub trait NativeType: Copy {
    fn element_type() -> ElementType;
    fn wrap(data: Vec<Self>) -> Storage;
    fn read(storage: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn element_type() -> ElementType {
        ElementType::F32
    }

    fn wrap(data: Vec<f32>) -> Storage {
        Storage::F32(data)
    }

    fn read(storage: &Storage) -> Option<Vec<f32>> {
        match storage {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn element_type() -> ElementType {
        ElementType::S32
    }

    fn wrap(data: Vec<i32>) -> Storage {
        Storage::I32(data)
    }

    fn read(storage: &Storage) -> Option<Vec<i32>> {
        match storage {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { storage: T::wrap(vec![v]), dims: Vec::new() }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            storage: T::wrap(data.to_vec()),
            dims: vec![data.len() as i64],
        }
    }

    /// Tuple literal (what executables return).
    pub fn tuple(elements: Vec<Literal>) -> Literal {
        Literal { storage: Storage::Tuple(elements), dims: Vec::new() }
    }

    /// Total element count (tuples: number of elements).
    pub fn element_count(&self) -> usize {
        match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(v) => v.len(),
        }
    }

    /// Same data, new dims; errors when the element count differs.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.storage, Storage::Tuple(_)) {
            return Err(Error::new("cannot reshape a tuple literal"));
        }
        let count: i64 = dims.iter().product();
        if count as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.dims, dims
            )));
        }
        Ok(Literal { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Copy the flat data out as `Vec<T>`; errors on dtype mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::read(&self.storage).ok_or_else(|| {
            Error::new(format!(
                "to_vec: literal is not {:?}",
                T::element_type()
            ))
        })
    }

    /// Decompose a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(v) => Ok(v),
            _ => Err(Error::new("to_tuple on a non-tuple literal")),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module. The stub holds the raw text so parse errors (file
/// missing/unreadable) surface exactly where the real binding fails.
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::new(format!("reading HLO text {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation wrapper; carries the module name for error messages.
pub struct XlaComputation {
    name: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        // first token of "HloModule <name>, ..." when present
        let name = proto
            .text
            .lines()
            .next()
            .and_then(|l| l.split_whitespace().nth(1))
            .unwrap_or("unnamed")
            .trim_end_matches(',')
            .to_string();
        XlaComputation { name }
    }
}

/// PJRT client handle. `cpu()` succeeds (the host is always present) but
/// reports zero devices; compilation is where the stub gates.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "host-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::new(format!(
            "no PJRT backend linked in this build; cannot compile module \
             {:?}. Use the pure-Rust attention/encoder paths, or rebuild \
             with the real `xla` crate in rust/Cargo.toml.",
            comp.name
        )))
    }
}

/// Device buffer returned by an execution.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Loaded executable. Unreachable through the stub client (compile gates
/// first); `execute` is implemented for API completeness.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new("no PJRT backend linked in this build"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn scalars_and_tuples() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.dims().len(), 0);
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        let t = Literal::tuple(vec![s.clone(), Literal::scalar(1.5f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(s.to_tuple().is_err());
    }

    #[test]
    fn client_gates_at_compile() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 0);
        let proto = HloModuleProto { text: "HloModule toy, x=1".into() };
        let comp = XlaComputation::from_proto(&proto);
        let err = client.compile(&comp).unwrap_err().to_string();
        assert!(err.contains("toy"), "{err}");
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
    }
}
