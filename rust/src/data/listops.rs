//! ListOps generator + evaluator (Nangia & Bowman, 2018) — the real
//! grammar used by LRA's ListOps task, scaled to our sequence budget.
//!
//! Expressions: `[OP a b c ...]` where OP in {MAX, MIN, MED, SM} (SM =
//! sum mod 10) and operands are digits 0-9 or nested expressions. The
//! label is the value of the root expression (10-way classification).

use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Max,
    Min,
    Med,
    Sm,
}

impl Op {
    fn apply(&self, args: &[u8]) -> u8 {
        assert!(!args.is_empty());
        match self {
            Op::Max => *args.iter().max().unwrap(),
            Op::Min => *args.iter().min().unwrap(),
            Op::Med => {
                let mut s = args.to_vec();
                s.sort_unstable();
                s[s.len() / 2]
            }
            Op::Sm => (args.iter().map(|&x| x as u32).sum::<u32>() % 10) as u8,
        }
    }
}

/// Token alphabet for the encoded sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Token {
    Digit(u8),
    Open(Op),
    Close,
}

impl Token {
    /// Stable small token ids (offset by the caller's tokenizer).
    pub fn id(&self) -> u32 {
        match self {
            Token::Digit(d) => *d as u32,            // 0..10
            Token::Open(Op::Max) => 10,
            Token::Open(Op::Min) => 11,
            Token::Open(Op::Med) => 12,
            Token::Open(Op::Sm) => 13,
            Token::Close => 14,
        }
    }

    pub const ALPHABET: usize = 15;
}

pub struct ListOpsConfig {
    pub max_depth: usize,
    pub max_args: usize,
    /// hard cap on emitted tokens; generation truncates nesting to fit
    pub max_tokens: usize,
}

impl Default for ListOpsConfig {
    fn default() -> Self {
        ListOpsConfig { max_depth: 6, max_args: 5, max_tokens: 200 }
    }
}

/// Generate an expression; returns (tokens, value). The recursive
/// generator can overshoot `max_tokens` slightly (each pending parent
/// still emits its remaining args and `]`), so we retry until the budget
/// holds — label/token consistency is never compromised by truncation.
pub fn generate(cfg: &ListOpsConfig, rng: &mut Rng) -> (Vec<Token>, u8) {
    for _ in 0..32 {
        let mut tokens = Vec::new();
        let value = gen_expr(cfg, rng, cfg.max_depth, &mut tokens);
        if tokens.len() <= cfg.max_tokens {
            return (tokens, value);
        }
    }
    // pathological budget: a bare digit is always valid
    let d = rng.below(10) as u8;
    (vec![Token::Digit(d)], d)
}

fn gen_expr(cfg: &ListOpsConfig, rng: &mut Rng, depth: usize, out: &mut Vec<Token>) -> u8 {
    let budget_left = cfg.max_tokens.saturating_sub(out.len());
    if depth == 0 || budget_left < 8 || rng.bernoulli(0.35) {
        let d = rng.below(10) as u8;
        out.push(Token::Digit(d));
        return d;
    }
    let op = match rng.below(4) {
        0 => Op::Max,
        1 => Op::Min,
        2 => Op::Med,
        _ => Op::Sm,
    };
    out.push(Token::Open(op));
    let n_args = rng.range(2, cfg.max_args + 1);
    let mut vals = Vec::with_capacity(n_args);
    for _ in 0..n_args {
        vals.push(gen_expr(cfg, rng, depth - 1, out));
    }
    out.push(Token::Close);
    op.apply(&vals)
}

/// Reference evaluator over a token stream (used to cross-check the
/// generator — parses the prefix encoding back).
pub fn evaluate(tokens: &[Token]) -> Option<u8> {
    let mut pos = 0usize;
    let v = eval_at(tokens, &mut pos)?;
    if pos == tokens.len() {
        Some(v)
    } else {
        None
    }
}

fn eval_at(tokens: &[Token], pos: &mut usize) -> Option<u8> {
    match tokens.get(*pos)? {
        Token::Digit(d) => {
            *pos += 1;
            Some(*d)
        }
        Token::Open(op) => {
            let op = *op;
            *pos += 1;
            let mut args = Vec::new();
            loop {
                match tokens.get(*pos)? {
                    Token::Close => {
                        *pos += 1;
                        return if args.is_empty() { None } else { Some(op.apply(&args)) };
                    }
                    _ => args.push(eval_at(tokens, pos)?),
                }
            }
        }
        Token::Close => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_semantics() {
        assert_eq!(Op::Max.apply(&[1, 5, 3]), 5);
        assert_eq!(Op::Min.apply(&[1, 5, 3]), 1);
        assert_eq!(Op::Med.apply(&[1, 5, 3]), 3);
        assert_eq!(Op::Sm.apply(&[7, 8]), 5);
    }

    #[test]
    fn generator_value_matches_evaluator() {
        let cfg = ListOpsConfig::default();
        let mut rng = Rng::new(0);
        for _ in 0..200 {
            let (tokens, value) = generate(&cfg, &mut rng);
            assert_eq!(evaluate(&tokens), Some(value));
        }
    }

    #[test]
    fn respects_token_budget() {
        let cfg = ListOpsConfig { max_tokens: 64, ..Default::default() };
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let (tokens, value) = generate(&cfg, &mut rng);
            assert!(tokens.len() <= 64, "{}", tokens.len());
            assert_eq!(evaluate(&tokens), Some(value));
        }
    }

    #[test]
    fn labels_cover_all_digits() {
        let cfg = ListOpsConfig::default();
        let mut rng = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..500 {
            let (_, v) = generate(&cfg, &mut rng);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }

    #[test]
    fn evaluate_rejects_malformed() {
        assert_eq!(evaluate(&[Token::Close]), None);
        assert_eq!(evaluate(&[Token::Open(Op::Max), Token::Close]), None);
        assert_eq!(
            evaluate(&[Token::Digit(1), Token::Digit(2)]),
            None // trailing tokens
        );
    }
}
