//! Data substrate: synthetic corpus ("synthlang"), tokenizer, MLM/SOP
//! batch construction, synthetic GLUE-style tasks, and the LRA-style
//! long-sequence task suite (including a real ListOps generator).
//!
//! Substitution note (DESIGN.md): the paper pretrains on BookCorpus +
//! Wikipedia and evaluates on GLUE/LRA. Those corpora are not available
//! here; each generator below synthesizes a task with the same *shape*
//! (sequence statistics, label structure, learnable signal) so that the
//! relative ordering of attention variants — what the paper's tables
//! test — is preserved.

pub mod corpus;
pub mod glue_synth;
pub mod listops;
pub mod lra;
pub mod mlm;
pub mod tokenizer;

/// Special token ids shared by all vocabularies.
pub mod special {
    pub const PAD: i32 = 0;
    pub const CLS: i32 = 1;
    pub const SEP: i32 = 2;
    pub const MASK: i32 = 3;
    pub const UNK: i32 = 4;
    /// First id available for real tokens.
    pub const FIRST_WORD: i32 = 5;
}

/// A classification example: token ids + segment ids + label.
#[derive(Clone, Debug)]
pub struct ClsExample {
    pub input_ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    pub label: i32,
}

/// A pretraining example: masked ids, MLM labels (-1 = unmasked), SOP label.
#[derive(Clone, Debug)]
pub struct PretrainExample {
    pub input_ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    pub mlm_labels: Vec<i32>,
    pub sop_label: i32,
}

/// Batches are struct-of-arrays matching the artifact ABI.
#[derive(Clone, Debug, Default)]
pub struct ClsBatch {
    pub input_ids: Vec<i32>,   // (b * n)
    pub segment_ids: Vec<i32>, // (b * n)
    pub labels: Vec<i32>,      // (b)
    pub batch: usize,
    pub seq_len: usize,
}

#[derive(Clone, Debug, Default)]
pub struct PretrainBatch {
    pub input_ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    pub mlm_labels: Vec<i32>,
    pub sop_labels: Vec<i32>,
    pub batch: usize,
    pub seq_len: usize,
}

pub fn collate_cls(examples: &[ClsExample], seq_len: usize) -> ClsBatch {
    let b = examples.len();
    let mut out = ClsBatch {
        input_ids: Vec::with_capacity(b * seq_len),
        segment_ids: Vec::with_capacity(b * seq_len),
        labels: Vec::with_capacity(b),
        batch: b,
        seq_len,
    };
    for ex in examples {
        push_padded(&mut out.input_ids, &ex.input_ids, seq_len, special::PAD);
        push_padded(&mut out.segment_ids, &ex.segment_ids, seq_len, 0);
        out.labels.push(ex.label);
    }
    out
}

pub fn collate_pretrain(examples: &[PretrainExample], seq_len: usize) -> PretrainBatch {
    let b = examples.len();
    let mut out = PretrainBatch {
        input_ids: Vec::with_capacity(b * seq_len),
        segment_ids: Vec::with_capacity(b * seq_len),
        mlm_labels: Vec::with_capacity(b * seq_len),
        sop_labels: Vec::with_capacity(b),
        batch: b,
        seq_len,
    };
    for ex in examples {
        push_padded(&mut out.input_ids, &ex.input_ids, seq_len, special::PAD);
        push_padded(&mut out.segment_ids, &ex.segment_ids, seq_len, 0);
        push_padded(&mut out.mlm_labels, &ex.mlm_labels, seq_len, -1);
        out.sop_labels.push(ex.sop_label);
    }
    out
}

fn push_padded(dst: &mut Vec<i32>, src: &[i32], len: usize, pad: i32) {
    dst.extend(src.iter().take(len));
    for _ in src.len()..len {
        dst.push(pad);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collate_pads_and_truncates() {
        let ex = ClsExample {
            input_ids: vec![1, 2, 3],
            segment_ids: vec![0, 0, 0],
            label: 1,
        };
        let b = collate_cls(&[ex.clone(), ex], 5);
        assert_eq!(b.input_ids.len(), 10);
        assert_eq!(&b.input_ids[..5], &[1, 2, 3, special::PAD, special::PAD]);

        let long = ClsExample {
            input_ids: (0..10).collect(),
            segment_ids: vec![0; 10],
            label: 0,
        };
        let b2 = collate_cls(&[long], 4);
        assert_eq!(b2.input_ids, vec![0, 1, 2, 3]);
    }
}
