//! LRA-style long-sequence task suite (Tay et al., 2021), synthesized at
//! our sequence budget (n = 256, vocab = 256, 10-way max) — see DESIGN.md
//! for the substitution rationale. Five tasks mirroring the benchmark:
//!
//! * `listops`    — real ListOps grammar (see `listops.rs`), 10 classes.
//! * `text`       — byte-level "sentiment": class-dependent byte-bigram
//!                  distributions, 2 classes.
//! * `retrieval`  — document matching: two byte docs, same-source or not,
//!                  packed as a segment pair, 2 classes.
//! * `image`      — 16x16 grayscale procedural patterns (oriented
//!                  gratings), pixel sequence, 10 classes.
//! * `pathfinder` — 16x16 grid: are the two endpoints connected by the
//!                  drawn path? 2 classes.

use super::listops::{generate as gen_listops, ListOpsConfig, Token};
use super::special;
use super::tokenizer::{build_input, ByteTokenizer};
use super::ClsExample;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LraTask {
    ListOps,
    Text,
    Retrieval,
    Image,
    Pathfinder,
}

impl LraTask {
    pub fn all() -> [LraTask; 5] {
        [LraTask::ListOps, LraTask::Text, LraTask::Retrieval, LraTask::Image,
         LraTask::Pathfinder]
    }

    pub fn name(&self) -> &'static str {
        match self {
            LraTask::ListOps => "listops",
            LraTask::Text => "text",
            LraTask::Retrieval => "retrieval",
            LraTask::Image => "image",
            LraTask::Pathfinder => "pathfinder",
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            LraTask::ListOps | LraTask::Image => 10,
            _ => 2,
        }
    }
}

pub struct LraGenerator {
    pub task: LraTask,
    pub seq_len: usize,
    base: Rng,
    tok: ByteTokenizer,
}

const GRID: usize = 16;

impl LraGenerator {
    pub fn new(task: LraTask, seq_len: usize, seed: u64) -> Self {
        LraGenerator { task, seq_len, base: Rng::new(seed), tok: ByteTokenizer { vocab: 256 } }
    }

    pub fn example(&self, index: u64) -> ClsExample {
        let mut rng = self.base.fold_in(index);
        match self.task {
            LraTask::ListOps => self.listops(&mut rng),
            LraTask::Text => self.text(&mut rng),
            LraTask::Retrieval => self.retrieval(&mut rng),
            LraTask::Image => self.image(&mut rng),
            LraTask::Pathfinder => self.pathfinder(&mut rng),
        }
    }

    pub fn batch(&self, start: u64, b: usize) -> super::ClsBatch {
        let ex: Vec<_> = (0..b).map(|i| self.example(start + i as u64)).collect();
        super::collate_cls(&ex, self.seq_len)
    }

    fn listops(&self, rng: &mut Rng) -> ClsExample {
        let cfg = ListOpsConfig {
            max_tokens: self.seq_len - 8,
            ..Default::default()
        };
        let (tokens, value) = gen_listops(&cfg, rng);
        let ids: Vec<i32> = tokens
            .iter()
            .map(|t| t.id() as i32 + special::FIRST_WORD)
            .collect();
        debug_assert!(Token::ALPHABET + special::FIRST_WORD as usize <= 256);
        let (input_ids, segment_ids) = build_input(&ids, None, self.seq_len);
        ClsExample { input_ids, segment_ids, label: value as i32 }
    }

    /// Class-dependent byte-bigram "language": class c biases transitions
    /// toward (prev * (3 + c)) % 200.
    fn class_bytes(&self, rng: &mut Rng, class: usize, len: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(len);
        let mut prev: u8 = rng.below(200) as u8;
        for _ in 0..len {
            let next = if rng.bernoulli(0.6) {
                ((prev as usize * (3 + class) + 1) % 200) as u8
            } else {
                rng.below(200) as u8
            };
            out.push(next);
            prev = next;
        }
        out
    }

    fn text(&self, rng: &mut Rng) -> ClsExample {
        let class = rng.below(2);
        let bytes = self.class_bytes(rng, class, self.seq_len - 2);
        let ids = self.tok.encode(&bytes);
        let (input_ids, segment_ids) = build_input(&ids, None, self.seq_len);
        ClsExample { input_ids, segment_ids, label: class as i32 }
    }

    fn retrieval(&self, rng: &mut Rng) -> ClsExample {
        let same = rng.bernoulli(0.5);
        let class_a = rng.below(8);
        let class_b = if same { class_a } else { (class_a + 1 + rng.below(7)) % 8 };
        let half = (self.seq_len - 3) / 2;
        let a = self.class_bytes(rng, class_a, half);
        let b = self.class_bytes(rng, class_b, half);
        let (input_ids, segment_ids) = build_input(
            &self.tok.encode(&a),
            Some(&self.tok.encode(&b)),
            self.seq_len,
        );
        ClsExample { input_ids, segment_ids, label: same as i32 }
    }

    /// Oriented sinusoidal grating; class determines frequency+angle.
    fn image(&self, rng: &mut Rng) -> ClsExample {
        let class = rng.below(10);
        let angle = class as f32 * std::f32::consts::PI / 10.0;
        let freq = 0.5 + (class % 5) as f32 * 0.35;
        let phase = rng.uniform() * std::f32::consts::TAU;
        let mut bytes = Vec::with_capacity(GRID * GRID);
        for y in 0..GRID {
            for x in 0..GRID {
                let u = x as f32 * angle.cos() + y as f32 * angle.sin();
                let val = ((u * freq + phase).sin() * 0.5 + 0.5) * 200.0
                    + rng.normal() * 10.0;
                bytes.push(val.clamp(0.0, 199.0) as u8);
            }
        }
        let ids = self.tok.encode(&bytes);
        let (input_ids, segment_ids) = build_input(&ids, None, self.seq_len);
        ClsExample { input_ids, segment_ids, label: class as i32 }
    }

    /// Random-walk path rendering; positive = endpoints on one path.
    fn pathfinder(&self, rng: &mut Rng) -> ClsExample {
        let mut grid = [[0u8; GRID]; GRID];
        let connected = rng.bernoulli(0.5);

        let walk = |grid: &mut [[u8; GRID]; GRID], rng: &mut Rng, steps: usize| {
            let mut x = rng.below(GRID);
            let mut y = rng.below(GRID);
            let start = (x, y);
            for _ in 0..steps {
                grid[y][x] = 1;
                match rng.below(4) {
                    0 if x + 1 < GRID => x += 1,
                    1 if x > 0 => x -= 1,
                    2 if y + 1 < GRID => y += 1,
                    _ if y > 0 => y -= 1,
                    _ => {}
                }
            }
            grid[y][x] = 1;
            (start, (x, y))
        };

        let (e1, e2) = if connected {
            let (a, b) = walk(&mut grid, rng, 40);
            (a, b)
        } else {
            let (a, _) = walk(&mut grid, rng, 18);
            // second, disjoint-ish walk; endpoints from different walks
            let (_, b) = walk(&mut grid, rng, 18);
            (a, b)
        };
        // mark endpoints with a distinct intensity
        grid[e1.1][e1.0] = 2;
        grid[e2.1][e2.0] = 2;

        let bytes: Vec<u8> = grid
            .iter()
            .flat_map(|row| row.iter().map(|&c| c * 90))
            .collect();
        let ids = self.tok.encode(&bytes);
        let (input_ids, segment_ids) = build_input(&ids, None, self.seq_len);
        ClsExample { input_ids, segment_ids, label: connected as i32 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_valid_shapes_and_labels() {
        for task in LraTask::all() {
            let g = LraGenerator::new(task, 256, 1);
            for i in 0..10 {
                let ex = g.example(i);
                assert!(ex.input_ids.len() <= 256, "{task:?}");
                assert_eq!(ex.input_ids.len(), ex.segment_ids.len());
                assert!((ex.label as usize) < task.n_classes(), "{task:?}");
                assert!(ex.input_ids.iter().all(|&t| (0..256).contains(&t)),
                        "{task:?}");
            }
        }
    }

    #[test]
    fn deterministic_by_index() {
        for task in LraTask::all() {
            let g = LraGenerator::new(task, 256, 2);
            assert_eq!(g.example(5).input_ids, g.example(5).input_ids);
        }
    }

    #[test]
    fn image_fills_sequence() {
        let g = LraGenerator::new(LraTask::Image, 256, 3);
        let ex = g.example(0);
        // 16x16 pixels fill most of the 256 budget (+CLS/SEP, truncated)
        assert!(ex.input_ids.len() >= 250);
    }

    #[test]
    fn retrieval_has_two_segments() {
        let g = LraGenerator::new(LraTask::Retrieval, 256, 4);
        assert!(g.example(0).segment_ids.contains(&1));
    }

    #[test]
    fn labels_balanced_binary_tasks() {
        for task in [LraTask::Text, LraTask::Retrieval, LraTask::Pathfinder] {
            let g = LraGenerator::new(task, 256, 5);
            let pos = (0..200).filter(|&i| g.example(i).label == 1).count();
            assert!((60..140).contains(&pos), "{task:?}: {pos}");
        }
    }

    #[test]
    fn batch_abi_shape() {
        let g = LraGenerator::new(LraTask::ListOps, 256, 6);
        let b = g.batch(0, 8);
        assert_eq!(b.input_ids.len(), 8 * 256);
        assert_eq!(b.labels.len(), 8);
    }
}
