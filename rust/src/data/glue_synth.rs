//! Synthetic GLUE-style downstream tasks (substitute for MRPC, SST-2,
//! QNLI, QQP, MNLI — see DESIGN.md).
//!
//! Each task reuses the pretraining vocabulary/corpus so fine-tuning from
//! a pretrained checkpoint measures exactly what Table 2 measures: does
//! the attention approximation hurt transfer? Tasks:
//!
//! * `mrpc` / `qqp` — paraphrase detection: pair (s, s') where s' is a
//!   light perturbation of s (positive) or an unrelated sentence
//!   (negative).
//! * `sst2`  — "sentiment": the sentence's topic block determines the
//!   label (topic blocks act as sentiment lexica).
//! * `qnli`  — question/answer relevance: pair shares topic or not.
//! * `mnli`  — 3-way: paraphrase / same-topic / unrelated.

use super::corpus::{CorpusConfig, CorpusGenerator};
use super::tokenizer::{build_input, WordTokenizer};
use super::ClsExample;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GlueTask {
    Mrpc,
    Sst2,
    Qnli,
    Qqp,
    Mnli,
}

impl GlueTask {
    pub fn all() -> [GlueTask; 5] {
        [GlueTask::Mrpc, GlueTask::Sst2, GlueTask::Qnli, GlueTask::Qqp, GlueTask::Mnli]
    }

    pub fn name(&self) -> &'static str {
        match self {
            GlueTask::Mrpc => "mrpc",
            GlueTask::Sst2 => "sst2",
            GlueTask::Qnli => "qnli",
            GlueTask::Qqp => "qqp",
            GlueTask::Mnli => "mnli",
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            GlueTask::Mnli => 3,
            _ => 2,
        }
    }

    /// F1 is reported for MRPC/QQP in the paper; accuracy elsewhere.
    pub fn metric(&self) -> &'static str {
        match self {
            GlueTask::Mrpc | GlueTask::Qqp => "f1",
            _ => "accuracy",
        }
    }
}

pub struct GlueGenerator {
    gen: CorpusGenerator,
    tok: WordTokenizer,
    pub seq_len: usize,
    base: Rng,
    task: GlueTask,
}

impl GlueGenerator {
    pub fn new(task: GlueTask, seq_len: usize, seed: u64) -> Self {
        let cfg = CorpusConfig::default();
        let n_words = cfg.vocab_words;
        GlueGenerator {
            gen: CorpusGenerator::new(cfg),
            tok: WordTokenizer { n_words },
            seq_len,
            base: Rng::new(seed),
            task,
        }
    }

    /// Perturb ~20% of tokens to build a paraphrase.
    fn perturb(&self, s: &[u32], rng: &mut Rng) -> Vec<u32> {
        s.iter()
            .map(|&w| {
                if rng.bernoulli(0.2) {
                    self.gen.succ(w)
                } else {
                    w
                }
            })
            .collect()
    }

    pub fn example(&self, index: u64) -> ClsExample {
        let mut rng = self.base.fold_in(index);
        let topic = rng.below(16);
        let s1 = self.gen.sentence(&mut rng, topic);
        match self.task {
            GlueTask::Sst2 => {
                // label = topic parity: a topic-lexicon signal
                let label = (topic % 2) as i32;
                let ids = self.tok.encode(&s1);
                let (input_ids, segment_ids) = build_input(&ids, None, self.seq_len);
                ClsExample { input_ids, segment_ids, label }
            }
            GlueTask::Mrpc | GlueTask::Qqp => {
                let positive = rng.bernoulli(0.5);
                let s2 = if positive {
                    self.perturb(&s1, &mut rng)
                } else {
                    let other_topic = rng.below(16);
                    self.gen.sentence(&mut rng, other_topic)
                };
                let (input_ids, segment_ids) = build_input(
                    &self.tok.encode(&s1),
                    Some(&self.tok.encode(&s2)),
                    self.seq_len,
                );
                ClsExample { input_ids, segment_ids, label: positive as i32 }
            }
            GlueTask::Qnli => {
                let related = rng.bernoulli(0.5);
                let s2 = if related {
                    self.gen.sentence(&mut rng, topic)
                } else {
                    self.gen.sentence(&mut rng, (topic + 8) % 16)
                };
                let (input_ids, segment_ids) = build_input(
                    &self.tok.encode(&s1),
                    Some(&self.tok.encode(&s2)),
                    self.seq_len,
                );
                ClsExample { input_ids, segment_ids, label: related as i32 }
            }
            GlueTask::Mnli => {
                let class = rng.below(3) as i32;
                let s2 = match class {
                    0 => self.perturb(&s1, &mut rng),                     // entail
                    1 => self.gen.sentence(&mut rng, topic),              // neutral
                    _ => self.gen.sentence(&mut rng, (topic + 8) % 16),   // contra
                };
                let (input_ids, segment_ids) = build_input(
                    &self.tok.encode(&s1),
                    Some(&self.tok.encode(&s2)),
                    self.seq_len,
                );
                ClsExample { input_ids, segment_ids, label: class }
            }
        }
    }

    pub fn batch(&self, start: u64, b: usize) -> super::ClsBatch {
        let ex: Vec<_> = (0..b).map(|i| self.example(start + i as u64)).collect();
        super::collate_cls(&ex, self.seq_len)
    }
}

/// F1 score for binary predictions (positive class = 1).
pub fn f1_score(preds: &[i32], labels: &[i32]) -> f64 {
    let mut tp = 0.0;
    let mut fp = 0.0;
    let mut fn_ = 0.0;
    for (&p, &l) in preds.iter().zip(labels) {
        match (p, l) {
            (1, 1) => tp += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fn_ += 1.0,
            _ => {}
        }
    }
    if tp == 0.0 {
        return 0.0;
    }
    let precision = tp / (tp + fp);
    let recall = tp / (tp + fn_);
    2.0 * precision * recall / (precision + recall)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_examples() {
        for task in GlueTask::all() {
            let g = GlueGenerator::new(task, 128, 3);
            for i in 0..20 {
                let ex = g.example(i);
                assert!(ex.input_ids.len() <= 128, "{task:?}");
                assert!((ex.label as usize) < task.n_classes(), "{task:?}");
                assert_eq!(ex.input_ids.len(), ex.segment_ids.len());
            }
        }
    }

    #[test]
    fn examples_deterministic() {
        let g = GlueGenerator::new(GlueTask::Mrpc, 128, 5);
        assert_eq!(g.example(9).input_ids, g.example(9).input_ids);
    }

    #[test]
    fn pair_tasks_have_two_segments() {
        let g = GlueGenerator::new(GlueTask::Qqp, 128, 5);
        let ex = g.example(0);
        assert!(ex.segment_ids.contains(&1));
        let g2 = GlueGenerator::new(GlueTask::Sst2, 128, 5);
        assert!(!g2.example(0).segment_ids.contains(&1));
    }

    #[test]
    fn labels_balanced() {
        let g = GlueGenerator::new(GlueTask::Qnli, 128, 5);
        let pos = (0..200).filter(|&i| g.example(i).label == 1).count();
        assert!((60..140).contains(&pos), "{pos}");
    }

    #[test]
    fn f1_known_values() {
        assert_eq!(f1_score(&[1, 1, 0, 0], &[1, 1, 0, 0]), 1.0);
        assert_eq!(f1_score(&[0, 0], &[1, 1]), 0.0);
        let f = f1_score(&[1, 1, 1, 0], &[1, 0, 1, 1]);
        assert!((f - 2.0 * (2.0 / 3.0) * (2.0 / 3.0) / (4.0 / 3.0)).abs() < 1e-9);
    }
}
