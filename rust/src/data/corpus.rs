//! "synthlang": a synthetic Zipf–Markov language.
//!
//! Substitute for BookCorpus/Wikipedia (see DESIGN.md). Properties that
//! matter for the MLM/SOP pretraining signal:
//!
//! * Zipfian unigram distribution (like natural text);
//! * deterministic-ish bigram structure (`succ(w)` follows w with
//!   probability `coherence`) so MLM is learnable above the unigram
//!   entropy floor;
//! * sentence segmentation with topic drift so Sentence-Order-Prediction
//!   is learnable: within a document, consecutive sentences share a topic
//!   offset that advances slowly.

use crate::util::rng::{Rng, Zipf};

pub struct CorpusConfig {
    pub vocab_words: usize,
    /// probability the next token is `succ(prev)` rather than a fresh draw
    pub coherence: f64,
    pub sentence_len: (usize, usize),
    pub sentences_per_doc: (usize, usize),
    /// number of latent topics; tokens are biased toward a topic block
    pub topics: usize,
    pub zipf_s: f64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab_words: 2000,
            coherence: 0.55,
            sentence_len: (6, 24),
            sentences_per_doc: (4, 12),
            topics: 16,
            zipf_s: 1.1,
        }
    }
}

/// A document is a list of sentences; a sentence a list of word ids in
/// [0, vocab_words).
pub struct Document {
    pub sentences: Vec<Vec<u32>>,
}

pub struct CorpusGenerator {
    cfg: CorpusConfig,
    zipf: Zipf,
}

impl CorpusGenerator {
    pub fn new(cfg: CorpusConfig) -> Self {
        let zipf = Zipf::new(cfg.vocab_words, cfg.zipf_s);
        CorpusGenerator { cfg, zipf }
    }

    /// Deterministic successor function: the learnable bigram structure.
    pub fn succ(&self, w: u32) -> u32 {
        ((w as u64 * 7 + 3) % self.cfg.vocab_words as u64) as u32
    }

    fn topic_word(&self, base: usize, topic: usize) -> u32 {
        // shift a zipf draw into the topic's block of the vocabulary
        let block = self.cfg.vocab_words / self.cfg.topics;
        ((topic * block + base % block) % self.cfg.vocab_words) as u32
    }

    pub fn sentence(&self, rng: &mut Rng, topic: usize) -> Vec<u32> {
        let (lo, hi) = self.cfg.sentence_len;
        let len = rng.range(lo, hi + 1);
        let mut out = Vec::with_capacity(len);
        let mut prev: Option<u32> = None;
        for _ in 0..len {
            let w = match prev {
                Some(p) if rng.uniform_f64() < self.cfg.coherence => self.succ(p),
                _ => self.topic_word(self.zipf.sample(rng), topic),
            };
            out.push(w);
            prev = Some(w);
        }
        out
    }

    pub fn document(&self, rng: &mut Rng) -> Document {
        let (lo, hi) = self.cfg.sentences_per_doc;
        let n = rng.range(lo, hi + 1);
        let mut topic = rng.below(self.cfg.topics);
        let mut sentences = Vec::with_capacity(n);
        for _ in 0..n {
            sentences.push(self.sentence(rng, topic));
            // slow topic drift
            if rng.uniform_f64() < 0.25 {
                topic = (topic + 1) % self.cfg.topics;
            }
        }
        Document { sentences }
    }

    pub fn vocab_words(&self) -> usize {
        self.cfg.vocab_words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sentences_within_length_bounds() {
        let g = CorpusGenerator::new(CorpusConfig::default());
        let mut rng = Rng::new(0);
        for _ in 0..50 {
            let s = g.sentence(&mut rng, 3);
            assert!((6..=24).contains(&s.len()));
            assert!(s.iter().all(|&w| (w as usize) < 2000));
        }
    }

    #[test]
    fn bigram_structure_present() {
        // with coherence 0.55, succ(prev) should follow prev far more
        // often than chance (1/vocab).
        let g = CorpusGenerator::new(CorpusConfig::default());
        let mut rng = Rng::new(1);
        let mut hits = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            let s = g.sentence(&mut rng, 0);
            for w in s.windows(2) {
                total += 1;
                if w[1] == g.succ(w[0]) {
                    hits += 1;
                }
            }
        }
        let rate = hits as f64 / total as f64;
        assert!(rate > 0.4, "successor rate {rate}");
    }

    #[test]
    fn documents_have_multiple_sentences() {
        let g = CorpusGenerator::new(CorpusConfig::default());
        let mut rng = Rng::new(2);
        let d = g.document(&mut rng);
        assert!(d.sentences.len() >= 4);
    }

    #[test]
    fn deterministic_given_seed() {
        let g = CorpusGenerator::new(CorpusConfig::default());
        let a = g.document(&mut Rng::new(7)).sentences;
        let b = g.document(&mut Rng::new(7)).sentences;
        assert_eq!(a, b);
    }
}
