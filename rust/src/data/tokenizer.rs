//! Tokenizer: maps corpus word ids / raw bytes into model token ids,
//! reserving the special-token block.
//!
//! synthlang words are already integers, so the "tokenizer" is an offset
//! map plus vocabulary bounds checking; the byte-level tokenizer (LRA
//! Text/Image tasks) maps bytes into the same reserved-id scheme. Both
//! share the `Tokenizer` trait so the pipeline is source-agnostic.

use super::special;

pub trait Tokenizer {
    /// Total vocabulary size including special tokens.
    fn vocab_size(&self) -> usize;

    /// Encode a raw symbol (word id or byte) to a model token id.
    fn encode_symbol(&self, sym: u32) -> i32;
}

/// Word-id tokenizer for synthlang.
pub struct WordTokenizer {
    pub n_words: usize,
}

impl Tokenizer for WordTokenizer {
    fn vocab_size(&self) -> usize {
        self.n_words + special::FIRST_WORD as usize
    }

    fn encode_symbol(&self, sym: u32) -> i32 {
        if (sym as usize) < self.n_words {
            sym as i32 + special::FIRST_WORD
        } else {
            special::UNK
        }
    }
}

impl WordTokenizer {
    /// Encode a sentence (list of word ids).
    pub fn encode(&self, words: &[u32]) -> Vec<i32> {
        words.iter().map(|&w| self.encode_symbol(w)).collect()
    }
}

/// Byte tokenizer for LRA-style byte-level tasks: byte b -> id, clamped to
/// a vocabulary of `vocab` ids (bytes above the budget map to UNK).
pub struct ByteTokenizer {
    pub vocab: usize,
}

impl Tokenizer for ByteTokenizer {
    fn vocab_size(&self) -> usize {
        self.vocab
    }

    fn encode_symbol(&self, sym: u32) -> i32 {
        let id = sym as i32 + special::FIRST_WORD;
        if (id as usize) < self.vocab {
            id
        } else {
            special::UNK
        }
    }
}

impl ByteTokenizer {
    pub fn encode(&self, bytes: &[u8]) -> Vec<i32> {
        bytes.iter().map(|&b| self.encode_symbol(b as u32)).collect()
    }
}

/// Build `[CLS] a [SEP]` or `[CLS] a [SEP] b [SEP]` with segment ids.
pub fn build_input(a: &[i32], b: Option<&[i32]>, max_len: usize) -> (Vec<i32>, Vec<i32>) {
    let mut ids = Vec::with_capacity(max_len);
    let mut segs = Vec::with_capacity(max_len);
    ids.push(special::CLS);
    segs.push(0);
    for &t in a {
        if ids.len() + 1 >= max_len {
            break;
        }
        ids.push(t);
        segs.push(0);
    }
    ids.push(special::SEP);
    segs.push(0);
    if let Some(b) = b {
        for &t in b {
            if ids.len() + 1 >= max_len {
                break;
            }
            ids.push(t);
            segs.push(1);
        }
        ids.push(special::SEP);
        segs.push(1);
    }
    (ids, segs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn word_encoding_offsets() {
        let t = WordTokenizer { n_words: 100 };
        assert_eq!(t.encode_symbol(0), special::FIRST_WORD);
        assert_eq!(t.encode_symbol(99), 99 + special::FIRST_WORD);
        assert_eq!(t.encode_symbol(100), special::UNK);
        assert_eq!(t.vocab_size(), 105);
    }

    #[test]
    fn byte_encoding_within_vocab() {
        let t = ByteTokenizer { vocab: 256 };
        assert_eq!(t.encode_symbol(0), special::FIRST_WORD);
        // bytes above vocab - FIRST_WORD map to UNK
        assert_eq!(t.encode_symbol(255), special::UNK);
        assert_eq!(t.encode(&[0, 1]), vec![5, 6]);
    }

    #[test]
    fn build_pair_input() {
        let (ids, segs) = build_input(&[10, 11], Some(&[20]), 16);
        assert_eq!(ids, vec![special::CLS, 10, 11, special::SEP, 20, special::SEP]);
        assert_eq!(segs, vec![0, 0, 0, 0, 1, 1]);
    }

    #[test]
    fn build_input_respects_max_len() {
        let a: Vec<i32> = (10..200).collect();
        let (ids, segs) = build_input(&a, None, 32);
        assert!(ids.len() <= 32);
        assert_eq!(ids.len(), segs.len());
        assert_eq!(*ids.last().unwrap(), special::SEP);
    }
}
