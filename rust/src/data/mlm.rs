//! MLM masking (BERT 80/10/10) and SOP (sentence-order prediction) pair
//! construction over synthlang documents — the paper's §4.1 pretraining
//! objectives (SOP from ALBERT instead of NSP, as in the paper).

use super::corpus::CorpusGenerator;
use super::special;
use super::tokenizer::WordTokenizer;
use super::PretrainExample;
use crate::util::Rng;

pub struct MlmConfig {
    pub mask_prob: f32,
    pub seq_len: usize,
    pub vocab_size: usize,
}

impl Default for MlmConfig {
    fn default() -> Self {
        MlmConfig { mask_prob: 0.15, seq_len: 128, vocab_size: 2048 }
    }
}

/// Apply BERT masking in place; returns the MLM label vector.
/// 80% -> [MASK], 10% -> random token, 10% -> unchanged.
pub fn apply_masking(
    ids: &mut [i32],
    cfg: &MlmConfig,
    rng: &mut Rng,
) -> Vec<i32> {
    let mut labels = vec![-1i32; ids.len()];
    for (i, tok) in ids.iter_mut().enumerate() {
        if *tok < special::FIRST_WORD {
            continue; // never mask special tokens / padding
        }
        if rng.bernoulli(cfg.mask_prob) {
            labels[i] = *tok;
            let r = rng.uniform();
            if r < 0.8 {
                *tok = special::MASK;
            } else if r < 0.9 {
                *tok = rng.range(special::FIRST_WORD as usize, cfg.vocab_size) as i32;
            } // else leave unchanged
        }
    }
    labels
}

/// Build one SOP pretraining example from a document: two consecutive
/// sentence groups, order swapped with p=0.5 (label 1 = swapped).
pub fn make_pretrain_example(
    gen: &CorpusGenerator,
    tok: &WordTokenizer,
    cfg: &MlmConfig,
    rng: &mut Rng,
) -> PretrainExample {
    let doc = gen.document(rng);
    let n_sent = doc.sentences.len();
    let split = (n_sent / 2).max(1);
    let first: Vec<i32> = doc.sentences[..split]
        .iter()
        .flat_map(|s| tok.encode(s))
        .collect();
    let second: Vec<i32> = doc.sentences[split..]
        .iter()
        .flat_map(|s| tok.encode(s))
        .collect();

    let swap = rng.bernoulli(0.5);
    let (a, b) = if swap { (&second, &first) } else { (&first, &second) };
    let (mut ids, segs) =
        super::tokenizer::build_input(a, Some(b), cfg.seq_len);
    let mlm_labels = {
        let mut l = apply_masking(&mut ids, cfg, rng);
        l.truncate(ids.len());
        l
    };
    PretrainExample {
        input_ids: ids,
        segment_ids: segs,
        mlm_labels,
        sop_label: if swap { 1 } else { 0 },
    }
}

/// Infinite pretraining stream with deterministic per-index examples.
pub struct PretrainStream {
    gen: CorpusGenerator,
    tok: WordTokenizer,
    cfg: MlmConfig,
    base: Rng,
}

impl PretrainStream {
    pub fn new(gen: CorpusGenerator, tok: WordTokenizer, cfg: MlmConfig, seed: u64) -> Self {
        PretrainStream { gen, tok, cfg, base: Rng::new(seed) }
    }

    /// The i-th example (stable across calls — resumable training).
    pub fn example(&self, index: u64) -> PretrainExample {
        let mut rng = self.base.fold_in(index);
        make_pretrain_example(&self.gen, &self.tok, &self.cfg, &mut rng)
    }

    pub fn batch(&self, start_index: u64, batch: usize) -> super::PretrainBatch {
        let examples: Vec<_> =
            (0..batch).map(|i| self.example(start_index + i as u64)).collect();
        super::collate_pretrain(&examples, self.cfg.seq_len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::CorpusConfig;

    fn stream() -> PretrainStream {
        PretrainStream::new(
            CorpusGenerator::new(CorpusConfig::default()),
            WordTokenizer { n_words: 2000 },
            MlmConfig::default(),
            7,
        )
    }

    #[test]
    fn masking_rate_near_target() {
        let mut rng = Rng::new(0);
        let cfg = MlmConfig::default();
        let mut masked = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            let mut ids: Vec<i32> = (0..100)
                .map(|_| rng.range(special::FIRST_WORD as usize, 2048) as i32)
                .collect();
            let labels = apply_masking(&mut ids, &cfg, &mut rng);
            masked += labels.iter().filter(|&&l| l >= 0).count();
            total += 100;
        }
        let rate = masked as f64 / total as f64;
        assert!((rate - 0.15).abs() < 0.02, "mask rate {rate}");
    }

    #[test]
    fn special_tokens_never_masked() {
        let mut rng = Rng::new(1);
        let cfg = MlmConfig::default();
        let mut ids = vec![special::CLS, special::SEP, special::PAD];
        let labels = apply_masking(&mut ids, &cfg, &mut rng);
        assert_eq!(ids, vec![special::CLS, special::SEP, special::PAD]);
        assert!(labels.iter().all(|&l| l == -1));
    }

    #[test]
    fn labels_record_original_token() {
        let mut rng = Rng::new(2);
        let cfg = MlmConfig { mask_prob: 1.0, ..MlmConfig::default() };
        let orig: Vec<i32> = (5..55).collect();
        let mut ids = orig.clone();
        let labels = apply_masking(&mut ids, &cfg, &mut rng);
        for (l, o) in labels.iter().zip(&orig) {
            assert_eq!(l, o);
        }
    }

    #[test]
    fn examples_deterministic_and_indexed() {
        let s = stream();
        let a = s.example(42);
        let b = s.example(42);
        assert_eq!(a.input_ids, b.input_ids);
        assert_eq!(a.sop_label, b.sop_label);
        let c = s.example(43);
        assert_ne!(a.input_ids, c.input_ids);
    }

    #[test]
    fn batch_shapes_match_abi() {
        let s = stream();
        let b = s.batch(0, 16);
        assert_eq!(b.input_ids.len(), 16 * 128);
        assert_eq!(b.mlm_labels.len(), 16 * 128);
        assert_eq!(b.sop_labels.len(), 16);
        // both SOP classes appear in a large sample
        let mut counts = [0, 0];
        for i in 0..64 {
            counts[s.example(i).sop_label as usize] += 1;
        }
        assert!(counts[0] > 10 && counts[1] > 10, "{counts:?}");
    }

    #[test]
    fn ids_within_vocab() {
        let s = stream();
        let b = s.batch(0, 8);
        assert!(b.input_ids.iter().all(|&t| (0..2048).contains(&t)));
    }
}
