//! Literal marshaling helpers: typed host arrays <-> xla::Literal,
//! validated against IoSpecs.

use super::manifest::{Dtype, IoSpec};
use anyhow::{ensure, Result};
use xla::Literal;

/// Build an f32 literal with the given shape.
pub fn f32_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let count: usize = shape.iter().product();
    ensure!(data.len() == count, "f32 literal: {} vs {:?}", data.len(), shape);
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 literal with the given shape.
pub fn i32_literal(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let count: usize = shape.iter().product();
    ensure!(data.len() == count, "i32 literal: {} vs {:?}", data.len(), shape);
    if shape.is_empty() {
        return Ok(Literal::scalar(data[0]));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(Literal::vec1(data).reshape(&dims)?)
}

/// Build a literal for a spec slot from f32 or i32 host data.
pub enum HostArray<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

pub fn literal_for_spec(spec: &IoSpec, data: HostArray) -> Result<Literal> {
    match (spec.dtype, data) {
        (Dtype::F32, HostArray::F32(d)) => f32_literal(d, &spec.shape),
        (Dtype::I32, HostArray::I32(d)) => i32_literal(d, &spec.shape),
        _ => anyhow::bail!("dtype mismatch for slot {}", spec.name),
    }
}

/// Read an f32 literal back to host.
pub fn to_f32_vec(lit: &Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let lit = f32_literal(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn scalar_literals() {
        let s = f32_literal(&[7.5], &[]).unwrap();
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
        let i = i32_literal(&[3], &[]).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![3]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(f32_literal(&[1.0], &[2]).is_err());
    }
}
