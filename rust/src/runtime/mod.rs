//! PJRT runtime: loads the HLO-text artifacts emitted by `make artifacts`
//! and executes them from the coordinator hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py`): jax >= 0.5
//! emits 64-bit instruction ids in serialized protos which xla_extension
//! 0.5.1 rejects; the text parser reassigns ids.

pub mod artifact;
pub mod literal;
pub mod manifest;
pub mod registry;

pub use artifact::Artifact;
pub use manifest::{ArtifactSpec, IoSpec, Manifest};
pub use registry::Runtime;
