//! Artifact manifest: the ABI contract between `aot.py` and the runtime.

use crate::json::{parse, Value};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor dtype in the artifact ABI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn from_str(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(anyhow!("unknown dtype {other}")),
        }
    }
}

/// One input or output slot.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<IoSpec> {
        Ok(IoSpec {
            name: v
                .get("name")
                .and_then(Value::as_str)
                .context("io spec name")?
                .to_string(),
            shape: v
                .get("shape")
                .and_then(Value::as_array)
                .context("io spec shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<_>>()?,
            dtype: Dtype::from_str(
                v.get("dtype").and_then(Value::as_str).context("dtype")?,
            )?,
        })
    }
}

/// One artifact's full ABI + metadata.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub family: String,
    pub attention: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub config: BTreeMap<String, Value>,
}

impl ArtifactSpec {
    /// Number of model parameters (inputs named `param:*`).
    pub fn n_params(&self) -> usize {
        self.inputs.iter().filter(|s| s.name.starts_with("param:")).count()
    }

    /// Input slots with a given prefix, in ABI order.
    pub fn inputs_with_prefix(&self, prefix: &str) -> Vec<&IoSpec> {
        self.inputs.iter().filter(|s| s.name.starts_with(prefix)).collect()
    }

    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).and_then(Value::as_usize)
    }
}

/// The parsed manifest.
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let v = parse(&text).map_err(|e| anyhow!("{e}"))?;
        let mut artifacts = BTreeMap::new();
        let entries = v
            .get("artifacts")
            .and_then(Value::as_object)
            .context("manifest missing 'artifacts'")?;
        for (name, entry) in entries {
            let spec = ArtifactSpec {
                name: name.clone(),
                file: dir.join(
                    entry.get("file").and_then(Value::as_str).context("file")?,
                ),
                kind: entry
                    .get("kind")
                    .and_then(Value::as_str)
                    .unwrap_or("unknown")
                    .to_string(),
                family: entry
                    .get("family")
                    .and_then(Value::as_str)
                    .unwrap_or("")
                    .to_string(),
                attention: entry
                    .get("attention")
                    .and_then(Value::as_str)
                    .unwrap_or("")
                    .to_string(),
                inputs: entry
                    .get("inputs")
                    .and_then(Value::as_array)
                    .context("inputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<_>>()?,
                outputs: entry
                    .get("outputs")
                    .and_then(Value::as_array)
                    .context("outputs")?
                    .iter()
                    .map(IoSpec::from_json)
                    .collect::<Result<_>>()?,
                config: entry
                    .get("config")
                    .and_then(Value::as_object)
                    .cloned()
                    .unwrap_or_default(),
            };
            artifacts.insert(name.clone(), spec);
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// Names filtered by kind/family.
    pub fn names_where(&self, kind: &str, family: &str) -> Vec<&str> {
        self.artifacts
            .values()
            .filter(|a| a.kind == kind && a.family == family)
            .map(|a| a.name.as_str())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("yoso_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts": {"toy": {
                "file": "toy.hlo.txt", "kind": "train_step",
                "family": "pretrain", "attention": "yoso_16",
                "config": {"batch": 16, "n_params": 2},
                "inputs": [
                  {"name": "param:a", "shape": [2, 3], "dtype": "f32"},
                  {"name": "param:b", "shape": [3], "dtype": "f32"},
                  {"name": "batch:ids", "shape": [4, 8], "dtype": "i32"},
                  {"name": "step", "shape": [], "dtype": "i32"}
                ],
                "outputs": [{"name": "metrics", "shape": [8], "dtype": "f32"}]
            }}}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn parses_spec() {
        let dir = fake_manifest_dir();
        let m = Manifest::load(&dir).unwrap();
        let a = m.get("toy").unwrap();
        assert_eq!(a.n_params(), 2);
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[0].shape, vec![2, 3]);
        assert_eq!(a.inputs[2].dtype, Dtype::I32);
        assert_eq!(a.inputs[3].element_count(), 1);
        assert_eq!(a.config_usize("batch"), Some(16));
        assert_eq!(m.names_where("train_step", "pretrain"), vec!["toy"]);
        assert!(m.get("missing").is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
