//! A compiled artifact: HLO text -> PJRT executable, with ABI-checked
//! execution.

use super::manifest::ArtifactSpec;
use anyhow::{ensure, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

impl Artifact {
    /// Load + compile the artifact's HLO text on the given client.
    pub fn load(client: &PjRtClient, spec: ArtifactSpec) -> Result<Artifact> {
        let path = spec
            .file
            .to_str()
            .context("artifact path not utf-8")?
            .to_string();
        let proto = HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .with_context(|| format!("compiling {}", spec.name))?;
        Ok(Artifact { spec, exe })
    }

    /// Execute with positional literals; returns the flattened output
    /// tuple (aot.py lowers with return_tuple=True).
    pub fn execute(&self, inputs: &[Literal]) -> Result<Vec<Literal>> {
        ensure!(
            inputs.len() == self.spec.inputs.len(),
            "{}: got {} inputs, ABI wants {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let result = self.exe.execute::<Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outputs = tuple.to_tuple()?;
        ensure!(
            outputs.len() == self.spec.outputs.len(),
            "{}: got {} outputs, ABI wants {}",
            self.spec.name,
            outputs.len(),
            self.spec.outputs.len()
        );
        Ok(outputs)
    }
}
