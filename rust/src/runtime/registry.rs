//! Runtime = PJRT client + manifest + lazily compiled artifact cache.

use super::artifact::Artifact;
use super::manifest::Manifest;
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use xla::PjRtClient;

pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<Artifact>>>,
}

impl Runtime {
    /// CPU-PJRT runtime over an artifact directory.
    pub fn open(artifacts_dir: &Path) -> Result<Runtime> {
        let client = PjRtClient::cpu()?;
        let manifest = Manifest::load(artifacts_dir)?;
        crate::info!(
            "runtime: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Runtime { client, manifest, cache: Mutex::new(BTreeMap::new()) })
    }

    /// Get (compiling on first use) an artifact by name.
    pub fn artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(a));
        }
        let spec = self.manifest.get(name)?.clone();
        let t = crate::util::Timer::start();
        let artifact = Arc::new(Artifact::load(&self.client, spec)?);
        crate::info!("compiled {} in {:.1}s", name, t.elapsed_secs());
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&artifact));
        Ok(artifact)
    }
}
