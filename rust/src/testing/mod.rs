//! Property-testing microframework (offline registry has no proptest).
//!
//! Provides seeded generators, a `check` driver that runs N cases and
//! reports the failing seed, and simple shrinking for numeric/size
//! parameters via halving. Used by the coordinator invariants tests
//! (routing, batching, state) and the attention-library property tests.

use crate::util::Rng;

/// Thread count for scheduler-exercising tests: `YOSO_TEST_THREADS`
/// overrides the test's built-in default (0, unset, or unparsable keep
/// the default). CI sweeps this over {1, 2, core-count} in release mode
/// so the work-stealing paths run at widths a 2-core runner would
/// otherwise never hit; determinism tests must pass at every value.
pub fn test_threads(default: usize) -> usize {
    match std::env::var("YOSO_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        Some(0) | None => default,
        Some(t) => t,
    }
}

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop` over `cases` random inputs drawn by `gen`. On failure,
/// attempt to shrink by regenerating with halved size hints, and panic
/// with the seed that reproduces the minimal found counterexample.
pub fn check<T: std::fmt::Debug, G, P>(cfg: PropConfig, mut generate: G, prop: P)
where
    G: FnMut(&mut Rng, usize) -> T,
    P: Fn(&T) -> bool,
{
    for case in 0..cfg.cases {
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(case_seed);
        let size = 1 + case % 64;
        let input = generate(&mut rng, size);
        if !prop(&input) {
            // shrink: retry with progressively smaller size hints
            let mut minimal = input;
            let mut cur = size;
            while cur > 1 {
                cur /= 2;
                let mut rng = Rng::new(case_seed);
                let candidate = generate(&mut rng, cur);
                if !prop(&candidate) {
                    minimal = candidate;
                } else {
                    break;
                }
            }
            panic!(
                "property failed (case {case}, seed {case_seed:#x}).\n\
                 minimal counterexample: {minimal:#?}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::tensor::Mat;
    use crate::util::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        rng.range(lo, hi)
    }

    pub fn f32_in(rng: &mut Rng, lo: f32, hi: f32) -> f32 {
        lo + rng.uniform() * (hi - lo)
    }

    pub fn unit_mat(rng: &mut Rng, n: usize, d: usize) -> Mat {
        Mat::randn(n, d, 1.0, rng).unit_rows()
    }

    pub fn vec_of<T>(rng: &mut Rng, len: usize, mut f: impl FnMut(&mut Rng) -> T) -> Vec<T> {
        (0..len).map(|_| f(rng)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(PropConfig::default(), |rng, size| {
            gen::vec_of(rng, size, |r| r.below(100))
        }, |v| v.iter().all(|&x| x < 100));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        check(
            PropConfig { cases: 16, seed: 1 },
            |rng, size| gen::vec_of(rng, size + 3, |r| r.below(10)),
            |v| v.len() < 3,
        );
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = Rng::new(0);
        for _ in 0..100 {
            let x = gen::usize_in(&mut rng, 5, 10);
            assert!((5..10).contains(&x));
            let f = gen::f32_in(&mut rng, -1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
        let m = gen::unit_mat(&mut rng, 4, 8);
        assert_eq!((m.rows, m.cols), (4, 8));
    }
}
