//! Bench harness (offline registry has no criterion): warmup + timed
//! iterations with percentile reporting, plus a counting global allocator
//! for peak-memory measurement (the Figure 7 memory axis).

use crate::util::stats::Summary;
use crate::util::Timer;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Counting allocator: tracks live and peak heap bytes. Install in a
/// bench binary with:
/// ```ignore
/// #[global_allocator]
/// static ALLOC: yoso::bench_support::CountingAlloc = yoso::bench_support::CountingAlloc;
/// ```
pub struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);
static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = unsafe { System.alloc(layout) };
        if !p.is_null() {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            let live = LIVE.fetch_add(layout.size(), Ordering::Relaxed) + layout.size();
            PEAK.fetch_max(live, Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) };
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
    }
}

/// Reset the peak to the current live size and return a probe.
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

/// Peak heap bytes since the last `reset_peak`.
pub fn peak_bytes() -> usize {
    PEAK.load(Ordering::Relaxed)
}

pub fn live_bytes() -> usize {
    LIVE.load(Ordering::Relaxed)
}

/// Total successful heap allocations (call count, not bytes) since
/// process start — the zero-allocation steady-state hook: snapshot,
/// run the fused kernel, assert the delta is 0 (`tests/alloc_kernel.rs`).
/// Process-global, so keep competing allocators off other threads while
/// measuring.
pub fn alloc_count() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

/// Timed benchmark: `warmup` unmeasured runs, then `iters` measured runs.
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
    pub peak_bytes: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} mean {:>10.3} ms  p50 {:>10.3}  p90 {:>10.3}  peak {:>10}",
            self.name,
            self.summary.mean * 1e3,
            self.summary.p50 * 1e3,
            self.summary.p90 * 1e3,
            human_bytes(self.peak_bytes),
        )
    }
}

pub fn human_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KiB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

/// Run a benchmark, measuring wall time and peak allocations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    reset_peak();
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.elapsed_secs());
    }
    BenchResult {
        name: name.to_string(),
        summary: Summary::of(&times),
        peak_bytes: peak_bytes().saturating_sub(live_bytes()),
    }
}

/// True when `YOSO_BENCH_SMOKE=1` (or `true`): every bench binary
/// shrinks its sweeps/iterations to a seconds-scale smoke run and skips
/// shape assertions that only hold at full problem sizes. CI's
/// bench-smoke job runs all benches in this mode on every PR and uploads
/// the emitted CSVs as artifacts, so the perf trajectory (including the
/// fig7 scheduler and chunk-policy columns) is recorded per change.
pub fn smoke() -> bool {
    smoke_setting(std::env::var("YOSO_BENCH_SMOKE").ok().as_deref())
}

/// The `YOSO_BENCH_SMOKE` parse itself, env-free so tests cover it
/// without `set_var` (mutating the process environment races parallel
/// tests that call `getenv` — UB on glibc).
fn smoke_setting(v: Option<&str>) -> bool {
    matches!(v, Some("1") | Some("true"))
}

/// `smoke_v` under `YOSO_BENCH_SMOKE`, else `full_v`.
pub fn smoke_or<T>(smoke_v: T, full_v: T) -> T {
    if smoke() {
        smoke_v
    } else {
        full_v
    }
}

/// Smoke-mode guard for artifact-dependent benches (fig5/table2/table3):
/// in the CI smoke sweep there is no `artifacts/` directory (the offline
/// build gates PJRT), so those benches print a skip note and exit clean
/// instead of failing the job. Outside smoke mode this never skips.
pub fn smoke_skip_without_artifacts(dir: &str) -> bool {
    if smoke() && !std::path::Path::new(dir).join("manifest.json").exists() {
        println!("YOSO_BENCH_SMOKE: no {dir}/manifest.json — skipping artifact bench");
        return true;
    }
    false
}

/// Thread budget for benches: `YOSO_BENCH_THREADS`, where 0, unset, or
/// unparsable all mean "every available core". Shared by fig7/table1 so
/// the env var has one meaning everywhere (Engine::new(0) agrees).
pub fn bench_threads() -> usize {
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    match std::env::var("YOSO_BENCH_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
    {
        Some(0) | None => cores,
        Some(t) => t,
    }
}

/// Choose iteration count so a bench takes roughly `budget_secs`.
pub fn calibrate_iters<F: FnMut()>(mut f: F, budget_secs: f64) -> usize {
    let t = Timer::start();
    f();
    let one = t.elapsed_secs().max(1e-9);
    ((budget_secs / one).round() as usize).clamp(3, 1000)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_times() {
        let r = bench("noop-ish", 1, 10, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.summary.n, 10);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert!(human_bytes(2048).contains("KiB"));
        assert!(human_bytes(5 << 20).contains("MiB"));
    }

    #[test]
    fn smoke_flag_parses_settings() {
        // the pure parser, not the env read: set_var would race the
        // parallel tests that getenv (YOSO_TEST_THREADS etc.)
        assert!(smoke_setting(Some("1")));
        assert!(smoke_setting(Some("true")));
        assert!(!smoke_setting(Some("0")));
        assert!(!smoke_setting(Some("")));
        assert!(!smoke_setting(Some("yes")));
        assert!(!smoke_setting(None));
    }

    #[test]
    fn calibrate_bounds() {
        let it = calibrate_iters(|| std::thread::sleep(std::time::Duration::from_micros(10)), 0.01);
        assert!((3..=1000).contains(&it));
    }
}
