//! The single-loop serving path: owns the executor on its thread, pulls
//! dynamic batches, executes, and delivers per-sequence logits. (The
//! multi-replica front door with admission control lives in
//! `serve::gateway` and shares this module's canonicalization/forward
//! helpers, so both paths serve bit-identical logits.)
//!
//! Two executors share the same handle/batcher/stats machinery:
//! * **artifact** (`ServerHandle::spawn`): PJRT runtime, pads each batch
//!   to the artifact's fixed batch size, one fused forward per batch.
//! * **CPU fallback** (`ServerHandle::spawn_cpu`): the pure-Rust encoder
//!   + attention zoo, no artifacts needed. Requests of a batch fan out
//!   across the work-stealing `ThreadPool` (one bulk submit per batch);
//!   inside each request job the encoder runs the batched multi-head API
//!   serially (`MultiHeadAttention::serial_with_policy`, carrying the
//!   configured `ChunkPolicy`) — one parallelism grain per pool, so jobs
//!   never re-enter it. Each request computes at its content-canonical
//!   `bucket_len` width (next power of two, capped at `max_len`), so a
//!   short request costs O(its own length), not O(max_len).

use super::batcher::{BatchPolicy, Batcher};
use super::clock::{Clock, SystemClock};
use super::{Request, Response};
use crate::attention::{
    by_name, yoso_variant, Attention, ChunkPolicy, KernelVariant,
    MultiHeadAttention,
};
use crate::data::special;
use crate::model::encoder::{
    bucket_len, encoder_abi_spec, pow2_floor, serving_rng, Encoder,
    EncoderConfig,
};
use crate::model::ParamSet;
use crate::runtime::literal::{f32_literal, i32_literal, to_f32_vec};
use crate::runtime::Runtime;
use crate::util::stats::Summary;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use xla::Literal;

/// The request channel's sender behind an explicit close flag. `close`
/// drops the underlying `Sender`, so the serve loop's receiver
/// disconnects **even while `Submitter` clones are alive** — shutdown
/// liveness never depends on producers dropping their handles first.
/// Submits after close observe `None` and hand back a dead receiver.
struct SharedTx(Mutex<Option<Sender<Request>>>);

impl SharedTx {
    fn new(tx: Sender<Request>) -> Arc<SharedTx> {
        Arc::new(SharedTx(Mutex::new(Some(tx))))
    }

    /// A clone of the live sender, or None once closed. Cloning out of
    /// the short critical section keeps the actual `send` lock-free.
    fn sender(&self) -> Option<Sender<Request>> {
        self.0.lock().unwrap().clone()
    }

    fn close(&self) {
        self.0.lock().unwrap().take();
    }
}

/// Client-side handle: submit sequences, receive logits.
pub struct ServerHandle {
    tx: Arc<SharedTx>,
    /// one clock per server: submit stamps, batch aging, and latency
    /// stats all live on a single timeline (`serve::clock`)
    clock: Arc<dyn Clock>,
    join: Option<std::thread::JoinHandle<Result<ServeStats>>>,
}

/// Aggregate serving statistics, returned at shutdown.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub latency: Summary,
    pub queue_latency: Summary,
    pub throughput_rps: f64,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests in {} batches | latency ms p50 {:.2} p95 {:.2} \
             p99 {:.2} | queue ms p50 {:.2} p95 {:.2} p99 {:.2} | {:.1} req/s",
            self.requests,
            self.batches,
            self.latency.p50,
            self.latency.p95,
            self.latency.p99,
            self.queue_latency.p50,
            self.queue_latency.p95,
            self.queue_latency.p99,
            self.throughput_rps
        )
    }
}

/// Cloneable submission handle: hand one to each producer thread.
/// Clones never pin the server open — `ServerHandle::shutdown` closes
/// the queue explicitly, after which submits return dead receivers.
#[derive(Clone)]
pub struct Submitter {
    tx: Arc<SharedTx>,
    clock: Arc<dyn Clock>,
}

impl Submitter {
    /// Submit one sequence; returns the response receiver. After the
    /// server shuts down the returned receiver's `recv` errors
    /// immediately (the request was never admitted).
    pub fn submit(&self, input_ids: Vec<i32>, segment_ids: Vec<i32>)
        -> Receiver<Response> {
        let (reply, rx) = channel();
        if let Some(tx) = self.tx.sender() {
            let _ = tx.send(Request {
                input_ids,
                segment_ids,
                reply,
                enqueued: self.clock.now(),
            });
        }
        rx
    }

    /// Submit one sequence and wait at most `timeout` for its response.
    /// The deadline-bounded client path: a reply sender dropped by a
    /// dying or shut-down server surfaces as a timely error — never an
    /// unbounded hang. (A submit against a closed server errors
    /// immediately; `timeout` is the worst case, not the wait.)
    pub fn submit_wait(
        &self,
        input_ids: Vec<i32>,
        segment_ids: Vec<i32>,
        timeout: std::time::Duration,
    ) -> Result<Response> {
        let rx = self.submit(input_ids, segment_ids);
        rx.recv_timeout(timeout).with_context(|| {
            format!(
                "no server response within {} ms (reply lost or timed out)",
                timeout.as_millis()
            )
        })
    }
}

/// Configuration for the artifact-free CPU fallback server.
#[derive(Clone, Debug)]
pub struct CpuServeConfig {
    /// attention zoo variant (`attention::by_name`)
    pub attention: String,
    /// encoder geometry; sequences truncate to `encoder.max_len` and
    /// compute at their content-canonical `bucket_len` width
    pub encoder: EncoderConfig,
    /// worker threads for request-level fan-out (0 = available cores)
    pub threads: usize,
    /// hash-chunking policy carried into each request's engine. Serving
    /// logits and latency are policy-independent today — the CPU path
    /// runs YOSO through the attention trait, not `Engine::forward_yoso`
    /// (a test asserts the independence); the field pins the layout
    /// contract for engine-level serving paths (fused per-request hash
    /// fan-out, workspace accounting) without a config ABI break later
    pub chunk_policy: ChunkPolicy,
    /// YOSO kernel variant (`attention::kernel`) every worker's
    /// attention instance runs. The fused default keeps steady-state
    /// request forwards allocation-free (each pool worker / gateway
    /// replica serves out of its warm thread-local `KernelArena`);
    /// `Seed` pins the baseline for A/B serving benchmarks. Logits are
    /// bit-identical either way (property-tested).
    pub kernel: KernelVariant,
    pub seed: u64,
}

impl Default for CpuServeConfig {
    fn default() -> Self {
        CpuServeConfig {
            attention: "yoso_32".into(),
            // vocab: WordTokenizer { n_words: 2000 } + special tokens
            encoder: EncoderConfig::base(2005, 128, 2),
            threads: 0,
            chunk_policy: ChunkPolicy::default(),
            kernel: KernelVariant::from_env(),
            seed: 42,
        }
    }
}

impl ServerHandle {
    /// Spawn the server thread. `checkpoint` (optional) initializes model
    /// weights; otherwise fresh-initialized weights serve (useful for
    /// latency benchmarking).
    pub fn spawn(
        artifacts_dir: PathBuf,
        artifact_name: String,
        policy: BatchPolicy,
        seed: u64,
        checkpoint: Option<PathBuf>,
    ) -> ServerHandle {
        let clock: Arc<dyn Clock> = Arc::new(SystemClock::new());
        let loop_clock = Arc::clone(&clock);
        let (tx, rx) = channel::<Request>();
        let join = std::thread::spawn(move || {
            serve_loop(
                artifacts_dir,
                artifact_name,
                policy,
                seed,
                checkpoint,
                rx,
                loop_clock,
            )
        });
        ServerHandle { tx: SharedTx::new(tx), clock, join: Some(join) }
    }

    /// Spawn the artifact-free CPU fallback server: pure-Rust encoder on
    /// a request-level worker pool.
    pub fn spawn_cpu(cfg: CpuServeConfig, policy: BatchPolicy) -> ServerHandle {
        let clock: Arc<dyn Clock> = Arc::new(SystemClock::new());
        let loop_clock = Arc::clone(&clock);
        let (tx, rx) = channel::<Request>();
        let join = std::thread::spawn(move || {
            serve_loop_cpu(cfg, policy, rx, loop_clock)
        });
        ServerHandle { tx: SharedTx::new(tx), clock, join: Some(join) }
    }

    /// Cloneable submission handle for concurrent producers. Clones may
    /// outlive the server: `shutdown` closes the queue itself, and a
    /// submit after close hands back a dead receiver.
    pub fn submitter(&self) -> Submitter {
        Submitter { tx: Arc::clone(&self.tx), clock: Arc::clone(&self.clock) }
    }

    /// Submit one sequence; returns the response receiver.
    pub fn submit(&self, input_ids: Vec<i32>, segment_ids: Vec<i32>)
        -> Receiver<Response> {
        self.submitter().submit(input_ids, segment_ids)
    }

    /// Submit and wait at most `timeout` for the response (see
    /// [`Submitter::submit_wait`]).
    pub fn submit_wait(
        &self,
        input_ids: Vec<i32>,
        segment_ids: Vec<i32>,
        timeout: std::time::Duration,
    ) -> Result<Response> {
        self.submitter().submit_wait(input_ids, segment_ids, timeout)
    }

    /// Close the queue, drain what was admitted, and collect stats.
    /// Returns once the serve loop finishes the already-queued requests
    /// — outstanding `Submitter` clones cannot block this (the close is
    /// explicit, not drop-based).
    pub fn shutdown(mut self) -> Result<ServeStats> {
        self.tx.close();
        self.join
            .take()
            .expect("already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))?
    }
}

fn serve_loop(
    artifacts_dir: PathBuf,
    artifact_name: String,
    policy: BatchPolicy,
    seed: u64,
    checkpoint: Option<PathBuf>,
    rx: Receiver<Request>,
    clock: Arc<dyn Clock>,
) -> Result<ServeStats> {
    let runtime = Runtime::open(&artifacts_dir)?;
    let artifact = runtime.artifact(&artifact_name)?;
    let spec = &artifact.spec;
    let ids_slot = spec
        .inputs
        .iter()
        .find(|s| s.name == "batch:input_ids")
        .context("forward artifact needs batch:input_ids")?;
    let (abi_batch, seq_len) = (ids_slot.shape[0], ids_slot.shape[1]);

    // model weights: checkpoint or fresh init
    let params = match checkpoint {
        Some(path) => crate::train::checkpoint::load(&path)?,
        None => ParamSet::init_for(spec, seed),
    };
    let param_lits: Vec<Literal> = params
        .values
        .iter()
        .zip(&params.shapes)
        .map(|(v, s)| f32_literal(v, s))
        .collect::<Result<_>>()?;

    let batcher = Batcher::with_clock(policy, Arc::clone(&clock));
    let mut latencies = Vec::new();
    let mut queue_latencies = Vec::new();
    let mut n_requests = 0usize;
    let mut n_batches = 0usize;
    let started = clock.now();

    while let Some(batch) = batcher.next_batch(&rx) {
        let exec_start = clock.now();
        n_batches += 1;
        // pad the dynamic batch to the ABI batch size
        let mut ids = vec![special::PAD; abi_batch * seq_len];
        let mut segs = vec![0i32; abi_batch * seq_len];
        for (row, req) in batch.iter().enumerate() {
            for (j, &t) in req.input_ids.iter().take(seq_len).enumerate() {
                ids[row * seq_len + j] = t;
            }
            for (j, &t) in req.segment_ids.iter().take(seq_len).enumerate() {
                segs[row * seq_len + j] = t;
            }
        }
        let mut inputs: Vec<Literal> = param_lits.to_vec();
        inputs.push(i32_literal(&ids, &[abi_batch, seq_len])?);
        inputs.push(i32_literal(&segs, &[abi_batch, seq_len])?);
        inputs.push(i32_literal(&[n_batches as i32], &[])?);

        let outputs = artifact.execute(&inputs)?;
        let logits = to_f32_vec(&outputs[0])?;
        let per_row = logits.len() / abi_batch;

        for (row, req) in batch.into_iter().enumerate() {
            n_requests += 1;
            let queue_ms = exec_start.ms_since(req.enqueued);
            let total_ms = clock.now().ms_since(req.enqueued);
            latencies.push(total_ms);
            queue_latencies.push(queue_ms);
            // the artifact path never degrades; its hash-round count is
            // baked into the HLO and not visible to the server, so
            // m_served reports 0 ("not applicable") at Full quality
            let _ = req.reply.send(Response {
                logits: logits[row * per_row..(row + 1) * per_row].to_vec(),
                queue_ms,
                total_ms,
                m_served: 0,
                quality: super::Quality::Full,
                retries: 0,
            });
        }
    }

    let elapsed = clock.now().duration_since(started).as_secs_f64();
    Ok(make_stats(n_requests, n_batches, &latencies, &queue_latencies, elapsed))
}

/// Clamp untrusted client tokens into the embedding tables' ranges:
/// out-of-vocabulary ids become UNK, segments clamp to {0, 1}. The
/// encoder indexes these tables directly, so a raw client value would
/// otherwise panic a worker.
fn sanitize(ids: &mut [i32], segs: &mut [i32], vocab_size: usize) {
    for t in ids.iter_mut() {
        if *t < 0 || *t as usize >= vocab_size {
            *t = special::UNK;
        }
    }
    for s in segs.iter_mut() {
        *s = (*s).clamp(0, 1);
    }
}

/// Canonicalize a raw client request: align segment length to the ids,
/// truncate to the model length, clamp hostile tokens. The canonical
/// content is what the forward computes on (at its `bucket_len` width,
/// under the width-keyed `serving_rng` stream), so identical canonical
/// content always serves identical logits — the determinism contract
/// every CPU serving path (single loop and gateway replicas alike) is
/// property-tested against.
pub(crate) fn canonicalize(
    mut ids: Vec<i32>,
    mut segs: Vec<i32>,
    vocab_size: usize,
    max_len: usize,
) -> (Vec<i32>, Vec<i32>) {
    segs.resize(ids.len(), 0);
    ids.truncate(max_len);
    segs.truncate(max_len);
    sanitize(&mut ids, &mut segs, vocab_size);
    (ids, segs)
}

/// One canonical request through the encoder at `width` rows: derive the
/// width-keyed serving RNG stream (`model::encoder::serving_rng` — width
/// is content-canonical, so logits remain a pure function of (seed,
/// content), and same-width requests share hash functions, which is what
/// the gateway prefix cache reuses), pad to the bucket width, classify.
/// Shared by the single-loop CPU path and every gateway replica — the
/// gateway bit-identity property test compares exactly these bytes, and
/// the streamed cache path (`model::encoder::EncoderStream`) is
/// property-tested bit-identical to this function.
pub(crate) fn serve_forward(
    enc: &Encoder,
    attn: &Arc<dyn Attention>,
    chunk: ChunkPolicy,
    seed: u64,
    ids: &[i32],
    segs: &[i32],
    width: usize,
) -> Vec<f32> {
    let mut rng = serving_rng(seed, width);
    let mh = MultiHeadAttention::serial_with_policy(chunk);
    enc.classify_bucketed(ids, segs, width, attn, &mh, &mut rng)
}

/// The CPU server/gateway attention constructor: one fixed ctor stream
/// per config seed, so every gateway replica — and the single-loop path
/// the property tests compare against — builds a bit-identical attention
/// instance (some zoo variants draw projections from the ctor RNG).
pub(crate) fn build_attention(cfg: &CpuServeConfig) -> Arc<dyn Attention> {
    let mut ctor_rng = Rng::new(cfg.seed ^ 0x5EED_CAFE);
    let mut attn = by_name(&cfg.attention, &mut ctor_rng, cfg.encoder.d_head());
    // pin the configured kernel variant (no-op for non-YOSO zoo members)
    // so every replica and the single-loop path run the same kernel
    attn.set_kernel(cfg.kernel);
    Arc::from(attn)
}

/// `threads == 0` means every available core.
pub(crate) fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// Shared tail of both serve loops.
fn make_stats(
    n_requests: usize,
    n_batches: usize,
    latencies: &[f64],
    queue_latencies: &[f64],
    elapsed: f64,
) -> ServeStats {
    ServeStats {
        requests: n_requests,
        batches: n_batches,
        latency: if latencies.is_empty() {
            Summary::of(&[0.0])
        } else {
            Summary::of(latencies)
        },
        queue_latency: if queue_latencies.is_empty() {
            Summary::of(&[0.0])
        } else {
            Summary::of(queue_latencies)
        },
        throughput_rps: n_requests as f64 / elapsed.max(1e-9),
    }
}

fn serve_loop_cpu(
    cfg: CpuServeConfig,
    policy: BatchPolicy,
    rx: Receiver<Request>,
    clock: Arc<dyn Clock>,
) -> Result<ServeStats> {
    let mut ecfg = cfg.encoder.clone();
    // every canonical compute width is a power of two, so a non-pow2
    // max_len is floored up front — truncation, bucket widths, and
    // prefix-cache keys then all agree on one cap (`bucket_len` floors
    // its own cap identically, so this is belt-and-suspenders for
    // configs built without `EncoderConfig::base`'s validation)
    ecfg.max_len = pow2_floor(ecfg.max_len);
    let params =
        Arc::new(ParamSet::init_for(&encoder_abi_spec(&ecfg), cfg.seed));
    let attn = build_attention(&cfg);
    let threads = resolve_threads(cfg.threads);
    let pool = ThreadPool::new(threads);
    crate::info!(
        "cpu serve: attention={} threads={threads} chunk={} kernel={} vocab={} seq={}",
        cfg.attention,
        cfg.chunk_policy.label(),
        cfg.kernel.label(),
        ecfg.vocab_size,
        ecfg.max_len
    );

    let batcher = Batcher::with_clock(policy, Arc::clone(&clock));
    let mut latencies = Vec::new();
    let mut queue_latencies = Vec::new();
    let mut n_requests = 0usize;
    let mut n_batches = 0usize;
    let started = clock.now();
    // the single-loop path never degrades: every response reports the
    // configured full hash-round count (1 for non-YOSO variants — the
    // same convention as the gateway's m_full)
    let m_full = yoso_variant(&cfg.attention).map_or(1, |a| a.m);

    while let Some(batch) = batcher.next_batch(&rx) {
        let exec_start = clock.now();
        n_batches += 1;
        n_requests += batch.len();
        let params = Arc::clone(&params);
        let attn = Arc::clone(&attn);
        let worker_clock = Arc::clone(&clock);
        let ecfg = ecfg.clone();
        let (seed, max_len) = (cfg.seed, ecfg.max_len);
        let chunk_policy = cfg.chunk_policy;
        // request-level fan-out on the work-stealing pool; the
        // per-request reply is sent from the worker so fast requests are
        // not stuck behind slow batchmates. Each request computes at its
        // content-canonical `bucket_len` width — O(next-pow2(len)), not
        // O(max_len) — the same width every gateway replica would pick,
        // so this single-loop path stays the gateway's bit-identical
        // reference.
        let timings = pool.map(batch, move |req| {
            let (ids, segs) = canonicalize(
                req.input_ids,
                req.segment_ids,
                ecfg.vocab_size,
                max_len,
            );
            let width = bucket_len(ids.len(), max_len);
            // per-request Encoder::new only rebuilds the ~50-entry name
            // map — noise next to the forward's matmuls
            let enc = Encoder::new(ecfg.clone(), &params);
            let logits =
                serve_forward(&enc, &attn, chunk_policy, seed, &ids, &segs, width);
            let queue_ms = exec_start.ms_since(req.enqueued);
            let total_ms = worker_clock.now().ms_since(req.enqueued);
            let _ = req.reply.send(Response {
                logits,
                queue_ms,
                total_ms,
                m_served: m_full,
                quality: super::Quality::Full,
                retries: 0,
            });
            (queue_ms, total_ms)
        });
        for (queue_ms, total_ms) in timings {
            queue_latencies.push(queue_ms);
            latencies.push(total_ms);
        }
    }

    let elapsed = clock.now().duration_since(started).as_secs_f64();
    Ok(make_stats(n_requests, n_batches, &latencies, &queue_latencies, elapsed))
}
