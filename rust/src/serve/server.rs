//! The serving loop: owns the PJRT runtime on its thread, pulls dynamic
//! batches, pads to the artifact's fixed batch size, executes, and
//! delivers per-sequence logits.

use super::batcher::{BatchPolicy, Batcher};
use super::{Request, Response};
use crate::data::special;
use crate::model::ParamSet;
use crate::runtime::literal::{f32_literal, i32_literal, to_f32_vec};
use crate::runtime::Runtime;
use crate::util::stats::Summary;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;
use xla::Literal;

/// Client-side handle: submit sequences, receive logits.
pub struct ServerHandle {
    tx: Sender<Request>,
    join: Option<std::thread::JoinHandle<Result<ServeStats>>>,
}

/// Aggregate serving statistics, returned at shutdown.
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub requests: usize,
    pub batches: usize,
    pub latency: Summary,
    pub queue_latency: Summary,
    pub throughput_rps: f64,
}

impl ServerHandle {
    /// Spawn the server thread. `checkpoint` (optional) initializes model
    /// weights; otherwise fresh-initialized weights serve (useful for
    /// latency benchmarking).
    pub fn spawn(
        artifacts_dir: PathBuf,
        artifact_name: String,
        policy: BatchPolicy,
        seed: u64,
        checkpoint: Option<PathBuf>,
    ) -> ServerHandle {
        let (tx, rx) = channel::<Request>();
        let join = std::thread::spawn(move || {
            serve_loop(artifacts_dir, artifact_name, policy, seed, checkpoint, rx)
        });
        ServerHandle { tx, join: Some(join) }
    }

    /// Submit one sequence; returns the response receiver.
    pub fn submit(&self, input_ids: Vec<i32>, segment_ids: Vec<i32>)
        -> Receiver<Response> {
        let (reply, rx) = channel();
        let _ = self.tx.send(Request {
            input_ids,
            segment_ids,
            reply,
            enqueued: Instant::now(),
        });
        rx
    }

    /// Close the queue and collect stats.
    pub fn shutdown(mut self) -> Result<ServeStats> {
        drop(self.tx);
        self.join
            .take()
            .expect("already joined")
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))?
    }
}

fn serve_loop(
    artifacts_dir: PathBuf,
    artifact_name: String,
    policy: BatchPolicy,
    seed: u64,
    checkpoint: Option<PathBuf>,
    rx: Receiver<Request>,
) -> Result<ServeStats> {
    let runtime = Runtime::open(&artifacts_dir)?;
    let artifact = runtime.artifact(&artifact_name)?;
    let spec = &artifact.spec;
    let ids_slot = spec
        .inputs
        .iter()
        .find(|s| s.name == "batch:input_ids")
        .context("forward artifact needs batch:input_ids")?;
    let (abi_batch, seq_len) = (ids_slot.shape[0], ids_slot.shape[1]);

    // model weights: checkpoint or fresh init
    let params = match checkpoint {
        Some(path) => crate::train::checkpoint::load(&path)?,
        None => ParamSet::init_for(spec, seed),
    };
    let param_lits: Vec<Literal> = params
        .values
        .iter()
        .zip(&params.shapes)
        .map(|(v, s)| f32_literal(v, s))
        .collect::<Result<_>>()?;

    let batcher = Batcher { policy };
    let mut latencies = Vec::new();
    let mut queue_latencies = Vec::new();
    let mut n_requests = 0usize;
    let mut n_batches = 0usize;
    let started = Instant::now();

    while let Some(batch) = batcher.next_batch(&rx) {
        let exec_start = Instant::now();
        n_batches += 1;
        // pad the dynamic batch to the ABI batch size
        let mut ids = vec![special::PAD; abi_batch * seq_len];
        let mut segs = vec![0i32; abi_batch * seq_len];
        for (row, req) in batch.iter().enumerate() {
            for (j, &t) in req.input_ids.iter().take(seq_len).enumerate() {
                ids[row * seq_len + j] = t;
            }
            for (j, &t) in req.segment_ids.iter().take(seq_len).enumerate() {
                segs[row * seq_len + j] = t;
            }
        }
        let mut inputs: Vec<Literal> = param_lits.iter().cloned().collect();
        inputs.push(i32_literal(&ids, &[abi_batch, seq_len])?);
        inputs.push(i32_literal(&segs, &[abi_batch, seq_len])?);
        inputs.push(i32_literal(&[n_batches as i32], &[])?);

        let outputs = artifact.execute(&inputs)?;
        let logits = to_f32_vec(&outputs[0])?;
        let per_row = logits.len() / abi_batch;

        for (row, req) in batch.into_iter().enumerate() {
            n_requests += 1;
            let queue_ms =
                (exec_start - req.enqueued).as_secs_f64() * 1e3;
            let total_ms = req.enqueued.elapsed().as_secs_f64() * 1e3;
            latencies.push(total_ms);
            queue_latencies.push(queue_ms);
            let _ = req.reply.send(Response {
                logits: logits[row * per_row..(row + 1) * per_row].to_vec(),
                queue_ms,
                total_ms,
            });
        }
    }

    let elapsed = started.elapsed().as_secs_f64();
    Ok(ServeStats {
        requests: n_requests,
        batches: n_batches,
        latency: if latencies.is_empty() {
            Summary::of(&[0.0])
        } else {
            Summary::of(&latencies)
        },
        queue_latency: if queue_latencies.is_empty() {
            Summary::of(&[0.0])
        } else {
            Summary::of(&queue_latencies)
        },
        throughput_rps: n_requests as f64 / elapsed.max(1e-9),
    })
}
