//! The serving stack's time source: a [`Clock`] trait with a wall-clock
//! implementation ([`SystemClock`]) and a manually-advanced virtual one
//! ([`SimClock`]).
//!
//! Every timing decision in `serve` — request timestamps, deadline
//! expiry, batch aging, EWMA retry hints, `GatewayStats::elapsed_secs` —
//! reads time as a [`Tick`] off an injected `Clock` instead of calling
//! `Instant::now()` directly. Under `SystemClock` the behavior is
//! exactly the pre-clock wall-time behavior; under `SimClock` the whole
//! scheduling stack becomes deterministic, instant, property-testable
//! code: the batcher aging tests assert *exact* virtual durations, and
//! the `serve::sim` discrete-event harness replays scripted traces with
//! zero wall-clock sleeps.
//!
//! # Tick
//!
//! A [`Tick`] is a point on one clock's timeline — nanoseconds since
//! that clock's epoch (construction time for `SystemClock`, t=0 for
//! `SimClock`). Ticks from different clocks are not comparable; the
//! serving stack threads one shared clock per server/gateway so every
//! stamp lives on one timeline.
//!
//! # Virtual waiting
//!
//! `SimClock::wait_until` *advances the clock* to the target instead of
//! sleeping: in a simulation the waiter owns time, and "nothing happens
//! until the deadline" is exactly the discrete-event semantics the
//! batcher's aging loop and the sim harness need. Code that would
//! otherwise block on a channel or condvar with a wall timeout checks
//! [`Clock::is_virtual`] and polls + `wait_until` instead, so a virtual
//! run never touches the wall clock.

use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A point on a [`Clock`]'s timeline: nanoseconds since the clock's
/// epoch. Ordered, copyable, and saturating at both ends (a latency
/// difference never underflows, a far deadline never overflows).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Tick(u64);

impl Tick {
    pub const ZERO: Tick = Tick(0);

    pub fn from_nanos(ns: u64) -> Tick {
        Tick(ns)
    }

    pub fn from_ms(ms: u64) -> Tick {
        Tick(ms.saturating_mul(1_000_000))
    }

    pub fn as_nanos(self) -> u64 {
        self.0
    }

    /// This tick advanced by `d`, saturating at the end of time.
    pub fn saturating_add(self, d: Duration) -> Tick {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        Tick(self.0.saturating_add(ns))
    }

    /// Elapsed time since `earlier`, zero if `earlier` is in the future
    /// (the same saturation `Instant::duration_since` callers had to
    /// hand-roll around clock skew).
    pub fn duration_since(self, earlier: Tick) -> Duration {
        Duration::from_nanos(self.0.saturating_sub(earlier.0))
    }

    /// `duration_since` in fractional milliseconds — the unit every
    /// latency histogram and stat in `serve` records.
    pub fn ms_since(self, earlier: Tick) -> f64 {
        self.duration_since(earlier).as_secs_f64() * 1e3
    }
}

/// The serving stack's time source. Implementations must be cheap to
/// read — `now` sits on the submit and dequeue hot paths.
pub trait Clock: Send + Sync {
    /// Current instant on this clock's timeline.
    fn now(&self) -> Tick;

    /// Block until `deadline`: `SystemClock` sleeps the wall-clock
    /// difference; `SimClock` advances the virtual clock to `deadline`
    /// and returns immediately (the waiter owns virtual time).
    fn wait_until(&self, deadline: Tick);

    /// True for manually-advanced clocks: time-bounded waits must poll
    /// + `wait_until` instead of blocking on wall-clock timeouts.
    fn is_virtual(&self) -> bool {
        false
    }
}

/// Wall-clock time, epoch = construction. The production clock: under
/// it the serving stack behaves exactly as the pre-clock
/// `Instant::now()` code did.
pub struct SystemClock {
    epoch: Instant,
}

impl SystemClock {
    pub fn new() -> SystemClock {
        SystemClock { epoch: Instant::now() }
    }
}

impl Default for SystemClock {
    fn default() -> Self {
        SystemClock::new()
    }
}

impl Clock for SystemClock {
    fn now(&self) -> Tick {
        Tick(self.epoch.elapsed().as_nanos().min(u64::MAX as u128) as u64)
    }

    fn wait_until(&self, deadline: Tick) {
        let now = self.now();
        if deadline > now {
            std::thread::sleep(deadline.duration_since(now));
        }
    }
}

/// Manually-advanced virtual clock for deterministic tests and the
/// `serve::sim` harness. Starts at [`Tick::ZERO`]; time moves only via
/// [`SimClock::advance`]/[`SimClock::advance_to`] (or a virtual waiter's
/// `wait_until`). Monotonic: advancing to the past is a no-op.
pub struct SimClock {
    now: Mutex<u64>,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock { now: Mutex::new(0) }
    }

    /// Move the clock forward by `d`.
    pub fn advance(&self, d: Duration) {
        let mut g = self.now.lock().unwrap();
        let t = Tick(*g).saturating_add(d);
        *g = t.as_nanos();
    }

    /// Move the clock forward to `t` (no-op if `t` is not in the
    /// future — virtual time never runs backward).
    pub fn advance_to(&self, t: Tick) {
        let mut g = self.now.lock().unwrap();
        if t.as_nanos() > *g {
            *g = t.as_nanos();
        }
    }
}

impl Default for SimClock {
    fn default() -> Self {
        SimClock::new()
    }
}

impl Clock for SimClock {
    fn now(&self) -> Tick {
        Tick(*self.now.lock().unwrap())
    }

    fn wait_until(&self, deadline: Tick) {
        self.advance_to(deadline);
    }

    fn is_virtual(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tick_arithmetic_saturates() {
        let t = Tick::from_ms(5);
        assert_eq!(t.as_nanos(), 5_000_000);
        assert_eq!(t.saturating_add(Duration::from_millis(3)), Tick::from_ms(8));
        // differences never underflow: "earlier minus later" is zero
        assert_eq!(Tick::ZERO.duration_since(t), Duration::ZERO);
        assert_eq!(t.duration_since(Tick::ZERO), Duration::from_millis(5));
        assert_eq!(t.ms_since(Tick::ZERO), 5.0);
        assert_eq!(Tick::ZERO.ms_since(t), 0.0);
        // far deadlines clamp at the end of time instead of wrapping
        let far = Tick::from_nanos(u64::MAX);
        assert_eq!(far.saturating_add(Duration::from_secs(1)), far);
    }

    #[test]
    fn sim_clock_is_manual_and_monotonic() {
        let c = SimClock::new();
        assert_eq!(c.now(), Tick::ZERO);
        assert!(c.is_virtual());
        c.advance(Duration::from_millis(10));
        assert_eq!(c.now(), Tick::from_ms(10));
        // advancing into the past is a no-op
        c.advance_to(Tick::from_ms(3));
        assert_eq!(c.now(), Tick::from_ms(10));
        // a virtual waiter owns time: waiting advances the clock
        c.wait_until(Tick::from_ms(25));
        assert_eq!(c.now(), Tick::from_ms(25));
    }

    #[test]
    fn system_clock_advances_and_waits() {
        let c = SystemClock::new();
        assert!(!c.is_virtual());
        let a = c.now();
        // a deadline already in the past returns immediately
        c.wait_until(Tick::ZERO);
        let b = c.now();
        assert!(b >= a, "wall clock went backward");
    }
}
