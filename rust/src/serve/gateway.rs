//! Multi-replica serving gateway: admission control, length-bucketed
//! dynamic batching, work-conserving deadline-aware scheduling, live
//! latency histograms — on an injected [`Clock`].
//!
//! ```text
//!  clients ──▶ GatewaySubmitter ──▶ [bounded, bucketed queue] ──▶ replica 0 (pool)
//!                 (admission:           one queue per length         replica 1 (pool)
//!                  Reject | Block)      bucket; sched core           ...
//!                                       picks/pops/sheds
//! ```
//!
//! # Admission control
//!
//! The queue is bounded (`queue_capacity`). When it is full,
//! [`ShedPolicy::Reject`] refuses new work immediately with a
//! [`Shed::QueueFull`] carrying a retry hint (estimated drain time), so
//! overload degrades p99 gracefully instead of growing latency without
//! bound; [`ShedPolicy::Block`] parks the submitter until space frees —
//! the closed-loop producer's natural backpressure.
//!
//! # Scheduling
//!
//! Requests route to the narrowest [`BucketLayout`] bucket admitting
//! their (canonical) length, and a batch is always formed within one
//! bucket, so batchmates have similar cost. Everything else is a
//! [`SchedPolicy`] decision made by the shared scheduling core
//! (`serve::sched` — the exact code the deterministic `serve::sim`
//! harness proves properties about):
//!
//! * [`SchedPolicy::Conserve`] (default) — **work conservation**: an
//!   idle replica drains the bucket holding the globally most urgent
//!   deadline (or the deepest bucket when no deadline is queued), and a
//!   partial batch never parks on its aging wait while any bucket still
//!   holds work; **deadline-earliest-first** dequeue within a bucket.
//! * [`SchedPolicy::Fifo`] — the PR-3 globally-FIFO scheduler, kept
//!   verbatim as the A/B baseline (fig9 carries a `sched` column).
//!
//! Batch shape is per-bucket: a [`BatchPolicyTable`] keyed by bucket
//! width gives narrow buckets wider `max_batch` and shorter `max_wait`
//! (their requests are cheap), wide buckets the base policy.
//!
//! # The determinism contract
//!
//! Buckets and scheduling decide *grouping and order only*. Each request
//! computes at its content-canonical `model::encoder::bucket_len` width
//! and draws randomness from the width-keyed serving RNG stream
//! (`model::encoder::serving_rng`), so logits are a pure function of
//! (config seed, request content): bit-identical across every bucket
//! layout, replica count, batch placement, arrival order, **and
//! scheduling policy**, and bit-identical to the single-loop
//! `ServerHandle::spawn_cpu` path (property-tested). `bucketing: false`
//! disables the canonical width (everything pads to `max_len`, the
//! legacy cost model) and is kept as the fig9 baseline.
//!
//! # Prefix caching
//!
//! Streamable attention variants (`attention::yoso_variant`) serve
//! through a byte-budgeted LRU [`PrefixCache`] of incremental
//! [`EncoderStream`] sessions: a request that extends a cached prefix
//! at the same canonical width checks the session out, appends only its
//! new tokens (O(m·dv) each), classifies, and publishes the grown
//! session back. The streamed path is bit-identical to the batch
//! recompute (property-tested), so hits move wall-clock only — never
//! logits — and the determinism contract above is unchanged.
//! `cache_hits`/`cache_misses` surface in [`GatewayStats`];
//! `prefix_cache_bytes: 0` disables the cache, and non-streamable
//! variants always take the batch `serve_forward` path.
//!
//! # Graceful degradation: shed compute, not users
//!
//! YOSO's hash-round count `m` trades approximation error for latency
//! linearly, per readout, with no retraining and no session rebuild
//! (the m'-prefix contract in `attention::stream`). The gateway turns
//! that into an overload ladder:
//!
//! * every request carries a [`Quality`] class — `Full` (never
//!   degraded), `Degraded(m')` (pinned to at most `m'` rounds), or
//!   `BestEffort` (the default: the overload controller decides);
//! * a [`DegradeLadder`] (`GatewayConfig::degrade`; disabled by
//!   default) maps the EWMA backlog estimate to a reduced `m'` — under
//!   pressure, best-effort batches step down to e.g. m'∈{16, 8}
//!   *before* the deadline shedder starts shedding users. The decision
//!   is made once per batch at formation time, off the backlog left
//!   behind it;
//! * with `admission_edf: true`, a request whose relative deadline is
//!   already below the degraded-rate drain estimate is rejected at
//!   admission ([`Shed::DeadlineInfeasible`], counted in
//!   `rejected_infeasible`) instead of queuing to die;
//! * retry hints (both shed variants) quote the **degraded** service
//!   rate whenever the ladder is active — a client told "retry in N ms"
//!   must be told the N the ladder can actually deliver.
//!
//! Degraded readouts stay deterministic: a request served at `m'` gets
//! bytes identical to a full encode with an `m == m'` attention at the
//! same width and seed (property-tested). `Full`/`Degraded` logits are
//! therefore still a pure function of (seed, content, quality);
//! `BestEffort` logits additionally depend on the load the controller
//! reacted to — that is the documented trade. Per-quality counters
//! (`served_full`/`served_degraded`) land in [`GatewayStats`], and the
//! ladder is sim-proven on an overload trace in `tests/sim_gateway.rs`
//! (degradation serves strictly more within-deadline requests than
//! shed-only).
//!
//! # Deadlines
//!
//! A request may carry a deadline. Dequeue is deadline-aware: an expired
//! request is shed *before execution* — its reply channel delivers
//! [`Shed::DeadlineExpired`] and it counts in `shed_deadline`, never
//! silently dropped. Stats reconcile: `accepted == completed +
//! shed_deadline`. Under `Conserve`, deadline-bearing requests also
//! dequeue ahead of deadline-free ones within their bucket.
//!
//! # Time
//!
//! Every timestamp (enqueue, deadline expiry, batch aging, EWMA service
//! estimate, `GatewayStats::elapsed_secs`) reads an injected
//! [`Clock`] as a [`Tick`]. [`Gateway::spawn`] uses the wall-clock
//! [`SystemClock`]; [`Gateway::spawn_with_clock`] accepts any clock.
//! Note the replica threads' *blocking* waits (condvar parking) convert
//! tick differences to wall durations, so a live gateway needs a clock
//! whose ticks track wall time — fully-virtual scheduling runs belong to
//! the thread-free `serve::sim` harness, which drives this module's
//! scheduling core directly on a `SimClock`.
//!
//! # Observability
//!
//! Every replica records per-request latency into its own log-bucketed
//! [`Histogram`] (plus per-bucket histograms and a queue-depth gauge
//! sampled at each dequeue); shutdown merges them into [`GatewayStats`],
//! which renders p50/p95/p99 per bucket and per replica and can emit
//! everything into a `metrics::Recorder` for the CSV/JSON reports.
//!
//! # Robustness: no admitted request is lost
//!
//! At fleet scale replica death is traffic, not an exception, so the
//! gateway holds a terminal-outcome contract: **every admitted request
//! reaches exactly one of replied / deadline-shed / failed**, never a
//! silently dropped reply channel. Four layers enforce it:
//!
//! * **Panic isolation** — each per-request forward runs under
//!   `catch_unwind`, so a poisoned request fails terminally
//!   ([`Shed::InternalError`], counted in `failed_internal`) while its
//!   batch-mates complete normally. The reply is sent exactly once, on
//!   either side of the catch.
//! * **Replica supervision** (`GatewayConfig::supervised`, default on)
//!   — a worker thread whose replica loop dies outside the per-request
//!   catch restarts in place: partial [`ReplicaStats`] survive (they
//!   live outside the unwind), `ReplicaDied`/`ReplicaRestarted` trace
//!   events fire, and the batch the dead replica held is **requeued**
//!   in seq position (EDF ordering and deadline sheds stay correct)
//!   under a bounded per-request `retry_budget` — a request that keeps
//!   killing replicas fails terminally instead of crash-looping the
//!   fleet.
//! * **Poison-proof shared state** — every lock/condvar wait on the
//!   control mutex, the per-bucket lanes, and the steal board recovers
//!   from mutex poisoning before proceeding; the prefix cache
//!   recovers via [`PrefixCache::repair`], and a session checked out by
//!   a dying replica is discarded by its [`SessionLease`] drop-guard,
//!   never published back half-appended.
//! * **Deterministic fault injection** (`GatewayConfig::fault`) — a
//!   seeded [`FaultPlan`] injects request panics, replica kills,
//!   stalls, and abandoned cache leases keyed by admission seq, in both
//!   this live gateway and the virtual-clock `serve::sim`. The chaos
//!   property suite (`tests/chaos_gateway.rs`) proves the terminal-
//!   outcome partition *and* that every delivered reply is bit-identical
//!   to the fault-free run.
//!
//! # Sharded scheduling: no global queue mutex
//!
//! The queues live in a [`ShardedQueues`]: one lock per length bucket
//! plus atomic depth/deadline counters, so admission and every replica
//! contend per-lane, never on one gateway-wide mutex. Control state
//! that must stay coherent across readers (the service-time EWMA and
//! the degradation ladder's hysteresis) sits behind a small `ctrl`
//! mutex touched once per batch; the hot counters are plain atomics.
//! Lanes are seq-keyed B-trees, so two submitters racing into the same
//! bucket still land in admission order — the schedule the sharded
//! layout produces is proven bit-identical to the single-lock layout
//! on adversarial traces (`tests/sim_gateway.rs`).
//!
//! Every replica park is **heartbeat-bounded** (`GatewayConfig::
//! heartbeat`): condvar wake-ups are a latency optimization, the
//! timeout is the progress guarantee — an idle replica re-examines the
//! queues (and the steal board) at least once per heartbeat, so a
//! missed notify can delay work by one tick, never strand it.
//!
//! # Cross-replica batch stealing
//!
//! With `GatewayConfig::steal` on, each replica owns a slot on a steal
//! board. A partial batch entering its aging park is published there;
//! a batch about to wedge on an injected stall is posted there too.
//! An idle replica that finds every lane empty scans the board:
//!
//! * a **parked partial** with two or more members is split — the
//!   victim keeps the front (older-seq) half, the thief takes the tail
//!   as a fresh batch (its own formation events and ladder decision);
//! * a **posted batch** older than one heartbeat is taken whole: the
//!   wedged victim wakes to an empty slot and skips execution, and the
//!   already-formed batch runs on the thief — stolen or requeued
//!   within the heartbeat bound, never parked behind a stalled peer.
//!
//! Stealing moves whole entries between replicas under one slot lock,
//! so it never reorders within a bucket and never loses an admitted
//! request — the chaos accounting identity (`accepted == completed +
//! shed_deadline + failed_internal`) holds under stealing
//! (`tests/chaos_gateway.rs`).

use super::batcher::BatchPolicy;
use super::cache::{PrefixCache, SessionLease};
use super::clock::{Clock, SystemClock, Tick};
use super::fault::FaultPlan;
use super::sched::{
    admission_cap, deadline_infeasible, update_ewma, BatchPolicyTable,
    DegradeLadder, DegradePlan, Entry, LadderState, SchedPolicy,
    ShardedQueues,
};
use super::server::{
    build_attention, canonicalize, resolve_threads, serve_forward,
    CpuServeConfig,
};
use super::Response;
use crate::attention::{yoso_variant, Attention, YosoAttention};
use crate::metrics::{Histogram, Recorder};
use crate::obs::{
    self, CacheTag, Event, EventKind, QualityTag, ShedTag, TraceSink,
};
use crate::model::encoder::{
    bucket_len, encoder_abi_spec, pow2_floor, Encoder, EncoderStream,
};
use crate::model::ParamSet;
use crate::util::threadpool::ThreadPool;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// Sequence-length buckets for batch grouping: sorted widths, a request
/// routes to the narrowest bucket covering its canonical length (the
/// last bucket takes everything longer).
#[derive(Clone, Debug)]
pub struct BucketLayout {
    widths: Vec<usize>,
}

impl BucketLayout {
    /// Power-of-two widths doubling from `min` up to (and always
    /// including) `max_len`.
    pub fn pow2(min: usize, max_len: usize) -> BucketLayout {
        let mut widths = Vec::new();
        let mut w = min.max(8);
        while w < max_len {
            widths.push(w);
            w *= 2;
        }
        widths.push(max_len);
        BucketLayout { widths }
    }

    /// One bucket at `max_len` — the unbucketed layout.
    pub fn single(max_len: usize) -> BucketLayout {
        BucketLayout { widths: vec![max_len.max(1)] }
    }

    pub fn widths(&self) -> &[usize] {
        &self.widths
    }

    /// Index of the narrowest bucket admitting `len` (the widest bucket
    /// admits everything).
    pub(crate) fn bucket_for(&self, len: usize) -> usize {
        self.widths
            .iter()
            .position(|&w| len <= w)
            .unwrap_or(self.widths.len() - 1)
    }

    /// Sorted, deduped, clamped into (0, max_len]; empty layouts
    /// degrade to `single(max_len)`.
    pub(crate) fn normalized(&self, max_len: usize) -> BucketLayout {
        let mut widths: Vec<usize> = self
            .widths
            .iter()
            .map(|&w| w.clamp(1, max_len))
            .collect();
        widths.sort_unstable();
        widths.dedup();
        if widths.is_empty() {
            return BucketLayout::single(max_len);
        }
        BucketLayout { widths }
    }
}

/// Per-request quality class: how far the gateway may trade hash
/// rounds (and thus approximation error) for latency on this request.
///
/// A YOSO readout at `m' <= m` hash rounds costs `O(m'·dv)` and is
/// bit-identical to a fresh `m'`-round forward at the same seed and
/// width (the m'-prefix contract in [`crate::attention::YosoStream`]),
/// so degraded service needs no retraining, no session rebuild, and no
/// second model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Quality {
    /// Never degraded: always served at the configured full `m`, even
    /// when the overload controller has stepped best-effort traffic
    /// down. Logits are a pure function of (seed, content).
    Full,
    /// Pinned to at most this many hash rounds (clamped into
    /// `[1, m_full]`), regardless of load — a client that has accepted
    /// the error-vs-m' trade up front. Deterministic per (seed,
    /// content, m').
    Degraded(usize),
    /// The default: served at full quality when the gateway is keeping
    /// up, stepped down the [`DegradeLadder`] under overload. Logits
    /// may therefore vary with load — the one documented exception to
    /// the pure-function determinism contract.
    #[default]
    BestEffort,
}

/// Why the gateway refused or dropped a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shed {
    /// Rejected at admission: the bounded queue is at capacity. The hint
    /// estimates when the backlog will have drained.
    QueueFull { retry_after_ms: u64 },
    /// Rejected at admission: the request's deadline is shorter than
    /// the estimated backlog drain time even at the degraded service
    /// rate — queuing it would only manufacture a deadline shed.
    /// Requires `GatewayConfig::admission_edf` and a warm service
    /// estimate; the hint quotes the degraded-rate drain time.
    DeadlineInfeasible { retry_after_ms: u64 },
    /// Admitted, but the deadline expired before a replica reached it.
    DeadlineExpired,
    /// The gateway has shut down.
    Closed,
    /// Admitted, but failed terminally inside the gateway: the
    /// request's own forward panicked (panic isolation caught it), or
    /// repeated replica crashes exhausted its retry budget. Carries the
    /// admission seq so operators can cross-reference the trace, and
    /// the number of crash-requeues the request survived before the
    /// terminal outcome (0 for a plain forward panic).
    InternalError { seq: u64, retries: u32 },
    /// The reply never arrived within the caller's wait budget
    /// ([`await_reply`] / `submit_wait`): the bound that turns a lost
    /// reply channel into a timely client-side error instead of a hang.
    /// Carries the budget waited, in ms.
    ReplyLost { waited_ms: u64 },
}

impl std::fmt::Display for Shed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Shed::QueueFull { retry_after_ms } => {
                write!(f, "queue full (retry after ~{retry_after_ms} ms)")
            }
            Shed::DeadlineInfeasible { retry_after_ms } => write!(
                f,
                "deadline infeasible under current backlog \
                 (retry after ~{retry_after_ms} ms)"
            ),
            Shed::DeadlineExpired => write!(f, "deadline expired in queue"),
            Shed::Closed => write!(f, "gateway shut down"),
            Shed::InternalError { seq, retries } => write!(
                f,
                "internal failure serving request seq {seq} \
                 (after {retries} crash retries)"
            ),
            Shed::ReplyLost { waited_ms } => {
                write!(f, "no reply within {waited_ms} ms (reply lost)")
            }
        }
    }
}

impl std::error::Error for Shed {}

/// Overload behavior at admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Refuse new work when the queue is full — open-loop traffic sheds
    /// instead of stacking unbounded latency.
    Reject,
    /// Park the submitter until space frees — closed-loop backpressure.
    Block,
}

/// What a request's reply channel delivers: logits, or the shed reason.
pub type GatewayReply = Result<Response, Shed>;

/// Deadline-bounded reply wait: the client-side half of the
/// no-request-lost contract. Blocks at most `timeout` for the reply;
/// a sender dropped without replying (or a reply that simply never
/// comes) surfaces as [`Shed::ReplyLost`] instead of hanging the
/// caller forever. A dropped sender returns immediately — `timeout`
/// is the worst case, not the wait.
pub fn await_reply(
    rx: &Receiver<GatewayReply>,
    timeout: Duration,
) -> GatewayReply {
    match rx.recv_timeout(timeout) {
        Ok(reply) => reply,
        Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => {
            Err(Shed::ReplyLost {
                waited_ms: timeout.as_millis().min(u64::MAX as u128) as u64,
            })
        }
    }
}

/// Gateway configuration. `base.threads` is the worker-pool width of
/// **each replica** (0 = every available core — set it explicitly when
/// running several replicas, or the pools oversubscribe).
#[derive(Clone, Debug)]
pub struct GatewayConfig {
    pub base: CpuServeConfig,
    /// replica workers, each owning its params handle, attention
    /// instance, and thread-pool shard (0 degrades to 1)
    pub replicas: usize,
    /// bound on admitted-but-unexecuted requests (0 degrades to 1)
    pub queue_capacity: usize,
    pub shed: ShedPolicy,
    /// per-bucket batch policies keyed by bucket width (max batch size,
    /// max wait aged from the first request's enqueue time); the default
    /// width-scales the base policy — narrow buckets batch wider and
    /// wait shorter
    pub batch: BatchPolicyTable,
    pub buckets: BucketLayout,
    /// cross-bucket scheduling policy: work-conserving deadline-aware
    /// `Conserve` (default) or the PR-3 `Fifo` A/B baseline
    pub sched: SchedPolicy,
    /// true: requests compute at their content-canonical `bucket_len`
    /// width (O(bucket), the point of this subsystem); false: everything
    /// pads to `encoder.max_len` — the legacy cost model, kept as the
    /// fig9 baseline
    pub bucketing: bool,
    /// byte budget for the gateway-wide prefix/session cache
    /// ([`PrefixCache`]); 0 disables it. Only consulted when the
    /// configured attention is streamable (`attention::yoso_variant`)
    pub prefix_cache_bytes: usize,
    /// overload degradation ladder for `BestEffort` traffic: EWMA
    /// backlog thresholds (ms) mapped to reduced hash-round counts.
    /// Disabled by default ([`DegradeLadder::none`]); only effective
    /// when the configured attention is streamable
    pub degrade: DegradeLadder,
    /// true: reject at admission any request whose relative deadline is
    /// already below the (degraded-rate) backlog drain estimate —
    /// [`Shed::DeadlineInfeasible`]. A cold service estimate never
    /// rejects. Default false
    pub admission_edf: bool,
    /// true: record flight-recorder lifecycle events
    /// (admitted/queued/batch_formed/exec/replied/shed) into a per-lane
    /// [`TraceSink`] readable via [`Gateway::trace_sink`]. Defaults from
    /// the `YOSO_TRACE` env var (see [`obs::trace_enabled`]); the
    /// disabled path emits nothing and allocates nothing
    pub trace: bool,
    /// fraction of `queue_capacity` reserved for `BestEffort` traffic
    /// (clamped into [0, 1]; default 0.0 = no reservation): guaranteed
    /// classes admit only into the unreserved remainder, so `Full`
    /// traffic cannot crowd best-effort out entirely (see
    /// `sched::admission_cap`)
    pub best_effort_reserve: f64,
    /// how many times one request may be pulled back out of a dying
    /// replica's batch and requeued before it fails terminally with
    /// [`Shed::InternalError`] (default 2: the request survives up to
    /// two replica crashes and rides the third attempt or fails)
    pub retry_budget: u32,
    /// true (default): each replica worker supervises its loop —
    /// a panic that escapes per-request isolation restarts the loop in
    /// place instead of killing the thread. false is the pre-supervision
    /// baseline, kept for the fig9 overhead A/B
    pub supervised: bool,
    /// true: idle replicas steal work — the tail of a peer's parked
    /// partial batch, or (whole) a batch posted to the steal board
    /// that has sat past one `heartbeat` (a wedged replica). Default
    /// false: the non-stealing schedule is the fig9 A/B baseline and
    /// the one the sim bit-identity gate pins
    pub steal: bool,
    /// progress bound for every replica park and the steal-board
    /// staleness threshold: an idle replica re-examines the queues
    /// (and the board, with `steal` on) at least once per heartbeat,
    /// so a stalled batch is stolen or requeued within this bound
    /// (default 5 ms)
    pub heartbeat: Duration,
    /// deterministic fault-injection plan (empty in production configs
    /// — [`FaultPlan::none`] — at one branch per batch on the hot path)
    pub fault: FaultPlan,
}

impl GatewayConfig {
    pub fn new(base: CpuServeConfig) -> GatewayConfig {
        let max_len = base.encoder.max_len;
        GatewayConfig {
            base,
            replicas: 1,
            queue_capacity: 256,
            shed: ShedPolicy::Reject,
            batch: BatchPolicyTable::scaled(BatchPolicy::default()),
            buckets: BucketLayout::pow2(16, max_len),
            sched: SchedPolicy::Conserve,
            bucketing: true,
            prefix_cache_bytes: 64 << 20,
            degrade: DegradeLadder::none(),
            admission_edf: false,
            trace: obs::trace_enabled(),
            best_effort_reserve: 0.0,
            retry_budget: 2,
            supervised: true,
            steal: false,
            heartbeat: Duration::from_millis(5),
            fault: FaultPlan::none(),
        }
    }
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig::new(CpuServeConfig::default())
    }
}

/// The request bytes + reply channel a queued entry carries (the
/// scheduling core is payload-generic; this is the live gateway's
/// payload).
struct GwPayload {
    ids: Vec<i32>,
    segs: Vec<i32>,
    quality: Quality,
    reply: Sender<GatewayReply>,
}

type GwEntry = Entry<GwPayload>;

/// The EWMA/ladder pair behind the small control mutex: the only
/// gateway state whose readers need cross-field coherence. Everything
/// else (queues, counters) is sharded or atomic.
struct GwCtrl {
    /// EWMA of **full-quality** per-request service time, feeding the
    /// retry hint and the degradation ladder; degraded batches scale
    /// their sample back up by `m_full / m_eff` before blending so the
    /// estimate keeps one meaning under load. `None` until the first
    /// batch completes — explicit warm-up, so a genuine 0.0 ms estimate
    /// (zero-duration service on a virtual clock) is not mistaken for
    /// "cold"
    svc_ewma_ms: Option<f64>,
    /// degradation-ladder hysteresis state: the rung currently being
    /// served and the step-up lag timer. Mutated only at batch
    /// formation (`DegradeLadder::plan_at`); admission-side reads use
    /// the read-only `peek_at`
    ladder_state: LadderState,
}

/// One steal-board entry: a batch a replica has made visible to idle
/// peers. `parked: true` is a partial batch sitting out its aging wait
/// (peers may split its tail off); `parked: false` is a fully-formed
/// batch posted just before a potentially-wedging operation (peers take
/// it whole once it has sat past one heartbeat).
struct StealSlot {
    bucket: usize,
    /// the formation-time ladder decision, carried so a whole-stolen
    /// batch executes exactly as formed (the ladder is not re-run)
    m_eff: usize,
    entries: Vec<GwEntry>,
    /// when the slot was posted — the whole-steal staleness clock
    since: Tick,
    parked: bool,
}

/// Everything shared between submitters, replicas, and the handle.
///
/// There is no global scheduling mutex: the queues shard one lock per
/// bucket lane ([`ShardedQueues`]), counters are atomics, and the
/// `ctrl` mutex guards only the EWMA/ladder pair. Capacity is enforced
/// by a CAS reservation on `depth` — admitted-but-unexecuted entries,
/// reserved before the lane push so the bound is exact even under
/// racing submitters.
struct GwShared {
    queues: ShardedQueues<GwPayload>,
    ctrl: Mutex<GwCtrl>,
    /// admission closed (shutdown); replicas drain, submitters reject
    closed: AtomicBool,
    /// admitted-but-unexecuted count: the capacity reservation ledger.
    /// Grows at admission (CAS against `capacity`) and requeue,
    /// shrinks as entries pop into batches or shed
    depth: AtomicUsize,
    next_seq: AtomicU64,
    accepted: AtomicU64,
    rejected: AtomicU64,
    /// admission-time EDF rejections (deadline < degraded-rate drain
    /// estimate); disjoint from `rejected` (queue-full)
    rejected_infeasible: AtomicU64,
    shed_deadline: AtomicU64,
    /// admitted requests that failed terminally
    /// ([`Shed::InternalError`]): the request's own forward panicked,
    /// or its retry budget ran out under replica crashes
    failed_internal: AtomicU64,
    /// requests pulled back out of a dying replica's batch and
    /// re-inserted in seq position (one per requeue, so a request can
    /// count up to `retry_budget` times)
    requeued: AtomicU64,
    /// supervised replica-loop restarts
    replica_restarts: AtomicU64,
    /// batches (or batch tails) taken by an idle peer off the steal
    /// board
    stolen: AtomicU64,
    peak_queue_depth: AtomicUsize,
    /// one slot per replica: parked partials and posted pre-stall
    /// batches, visible to idle peers (empty Vec when stealing is off)
    steal_board: Vec<Mutex<Option<StealSlot>>>,
    /// replicas park here for work; submitters notify. All waits are
    /// heartbeat-bounded: the notify is an optimization, never the
    /// progress guarantee
    work_cv: Condvar,
    /// blocked submitters park here for space; dequeues notify
    space_cv: Condvar,
    clock: Arc<dyn Clock>,
    capacity: usize,
    replicas: usize,
    policy: ShedPolicy,
    sched: SchedPolicy,
    batch: BatchPolicyTable,
    route: BucketLayout,
    vocab_size: usize,
    max_len: usize,
    /// streamed-session prefix cache (`None`: disabled, or the
    /// configured attention variant is not streamable)
    cache: Option<Mutex<PrefixCache>>,
    /// overload ladder for best-effort traffic; `none()` when disabled
    /// or the attention variant is not streamable
    ladder: DegradeLadder,
    /// the configured attention's full hash-round count (1 for
    /// non-streamable variants — the `m_eff == m_full` identity then
    /// makes every plan a no-op)
    m_full: usize,
    /// admission-time EDF feasibility rejection enabled
    admission_edf: bool,
    /// flight-recorder event sink; `None` when tracing is off — the
    /// disabled path is one branch per would-be event
    trace: Option<Arc<TraceSink>>,
    /// queue-capacity fraction reserved for `BestEffort` (see
    /// `GatewayConfig::best_effort_reserve`)
    reserve: f64,
    /// per-request requeue budget under replica crashes
    retry_budget: u32,
    /// replica loops restart in place after an escaped panic
    supervised: bool,
    /// idle replicas scavenge the steal board
    steal: bool,
    /// park bound and steal-board staleness threshold
    heartbeat: Duration,
    /// deterministic fault-injection plan (empty in production)
    fault: FaultPlan,
}

impl GwShared {
    /// Lock the control state, recovering from poison: a replica that
    /// panicked while holding the lock must not cascade its death into
    /// every submitter and peer via `lock().unwrap()`. The guarded
    /// fields (EWMA, ladder hysteresis) are each written atomically
    /// from the caller's point of view, so no repair sweep is needed —
    /// the queues' own lanes self-recover inside [`ShardedQueues`].
    fn lock_ctrl(&self) -> MutexGuard<'_, GwCtrl> {
        match self.ctrl.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                self.ctrl.clear_poison();
                poisoned.into_inner()
            }
        }
    }

    /// `work_cv.wait_timeout` on the ctrl mutex, with poison recovery.
    /// Every caller bounds the wait (heartbeat or aging deadline) and
    /// re-checks its condition on wake — the notify is advisory.
    fn wait_work_timeout<'a>(
        &self,
        g: MutexGuard<'a, GwCtrl>,
        dur: Duration,
    ) -> MutexGuard<'a, GwCtrl> {
        match self.work_cv.wait_timeout(g, dur) {
            Ok((g, _)) => g,
            Err(poisoned) => {
                self.ctrl.clear_poison();
                let (g, _) = poisoned.into_inner();
                g
            }
        }
    }

    /// `space_cv.wait_timeout` with poison recovery; same advisory-
    /// notify contract as [`wait_work_timeout`].
    fn wait_space_timeout<'a>(
        &self,
        g: MutexGuard<'a, GwCtrl>,
        dur: Duration,
    ) -> MutexGuard<'a, GwCtrl> {
        match self.space_cv.wait_timeout(g, dur) {
            Ok((g, _)) => g,
            Err(poisoned) => {
                self.ctrl.clear_poison();
                let (g, _) = poisoned.into_inner();
                g
            }
        }
    }

    /// Reserve one admission slot against `capacity` (CAS, exact even
    /// under racing submitters). Returns false when the queue is full.
    fn try_reserve(&self, cap: usize) -> bool {
        match self
            .depth
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                (d < cap).then_some(d + 1)
            }) {
            Ok(prev) => {
                self.peak_queue_depth.fetch_max(prev + 1, Ordering::SeqCst);
                true
            }
            Err(_) => false,
        }
    }

    /// Return `n` freed slots to the capacity ledger and wake blocked
    /// submitters. Saturating: tests that inject entries directly into
    /// the lanes never reserved, and must not wrap the ledger.
    fn release_capacity(&self, n: usize) {
        if n == 0 {
            return;
        }
        let _ = self
            .depth
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                Some(d.saturating_sub(n))
            });
        self.space_cv.notify_all();
    }

    /// Return one freed slot without waking submitters — the
    /// scheduling round batches its `space_cv` notify per batch/park,
    /// not per pop (a per-pop notify_all would wake every Block-mode
    /// submitter O(batch × waiters) times).
    fn free_slot_quiet(&self) {
        let _ = self
            .depth
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |d| {
                Some(d.saturating_sub(1))
            });
    }

    /// One ladder decision off the current queue state: the rung for
    /// the full-quality backlog estimate, restated at the degraded
    /// drain rate. Retry hints and admission EDF both read this plan,
    /// so a client is always quoted the rate the ladder can deliver.
    /// Read-only: a pending hysteresis step-up shows its *held* rung
    /// (`peek_at`), so hints quote the rate actually being served.
    fn plan(&self, ctrl: &GwCtrl) -> DegradePlan {
        self.ladder.peek_at(
            &ctrl.ladder_state,
            self.queues.len(),
            ctrl.svc_ewma_ms,
            self.replicas,
            self.m_full,
        )
    }

    /// The read-side of [`plan`] for callers not already holding the
    /// ctrl lock: lock, peek, release.
    fn plan_now(&self) -> DegradePlan {
        let ctrl = self.lock_ctrl();
        self.plan(&ctrl)
    }

    /// Record a flight-recorder event if tracing is on (one branch when
    /// off; never blocks on any other lane when on).
    fn emit(&self, lane: usize, e: Event) {
        if let Some(sink) = &self.trace {
            sink.emit(lane, e);
        }
    }
}

/// Lock the prefix cache, recovering from poison via
/// [`PrefixCache::repair`] (recompute the byte ledger from residents
/// and re-apply eviction) — a replica dying mid-publish must not take
/// the cache down with it.
fn lock_cache(m: &Mutex<PrefixCache>) -> MutexGuard<'_, PrefixCache> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            m.clear_poison();
            let mut g = poisoned.into_inner();
            g.repair();
            g
        }
    }
}

/// Lock a steal-board slot, recovering from poison: every slot
/// mutation is a single `Option` replacement under the lock, so a
/// poisoned slot holds either the old or the new value — no repair
/// sweep needed.
fn lock_slot(
    m: &Mutex<Option<StealSlot>>,
) -> MutexGuard<'_, Option<StealSlot>> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            m.clear_poison();
            poisoned.into_inner()
        }
    }
}

/// The [`QualityTag`] a request's *submitted* quality class maps to.
fn quality_tag(q: Quality) -> QualityTag {
    match q {
        Quality::Full => QualityTag::Full,
        Quality::Degraded(_) => QualityTag::Degraded,
        Quality::BestEffort => QualityTag::BestEffort,
    }
}

/// Cloneable submission handle. Clones never pin the gateway open —
/// `Gateway::shutdown` closes the queue explicitly; later submits get
/// `Err(Shed::Closed)`.
#[derive(Clone)]
pub struct GatewaySubmitter {
    shared: Arc<GwShared>,
}

impl GatewaySubmitter {
    /// Submit one sequence. `Ok` hands back the reply receiver (which
    /// delivers logits or a post-admission shed); `Err` is an admission
    /// rejection — the request was never queued.
    pub fn submit(
        &self,
        input_ids: Vec<i32>,
        segment_ids: Vec<i32>,
    ) -> Result<Receiver<GatewayReply>, Shed> {
        self.submit_with_deadline(input_ids, segment_ids, None)
    }

    /// Submit with an optional deadline (relative to now, on the
    /// gateway's clock). A request still queued when its deadline passes
    /// is shed before execution and its receiver delivers
    /// `Err(Shed::DeadlineExpired)`.
    pub fn submit_with_deadline(
        &self,
        input_ids: Vec<i32>,
        segment_ids: Vec<i32>,
        deadline: Option<Duration>,
    ) -> Result<Receiver<GatewayReply>, Shed> {
        self.submit_with(input_ids, segment_ids, deadline, Quality::default())
    }

    /// Submit with an optional deadline and an explicit [`Quality`]
    /// class. With `GatewayConfig::admission_edf`, a deadline already
    /// infeasible under the degraded-rate backlog estimate is rejected
    /// here ([`Shed::DeadlineInfeasible`]) instead of queuing to die.
    pub fn submit_with(
        &self,
        input_ids: Vec<i32>,
        segment_ids: Vec<i32>,
        deadline: Option<Duration>,
        quality: Quality,
    ) -> Result<Receiver<GatewayReply>, Shed> {
        let sh = &*self.shared;
        let (ids, segs) =
            canonicalize(input_ids, segment_ids, sh.vocab_size, sh.max_len);
        let bucket = sh.route.bucket_for(ids.len());
        // the client-visible submission instant: deadlines AND latency
        // accounting both start here, so time parked at Block admission
        // is part of queue_wait/total_ms — under-reporting overload
        // latency would defeat the SLO stats this subsystem exists for
        let submitted = sh.clock.now();
        let abs_deadline = deadline.map(|d| submitted.saturating_add(d));
        // per-class admission cap: best-effort admits into the full
        // capacity, guaranteed classes only into the unreserved
        // remainder — the reservation keeps a slice of the queue that
        // `Full` traffic can never crowd best-effort out of
        let cap = admission_cap(
            sh.capacity,
            sh.reserve,
            matches!(quality, Quality::BestEffort),
        );
        loop {
            if sh.closed.load(Ordering::SeqCst) {
                sh.emit(
                    0,
                    Event::new(EventKind::Shed, submitted, obs::NO_SEQ)
                        .with_shed(ShedTag::Closed),
                );
                return Err(Shed::Closed);
            }
            // CAS reservation: the capacity bound is exact under racing
            // submitters without any global queue lock
            if sh.try_reserve(cap) {
                break;
            }
            match sh.policy {
                ShedPolicy::Reject => {
                    sh.rejected.fetch_add(1, Ordering::SeqCst);
                    sh.emit(
                        0,
                        Event::new(EventKind::Shed, submitted, obs::NO_SEQ)
                            .with_width(sh.route.widths[bucket])
                            .with_shed(ShedTag::QueueFull),
                    );
                    // quote the drain time the ladder would deliver,
                    // not the full-quality estimate: under a stepped-
                    // down gateway, the honest retry hint is shorter
                    return Err(Shed::QueueFull {
                        retry_after_ms: sh.plan_now().hint_ms(),
                    });
                }
                ShedPolicy::Block => {
                    // heartbeat-bounded park: the space notify is
                    // advisory (frees happen outside the ctrl lock),
                    // the timeout guarantees we re-check
                    let g = sh.lock_ctrl();
                    drop(sh.wait_space_timeout(g, sh.heartbeat));
                }
            }
        }
        if sh.admission_edf {
            if let Some(d) = deadline {
                let plan = sh.plan_now();
                // warm-estimate-only: a cold gateway never rejects on
                // feasibility (the estimate would be a guess). The
                // boundary case deadline == backlog is feasible.
                if deadline_infeasible(&plan, d) {
                    sh.rejected_infeasible.fetch_add(1, Ordering::SeqCst);
                    sh.emit(
                        0,
                        Event::new(EventKind::Shed, submitted, obs::NO_SEQ)
                            .with_width(sh.route.widths[bucket])
                            .with_shed(ShedTag::Infeasible),
                    );
                    // hand back the slot reserved above — the request
                    // never queues
                    sh.release_capacity(1);
                    return Err(Shed::DeadlineInfeasible {
                        retry_after_ms: plan.hint_ms(),
                    });
                }
            }
        }
        let (reply, rx) = channel();
        let seq = sh.next_seq.fetch_add(1, Ordering::SeqCst);
        let n_tokens = ids.len();
        let entry = Entry {
            seq,
            enqueued: submitted,
            deadline: abs_deadline,
            retries: 0,
            payload: GwPayload { ids, segs, quality, reply },
        };
        // lanes are seq-keyed B-trees, so two submitters racing into
        // the same bucket still land in seq order
        sh.queues.push(bucket, entry);
        sh.accepted.fetch_add(1, Ordering::SeqCst);
        // close race: the push may have slipped in after the replicas
        // observed `closed` and began their final drain. Re-checking
        // *after* the push closes the window — if the entry is still in
        // its lane we pull it back and reject; if a replica already
        // popped it, the reply is on its way.
        if sh.closed.load(Ordering::SeqCst) {
            if let Some(e) = sh.queues.remove(bucket, seq) {
                sh.accepted.fetch_sub(1, Ordering::SeqCst);
                sh.release_capacity(1);
                sh.emit(
                    0,
                    Event::new(EventKind::Shed, submitted, obs::NO_SEQ)
                        .with_shed(ShedTag::Closed),
                );
                drop(e);
                return Err(Shed::Closed);
            }
        }
        if sh.trace.is_some() {
            let base = Event::new(EventKind::Admitted, submitted, seq)
                .with_width(sh.route.widths[bucket])
                .with_quality(quality_tag(quality))
                .with_n(n_tokens);
            sh.emit(0, base);
            sh.emit(0, Event { kind: EventKind::Queued, ..base });
        }
        // notify_all, not notify_one: a replica parked in its batch
        // aging wait could swallow a single wake-up meant for an idle
        // peer watching a different bucket
        sh.work_cv.notify_all();
        Ok(rx)
    }
}

/// Per-replica serving statistics (merged into [`GatewayStats`]).
#[derive(Clone, Debug)]
pub struct ReplicaStats {
    pub replica: usize,
    pub requests: u64,
    pub batches: u64,
    /// requests served at the full configured hash-round count
    pub served_full: u64,
    /// requests served at a reduced m' — ladder step-down or a pinned
    /// `Quality::Degraded` class
    pub served_degraded: u64,
    /// end-to-end ms per request served by this replica
    pub latency: Histogram,
    /// queue-wait ms per request
    pub queue_wait: Histogram,
    /// queue depth sampled at each dequeue (a gauge-as-histogram)
    pub queue_depth: Histogram,
    /// end-to-end ms per routing bucket (indexed like the layout widths)
    pub per_bucket: Vec<Histogram>,
}

impl ReplicaStats {
    fn new(replica: usize, n_buckets: usize) -> ReplicaStats {
        ReplicaStats {
            replica,
            requests: 0,
            batches: 0,
            served_full: 0,
            served_degraded: 0,
            latency: Histogram::new(),
            queue_wait: Histogram::new(),
            queue_depth: Histogram::new(),
            per_bucket: (0..n_buckets).map(|_| Histogram::new()).collect(),
        }
    }
}

/// Aggregate gateway statistics, returned at shutdown.
///
/// Reconciliation invariants (asserted by the overload integration and
/// chaos tests): `accepted == completed + shed_deadline +
/// failed_internal`; `rejected` counts admission refusals, which were
/// never accepted.
#[derive(Clone, Debug)]
pub struct GatewayStats {
    pub accepted: u64,
    pub completed: u64,
    pub rejected: u64,
    /// admission-time EDF rejections ([`Shed::DeadlineInfeasible`]);
    /// disjoint from `rejected` (queue-full)
    pub rejected_infeasible: u64,
    pub shed_deadline: u64,
    /// admitted requests that failed terminally
    /// ([`Shed::InternalError`]): own-forward panic, or retry budget
    /// exhausted under replica crashes
    pub failed_internal: u64,
    /// requeue actions (a request pulled back out of a dying replica's
    /// batch; one request can count up to `retry_budget` times)
    pub requeued: u64,
    /// supervised replica-loop restarts
    pub replica_restarts: u64,
    /// batches (or parked-batch tails) taken by an idle replica off a
    /// peer's steal board (`GatewayConfig::steal`)
    pub stolen: u64,
    /// prefix-cache sessions discarded by a dropped [`SessionLease`]
    /// (abandoned mid-encode by a dying request, never published back)
    pub cache_abandoned: u64,
    /// completions served at the full configured hash-round count
    pub served_full: u64,
    /// completions served at a reduced m' (ladder step-down or pinned
    /// `Quality::Degraded`); `served_full + served_degraded ==
    /// completed`
    pub served_degraded: u64,
    /// requests served by extending a cached [`PrefixCache`] session
    pub cache_hits: u64,
    /// streamed requests that found no cached prefix and started a
    /// fresh session; 0 when the cache is disabled (the batch path
    /// counts neither way)
    pub cache_misses: u64,
    pub batches: u64,
    pub peak_queue_depth: usize,
    pub latency: Histogram,
    pub queue_wait: Histogram,
    pub queue_depth: Histogram,
    pub bucket_widths: Vec<usize>,
    pub per_bucket: Vec<Histogram>,
    pub per_replica: Vec<ReplicaStats>,
    pub elapsed_secs: f64,
    pub throughput_rps: f64,
}

impl GatewayStats {
    /// Fraction of offered requests that were shed (either side of
    /// admission — queue-full and infeasible-deadline rejections plus
    /// in-queue deadline sheds and terminal internal failures). 0.0 —
    /// never NaN — when nothing was offered.
    pub fn shed_rate(&self) -> f64 {
        let offered =
            self.accepted + self.rejected + self.rejected_infeasible;
        if offered == 0 {
            0.0
        } else {
            (self.rejected
                + self.rejected_infeasible
                + self.shed_deadline
                + self.failed_internal) as f64
                / offered as f64
        }
    }

    /// Prefix-cache hit rate over all streamed probes. 0.0 — never
    /// NaN — when no request ever probed the cache (cache disabled, or
    /// the batch path served everything).
    pub fn cache_hit_rate(&self) -> f64 {
        let probes = self.cache_hits + self.cache_misses;
        if probes == 0 {
            0.0
        } else {
            self.cache_hits as f64 / probes as f64
        }
    }

    /// Emit counters, percentiles, and per-bucket/per-replica series
    /// into a `Recorder`, from which `write_csv`/`write_json` produce
    /// the run reports.
    pub fn record_into(&self, rec: &mut Recorder) {
        for (name, v) in [
            ("gateway/accepted", self.accepted as f64),
            ("gateway/completed", self.completed as f64),
            ("gateway/rejected", self.rejected as f64),
            ("gateway/rejected_infeasible", self.rejected_infeasible as f64),
            ("gateway/shed_deadline", self.shed_deadline as f64),
            ("gateway/failed_internal", self.failed_internal as f64),
            ("gateway/requeued", self.requeued as f64),
            ("gateway/replica_restarts", self.replica_restarts as f64),
            ("gateway/stolen", self.stolen as f64),
            ("gateway/cache_abandoned", self.cache_abandoned as f64),
            ("gateway/served_full", self.served_full as f64),
            ("gateway/served_degraded", self.served_degraded as f64),
            ("gateway/cache_hits", self.cache_hits as f64),
            ("gateway/cache_misses", self.cache_misses as f64),
            ("gateway/cache_hit_rate", self.cache_hit_rate()),
            ("gateway/batches", self.batches as f64),
            ("gateway/peak_queue_depth", self.peak_queue_depth as f64),
            ("gateway/shed_rate", self.shed_rate()),
            ("gateway/throughput_rps", self.throughput_rps),
            ("gateway/latency_p50_ms", self.latency.p50()),
            ("gateway/latency_p95_ms", self.latency.p95()),
            ("gateway/latency_p99_ms", self.latency.p99()),
            ("gateway/queue_wait_p99_ms", self.queue_wait.p99()),
            ("gateway/queue_depth_p99", self.queue_depth.p99()),
        ] {
            rec.push(name, 0.0, v);
        }
        for (&w, h) in self.bucket_widths.iter().zip(&self.per_bucket) {
            let x = w as f64;
            rec.push("gateway/bucket_requests", x, h.count() as f64);
            rec.push("gateway/bucket_p50_ms", x, h.p50());
            rec.push("gateway/bucket_p99_ms", x, h.p99());
        }
        for r in &self.per_replica {
            let x = r.replica as f64;
            rec.push("gateway/replica_requests", x, r.requests as f64);
            rec.push("gateway/replica_batches", x, r.batches as f64);
            rec.push("gateway/replica_p99_ms", x, r.latency.p99());
        }
    }
}

impl std::fmt::Display for GatewayStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "gateway: {} accepted ({} completed, {} deadline-shed), \
             {} rejected (+{} infeasible) | shed rate {:.1}% | {} batches | \
             peak depth {} | {:.1} req/s",
            self.accepted,
            self.completed,
            self.shed_deadline,
            self.rejected,
            self.rejected_infeasible,
            self.shed_rate() * 100.0,
            self.batches,
            self.peak_queue_depth,
            self.throughput_rps,
        )?;
        if self.served_degraded > 0 {
            writeln!(
                f,
                "  quality: {} full / {} degraded ({:.1}% stepped down)",
                self.served_full,
                self.served_degraded,
                100.0 * self.served_degraded as f64
                    / (self.served_full + self.served_degraded).max(1) as f64,
            )?;
        }
        writeln!(
            f,
            "  latency ms p50 {:.2} p95 {:.2} p99 {:.2} | queue wait p99 {:.2}",
            self.latency.p50(),
            self.latency.p95(),
            self.latency.p99(),
            self.queue_wait.p99(),
        )?;
        if self.failed_internal
            + self.requeued
            + self.replica_restarts
            + self.cache_abandoned
            > 0
        {
            writeln!(
                f,
                "  faults: {} failed internally | {} requeued | \
                 {} replica restarts | {} cache leases abandoned",
                self.failed_internal,
                self.requeued,
                self.replica_restarts,
                self.cache_abandoned,
            )?;
        }
        if self.stolen > 0 {
            writeln!(f, "  stealing: {} batches stolen", self.stolen)?;
        }
        if self.cache_hits + self.cache_misses > 0 {
            writeln!(
                f,
                "  prefix cache: {} hits / {} misses ({:.1}% hit rate)",
                self.cache_hits,
                self.cache_misses,
                100.0 * self.cache_hit_rate(),
            )?;
        }
        for (&w, h) in self.bucket_widths.iter().zip(&self.per_bucket) {
            if h.count() > 0 {
                writeln!(
                    f,
                    "  bucket<={w:<5} {:>7} req  p50 {:.2} p95 {:.2} p99 {:.2}",
                    h.count(),
                    h.p50(),
                    h.p95(),
                    h.p99(),
                )?;
            }
        }
        for r in &self.per_replica {
            writeln!(
                f,
                "  replica {:<3} {:>7} req in {:>6} batches  p99 {:.2}",
                r.replica,
                r.requests,
                r.batches,
                r.latency.p99(),
            )?;
        }
        Ok(())
    }
}

/// The gateway handle: spawn replicas, hand out submitters, shut down
/// into merged stats.
pub struct Gateway {
    shared: Arc<GwShared>,
    workers: Vec<std::thread::JoinHandle<ReplicaStats>>,
    started: Tick,
}

impl Gateway {
    /// Spawn the gateway on the wall clock: N replica worker threads,
    /// each owning its own params handle, attention instance (identical
    /// ctor stream — see `build_attention`), and work-stealing pool
    /// shard.
    pub fn spawn(cfg: GatewayConfig) -> Gateway {
        Gateway::spawn_with_clock(cfg, Arc::new(SystemClock::new()))
    }

    /// Spawn on an explicit clock. All timestamps (deadlines, latency
    /// stats, aging, `elapsed_secs`) read this clock; the replica
    /// threads' blocking waits convert tick differences to wall
    /// durations, so the clock's ticks must track wall time (virtual
    /// scheduling runs belong to the thread-free `serve::sim` harness).
    pub fn spawn_with_clock(
        cfg: GatewayConfig,
        clock: Arc<dyn Clock>,
    ) -> Gateway {
        let mut cfg = cfg;
        // serving computes at power-of-two canonical widths
        // (`bucket_len`); floor a non-pow2 configured max_len once here
        // so routing, canonicalization, the ABI spec, and every replica
        // agree on the effective cap (mirrors `serve_loop_cpu`)
        cfg.base.encoder.max_len = pow2_floor(cfg.base.encoder.max_len);
        let max_len = cfg.base.encoder.max_len;
        let route = if cfg.bucketing {
            cfg.buckets.normalized(max_len)
        } else {
            BucketLayout::single(max_len)
        };
        let replicas = cfg.replicas.max(1);
        let started = clock.now();
        // streamable-variant template: the prefix cache and the
        // degradation ladder both require it (the ladder trades hash
        // rounds, which only YOSO variants have). The kernel choice is
        // carried over so fresh sessions match the batch path exactly.
        let template = yoso_variant(&cfg.base.attention).map(|mut att| {
            att.kernel = cfg.base.kernel;
            att
        });
        // m_full == 1 for non-streamable variants: every ladder plan
        // then has m_eff == m_full, a no-op by construction
        let m_full = template.as_ref().map_or(1, |a| a.m);
        let ladder = if template.is_some() {
            cfg.degrade.clone()
        } else {
            DegradeLadder::none()
        };
        let cache = (cfg.prefix_cache_bytes > 0)
            .then(|| template.clone())
            .flatten()
            .map(|att| {
                Mutex::new(PrefixCache::new(att, cfg.prefix_cache_bytes))
            });
        // lane 0 = admission/scheduler events, lanes 1..=replicas = one
        // per replica worker. The epoch offset is captured *here*, next
        // to the clock the events will be stamped with, so the Chrome
        // exporter can shift kernel phase spans (process-global
        // `obs::now_ns` timeline) onto this gateway's event timeline.
        let trace = cfg.trace.then(|| {
            let offset =
                obs::now_ns() as i64 - clock.now().as_nanos() as i64;
            Arc::new(TraceSink::new(
                replicas + 1,
                TraceSink::DEFAULT_LANE_CAPACITY,
                offset,
            ))
        });
        let shared = Arc::new(GwShared {
            queues: ShardedQueues::new(route.widths.len()),
            ctrl: Mutex::new(GwCtrl {
                svc_ewma_ms: None,
                ladder_state: LadderState::default(),
            }),
            closed: AtomicBool::new(false),
            depth: AtomicUsize::new(0),
            next_seq: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            rejected_infeasible: AtomicU64::new(0),
            shed_deadline: AtomicU64::new(0),
            failed_internal: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            replica_restarts: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            peak_queue_depth: AtomicUsize::new(0),
            steal_board: (0..replicas).map(|_| Mutex::new(None)).collect(),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            clock,
            capacity: cfg.queue_capacity.max(1),
            replicas,
            policy: cfg.shed,
            sched: cfg.sched,
            batch: cfg.batch.clone(),
            route,
            vocab_size: cfg.base.encoder.vocab_size,
            max_len,
            cache,
            ladder,
            m_full,
            admission_edf: cfg.admission_edf,
            trace,
            reserve: cfg.best_effort_reserve,
            retry_budget: cfg.retry_budget,
            supervised: cfg.supervised,
            steal: cfg.steal,
            heartbeat: cfg.heartbeat.max(Duration::from_micros(100)),
            fault: cfg.fault.clone(),
        });
        // one weight init shared by value semantics: every replica holds
        // its own Arc handle onto identical bytes
        let params = Arc::new(ParamSet::init_for(
            &encoder_abi_spec(&cfg.base.encoder),
            cfg.base.seed,
        ));
        crate::info!(
            "gateway: attention={} kernel={} replicas={replicas} capacity={} \
             buckets={:?} bucketing={} sched={} threads/replica={} \
             degrade={} edf={}",
            cfg.base.attention,
            cfg.base.kernel.label(),
            shared.capacity,
            shared.route.widths,
            cfg.bucketing,
            shared.sched.label(),
            resolve_threads(cfg.base.threads),
            shared.ladder.is_enabled(),
            shared.admission_edf,
        );
        let workers = (0..replicas)
            .map(|id| {
                let shared = Arc::clone(&shared);
                let cfg = cfg.clone();
                let params = Arc::clone(&params);
                std::thread::spawn(move || {
                    replica_worker(id, shared, cfg, params)
                })
            })
            .collect();
        Gateway { shared, workers, started }
    }

    pub fn submitter(&self) -> GatewaySubmitter {
        GatewaySubmitter { shared: Arc::clone(&self.shared) }
    }

    /// Submit one sequence (see [`GatewaySubmitter::submit`]).
    pub fn submit(
        &self,
        input_ids: Vec<i32>,
        segment_ids: Vec<i32>,
    ) -> Result<Receiver<GatewayReply>, Shed> {
        self.submitter().submit(input_ids, segment_ids)
    }

    /// Live queue-depth gauge (admitted, not yet dequeued).
    pub fn queue_depth(&self) -> usize {
        self.shared.queues.len()
    }

    /// The flight-recorder event sink, when `GatewayConfig::trace` is
    /// on. Drain it (typically after [`Gateway::shutdown`] — the sink
    /// outlives the gateway through this handle) to export a Chrome
    /// timeline ([`obs::write_chrome_trace`]), a Prometheus snapshot
    /// ([`obs::prometheus_text`]), or to reconcile against
    /// [`GatewayStats`].
    pub fn trace_sink(&self) -> Option<Arc<TraceSink>> {
        self.shared.trace.clone()
    }

    /// Close admission and join the replica threads. Idempotent: the
    /// second call (e.g. `Drop` after `shutdown`) finds `workers` empty.
    fn close_and_join(&mut self) -> Vec<std::thread::Result<ReplicaStats>> {
        self.shared.closed.store(true, Ordering::SeqCst);
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
        self.workers.drain(..).map(|h| h.join()).collect()
    }

    /// Close admission, drain what was already accepted, join the
    /// replicas, and merge their stats. Returns even while
    /// `GatewaySubmitter` clones are alive — the close is explicit.
    pub fn shutdown(mut self) -> GatewayStats {
        // a replica thread that somehow died outside supervision (or
        // with supervision disabled) must not take shutdown down with
        // it: fold an empty stats record in its place — the no-request-
        // lost accounting lives in the shared atomic counters, not in
        // the thread result
        let n_buckets = self.shared.route.widths.len();
        let per_replica: Vec<ReplicaStats> = self
            .close_and_join()
            .into_iter()
            .enumerate()
            .map(|(id, r)| r.unwrap_or_else(|_| ReplicaStats::new(id, n_buckets)))
            .collect();
        let elapsed_secs = self
            .shared
            .clock
            .now()
            .duration_since(self.started)
            .as_secs_f64();

        let widths = self.shared.route.widths.clone();
        let mut latency = Histogram::new();
        let mut queue_wait = Histogram::new();
        let mut queue_depth = Histogram::new();
        let mut per_bucket: Vec<Histogram> =
            widths.iter().map(|_| Histogram::new()).collect();
        let (mut completed, mut batches) = (0u64, 0u64);
        let (mut served_full, mut served_degraded) = (0u64, 0u64);
        for r in &per_replica {
            completed += r.requests;
            batches += r.batches;
            served_full += r.served_full;
            served_degraded += r.served_degraded;
            latency.merge(&r.latency);
            queue_wait.merge(&r.queue_wait);
            queue_depth.merge(&r.queue_depth);
            for (acc, h) in per_bucket.iter_mut().zip(&r.per_bucket) {
                acc.merge(h);
            }
        }
        let (cache_hits, cache_misses, cache_abandoned) =
            match &self.shared.cache {
                Some(c) => {
                    let c = lock_cache(c);
                    (c.hits, c.misses, c.abandoned())
                }
                None => (0, 0, 0),
            };
        let sh = &self.shared;
        GatewayStats {
            accepted: sh.accepted.load(Ordering::SeqCst),
            completed,
            rejected: sh.rejected.load(Ordering::SeqCst),
            rejected_infeasible: sh.rejected_infeasible.load(Ordering::SeqCst),
            shed_deadline: sh.shed_deadline.load(Ordering::SeqCst),
            failed_internal: sh.failed_internal.load(Ordering::SeqCst),
            requeued: sh.requeued.load(Ordering::SeqCst),
            replica_restarts: sh.replica_restarts.load(Ordering::SeqCst),
            stolen: sh.stolen.load(Ordering::SeqCst),
            cache_abandoned,
            served_full,
            served_degraded,
            cache_hits,
            cache_misses,
            batches,
            peak_queue_depth: sh.peak_queue_depth.load(Ordering::SeqCst),
            latency,
            queue_wait,
            queue_depth,
            bucket_widths: widths,
            per_bucket,
            per_replica,
            elapsed_secs,
            throughput_rps: completed as f64 / elapsed_secs.max(1e-9),
        }
    }
}

impl Drop for Gateway {
    /// A gateway dropped without `shutdown` must not strand its replica
    /// threads: they hold the shared state alive themselves, so nothing
    /// else would ever wake them off `work_cv`. Close and join, ignoring
    /// stats (and replica panics — no double panic during unwind).
    fn drop(&mut self) {
        let _ = self.close_and_join();
    }
}

/// Shed one expired request. `now` is the pinned scheduling-round
/// instant the expiry was judged at.
fn shed_entry(shared: &GwShared, now: Tick, e: GwEntry) {
    shared.shed_deadline.fetch_add(1, Ordering::SeqCst);
    shared.emit(
        0,
        Event::new(EventKind::Shed, now, e.seq)
            .with_quality(quality_tag(e.payload.quality))
            .with_shed(ShedTag::Expired),
    );
    let _ = e.payload.reply.send(Err(Shed::DeadlineExpired));
}

/// A batch handed to a replica by [`next_batch`]: the routing bucket,
/// the formation-time ladder decision, the live entries, and whether
/// the fault gate still has to run (`false` only for a whole-stolen
/// batch, which was already stall/kill-checked on its victim — re-
/// running would double-fire the injected faults the steal rescued it
/// from).
struct FormedBatch {
    bucket: usize,
    m_eff: usize,
    entries: Vec<GwEntry>,
    fresh_faults: bool,
}

/// Scan the steal board for work an idle replica may take: a posted
/// (pre-stall) batch older than one heartbeat is taken whole; a parked
/// partial with two or more members loses its tail (the victim keeps
/// the older-seq front half, so stealing never reorders within a
/// bucket). Lowest victim index wins, mirroring the sim's
/// deterministic choice. The caller owns follow-up formation events
/// for a fresh tail; a whole-stolen batch keeps its victim-emitted
/// `BatchFormed` and ladder decision.
fn try_steal(shared: &GwShared, thief: usize, now: Tick) -> Option<FormedBatch> {
    for victim in 0..shared.steal_board.len() {
        if victim == thief {
            continue;
        }
        let mut slot = lock_slot(&shared.steal_board[victim]);
        let steal_whole = matches!(
            slot.as_ref(),
            Some(s) if !s.parked
                && now >= s.since.saturating_add(shared.heartbeat)
        );
        if steal_whole {
            let s = slot.take().expect("matched Some above");
            drop(slot);
            shared.stolen.fetch_add(1, Ordering::SeqCst);
            shared.emit(
                thief + 1,
                Event::new(EventKind::Stolen, now, obs::NO_SEQ)
                    .with_worker(thief)
                    .with_width(shared.route.widths[s.bucket])
                    .with_n(s.entries.len()),
            );
            return Some(FormedBatch {
                bucket: s.bucket,
                m_eff: s.m_eff,
                entries: s.entries,
                fresh_faults: false,
            });
        }
        if let Some(s) = slot.as_mut() {
            if s.parked && s.entries.len() >= 2 {
                let keep = (s.entries.len() + 1) / 2;
                let tail = s.entries.split_off(keep);
                let bucket = s.bucket;
                drop(slot);
                shared.stolen.fetch_add(1, Ordering::SeqCst);
                shared.emit(
                    thief + 1,
                    Event::new(EventKind::Stolen, now, obs::NO_SEQ)
                        .with_worker(thief)
                        .with_width(shared.route.widths[bucket])
                        .with_n(tail.len()),
                );
                return Some(FormedBatch {
                    bucket,
                    m_eff: 0, // caller runs the ladder for a fresh tail
                    entries: tail,
                    fresh_faults: true,
                });
            }
        }
    }
    None
}

/// Collect the next single-bucket batch via the shared scheduling core:
/// policy bucket pick (`Fifo`: oldest head; `Conserve`: the globally
/// most urgent queued deadline, else deepest backlog — see
/// `BucketQueues::pick_bucket`), policy dequeue order within the bucket
/// (arrival vs deadline-earliest-first), deadline sheds before
/// execution, max-wait aged from the first request's enqueue time
/// (clamped to now — the Batcher aging rule), and — under `Conserve` —
/// no aging park while any bucket still holds work *or* while a batch
/// member's deadline would expire inside the wait. None once the
/// gateway is closed and drained.
///
/// Returns a [`FormedBatch`]: its `m_eff` is the degradation ladder's
/// hash-round budget for the batch's best-effort members, decided once
/// at formation time off the backlog the batch leaves behind it (the
/// queue pressure still standing *after* these entries pop is what the
/// ladder must relieve). This formation-time decision is the one site
/// that advances the ladder's hysteresis state
/// (`DegradeLadder::plan_at`); `replica` tags the trace event.
///
/// No global lock: pops contend only on the picked bucket's lane, the
/// ctrl mutex is touched once per batch (ladder) and once per park.
/// Every park is heartbeat-bounded, and with stealing on an idle
/// replica scavenges the steal board before parking.
fn next_batch(shared: &GwShared, replica: usize) -> Option<FormedBatch> {
    let widest = *shared.route.widths.last().expect("non-empty layout");
    loop {
        // sampled BEFORE the shed/pick pass: the exit below requires a
        // pick performed *after* `closed` was observed, which (with the
        // submitter's post-push close re-check) guarantees no admitted
        // entry is stranded by a close racing an admission
        let draining = shared.closed.load(Ordering::SeqCst);
        // one timestamp pins the whole scheduling round (re-pinned only
        // after a park): every shed/fill/aging decision in a pass reads
        // the same instant, so an entry judged live by the shed pass
        // cannot be shed by a later clock read in the same pass — under
        // a SimClock stepping mid-fill, the old per-pop reads did
        // exactly that
        let mut now = shared.clock.now();
        // capacity slots free as entries pop (quietly); space_cv is
        // notified once per batch/park, not once per pop — a per-pop
        // notify_all would wake every Block-mode submitter
        // O(batch x waiters) times
        let mut freed = false;
        // shed everything already expired (anywhere in the queues, not
        // only heads — the EDF pop must never see corpses)
        for e in shared.queues.shed_expired(now) {
            shared.free_slot_quiet();
            freed = true;
            shed_entry(shared, now, e);
        }
        if let Some(b) = shared.queues.pick_bucket(shared.sched) {
            let bpolicy =
                shared.batch.policy_for(shared.route.widths[b], widest);
            let Some(first) = shared.queues.pop_next(b, shared.sched)
            else {
                // a peer drained the picked lane between the pick and
                // the pop — the benign race the sharded layout admits;
                // pick again
                if freed {
                    shared.space_cv.notify_all();
                }
                continue;
            };
            shared.free_slot_quiet();
            freed = true;
            let age_deadline =
                first.enqueued.saturating_add(bpolicy.max_wait).max(now);
            let mut batch = vec![first];
            loop {
                while batch.len() < bpolicy.max_batch {
                    match shared.queues.pop_next(b, shared.sched) {
                        Some(e) => {
                            shared.free_slot_quiet();
                            freed = true;
                            if e.expired(now) {
                                shed_entry(shared, now, e);
                            } else {
                                batch.push(e);
                            }
                        }
                        None => break,
                    }
                }
                if batch.len() >= bpolicy.max_batch
                    || shared.closed.load(Ordering::SeqCst)
                {
                    break;
                }
                if now >= age_deadline {
                    break;
                }
                if shared.sched == SchedPolicy::Conserve {
                    // work conservation: a partial batch never parks
                    // while any other bucket still holds work — ship it
                    // now and come back for the rest (its own bucket is
                    // empty here, or the drain above would have filled
                    // the batch)
                    if !shared.queues.is_empty() {
                        break;
                    }
                    // deadline-aware aging cap: never park a batch past
                    // a member's deadline — a request absorbed into the
                    // park would otherwise age into a shed even while
                    // the gateway had time to serve it
                    let earliest =
                        batch.iter().filter_map(|e| e.deadline).min();
                    if earliest.is_some_and(|d| d <= age_deadline) {
                        break;
                    }
                }
                // about to park for up to max_wait: release any
                // submitters waiting on the capacity freed so far
                if freed {
                    shared.space_cv.notify_all();
                    freed = false;
                }
                // publish the parked partial so an idle peer can split
                // its tail off while we age
                let posted = shared.steal && batch.len() >= 2;
                if posted {
                    *lock_slot(&shared.steal_board[replica]) =
                        Some(StealSlot {
                            bucket: b,
                            m_eff: 0,
                            entries: std::mem::take(&mut batch),
                            since: now,
                            parked: true,
                        });
                }
                {
                    // heartbeat-bounded park: the work notify is
                    // advisory, the timeout is the progress guarantee
                    let g = shared.lock_ctrl();
                    let dur = age_deadline
                        .duration_since(now)
                        .min(shared.heartbeat);
                    drop(shared.wait_work_timeout(g, dur));
                }
                if posted {
                    // reclaim what a thief left: the front (older-seq)
                    // half if the tail was stolen, everything
                    // otherwise. Parked slots are only ever split, so
                    // the reclaim is never empty — the guard is
                    // defensive
                    batch = lock_slot(&shared.steal_board[replica])
                        .take()
                        .map(|s| s.entries)
                        .unwrap_or_default();
                    if batch.is_empty() {
                        break;
                    }
                }
                // woke from the park: a new decision pass begins on a
                // freshly pinned instant
                now = shared.clock.now();
            }
            // a batch member (the head included) can expire while we
            // park waiting for batchmates — the post-park re-pin keeps
            // `now` current: re-check so nothing expired ever reaches
            // execution
            let mut live = Vec::with_capacity(batch.len());
            for e in batch {
                if e.expired(now) {
                    shed_entry(shared, now, e);
                } else {
                    live.push(e);
                }
            }
            if freed {
                shared.space_cv.notify_all();
            }
            if live.is_empty() {
                // the whole batch expired during the wait; pick again
                continue;
            }
            // the formation-time ladder decision — the one site that
            // advances the hysteresis state (step-down immediate,
            // step-up only after the backlog has stayed below the rung
            // for the configured lag)
            let m_eff = {
                let queued = shared.queues.len();
                let mut ctrl = shared.lock_ctrl();
                let ewma = ctrl.svc_ewma_ms;
                shared
                    .ladder
                    .plan_at(
                        &mut ctrl.ladder_state,
                        now,
                        queued,
                        ewma,
                        shared.replicas,
                        shared.m_full,
                    )
                    .m_eff
            };
            shared.emit(
                replica + 1,
                Event::new(EventKind::BatchFormed, now, obs::NO_SEQ)
                    .with_worker(replica)
                    .with_width(shared.route.widths[b])
                    .with_m_eff(m_eff)
                    .with_n(live.len()),
            );
            return Some(FormedBatch {
                bucket: b,
                m_eff,
                entries: live,
                fresh_faults: true,
            });
        }
        if freed {
            shared.space_cv.notify_all();
        }
        if draining {
            return None;
        }
        if shared.steal {
            if let Some(fb) = try_steal(shared, replica, now) {
                if !fb.fresh_faults {
                    // whole-stolen: already formed, fault-gated, and
                    // ladder-decided on the victim — execute as-is
                    return Some(fb);
                }
                // a stolen tail is a fresh batch: expiry re-check, own
                // ladder decision, own formation event
                let mut live = Vec::with_capacity(fb.entries.len());
                for e in fb.entries {
                    if e.expired(now) {
                        shed_entry(shared, now, e);
                    } else {
                        live.push(e);
                    }
                }
                if live.is_empty() {
                    continue;
                }
                let m_eff = {
                    let queued = shared.queues.len();
                    let mut ctrl = shared.lock_ctrl();
                    let ewma = ctrl.svc_ewma_ms;
                    shared
                        .ladder
                        .plan_at(
                            &mut ctrl.ladder_state,
                            now,
                            queued,
                            ewma,
                            shared.replicas,
                            shared.m_full,
                        )
                        .m_eff
                };
                shared.emit(
                    replica + 1,
                    Event::new(EventKind::BatchFormed, now, obs::NO_SEQ)
                        .with_worker(replica)
                        .with_width(shared.route.widths[fb.bucket])
                        .with_m_eff(m_eff)
                        .with_n(live.len()),
                );
                return Some(FormedBatch {
                    bucket: fb.bucket,
                    m_eff,
                    entries: live,
                    fresh_faults: true,
                });
            }
        }
        // idle: heartbeat-bounded park, then re-examine the lanes and
        // the steal board
        let g = shared.lock_ctrl();
        drop(shared.wait_work_timeout(g, shared.heartbeat));
    }
}

/// Replica worker thread body: owns this replica's [`ReplicaStats`]
/// across restarts and supervises the serving loop. A panic that
/// escapes per-request isolation (a real bug, or an injected replica
/// kill) lands here instead of killing the thread: the stats survive
/// (they live outside the unwind), `ReplicaDied`/`ReplicaRestarted`
/// fire on this replica's trace lane, and the loop restarts in place
/// with a fresh attention instance and thread pool — the old pool's
/// sticky panic flag dies with the old loop. With
/// `GatewayConfig::supervised` off (the fig9 overhead baseline), the
/// loop runs exactly once, pre-supervision semantics.
fn replica_worker(
    id: usize,
    shared: Arc<GwShared>,
    cfg: GatewayConfig,
    params: Arc<ParamSet>,
) -> ReplicaStats {
    let mut stats = ReplicaStats::new(id, shared.route.widths.len());
    if !shared.supervised {
        replica_loop(id, &shared, &cfg, &params, &mut stats);
        return stats;
    }
    loop {
        // AssertUnwindSafe: on a caught panic the only state reused is
        // `stats` (monotone counters and histograms — a torn batch
        // under-counts, never corrupts) and the shared mutexes, which
        // every locker recovers (`lock_ctrl`/`lock_slot`/`lock_cache`)
        let done = catch_unwind(AssertUnwindSafe(|| {
            replica_loop(id, &shared, &cfg, &params, &mut stats)
        }));
        match done {
            // closed and drained: the one non-panic way out
            Ok(()) => return stats,
            Err(_) => {
                let now = shared.clock.now();
                shared.replica_restarts.fetch_add(1, Ordering::SeqCst);
                shared.emit(
                    id + 1,
                    Event::new(EventKind::ReplicaDied, now, obs::NO_SEQ)
                        .with_worker(id),
                );
                shared.emit(
                    id + 1,
                    Event::new(EventKind::ReplicaRestarted, now, obs::NO_SEQ)
                        .with_worker(id),
                );
                // peers or submitters may have missed a wake-up while
                // the dying replica held (and poisoned) the state lock
                shared.work_cv.notify_all();
                shared.space_cv.notify_all();
            }
        }
    }
}

/// The injected replica-kill path: return every batch member to its
/// queue in seq position (original enqueue stamp and deadline intact,
/// so EDF ordering and deadline sheds stay correct) — or, once the
/// **killing** member's retry budget is spent, fail *it* terminally
/// with [`Shed::InternalError`] so a request that keeps killing
/// replicas cannot crash-loop the fleet forever. Innocent batch-mates
/// always requeue: they are collateral of the killer's crash, and
/// charging their budget for it could fail a healthy request that was
/// merely batched next to a cursed one three times (the crash loop
/// stays bounded — the killer exhausts its own budget first). Then
/// panic: supervision restarts the loop and re-dispatches the requeued
/// work.
fn die_with_batch(
    shared: &GwShared,
    replica: usize,
    bucket: usize,
    batch: Vec<GwEntry>,
) -> ! {
    let now = shared.clock.now();
    for mut e in batch {
        if shared.fault.kill_for(e.seq) && e.retries >= shared.retry_budget
        {
            shared.failed_internal.fetch_add(1, Ordering::SeqCst);
            shared.emit(
                0,
                Event::new(EventKind::Shed, now, e.seq)
                    .with_worker(replica)
                    .with_quality(quality_tag(e.payload.quality))
                    .with_shed(ShedTag::Internal),
            );
            let (seq, retries) = (e.seq, e.retries);
            let _ = e
                .payload
                .reply
                .send(Err(Shed::InternalError { seq, retries }));
        } else {
            e.retries = e.retries.saturating_add(1);
            shared.requeued.fetch_add(1, Ordering::SeqCst);
            shared.emit(
                replica + 1,
                Event::new(EventKind::Requeued, now, e.seq)
                    .with_worker(replica)
                    .with_width(shared.route.widths[bucket]),
            );
            shared.queues.requeue(bucket, e);
            // the requeued entry re-occupies an admission slot
            shared.depth.fetch_add(1, Ordering::SeqCst);
        }
    }
    // hand the requeued work to a live peer before dying
    shared.work_cv.notify_all();
    panic!("injected fault: replica {replica} killed while holding a batch");
}

/// One replica: pull single-bucket batches, fan requests across the
/// replica's own work-stealing pool (heads stay serial inside each
/// request job — one parallelism grain per pool), record latencies.
/// Returns when the gateway is closed and drained; panics escape to
/// the supervising [`replica_worker`].
fn replica_loop(
    id: usize,
    shared: &Arc<GwShared>,
    cfg: &GatewayConfig,
    params: &Arc<ParamSet>,
    stats: &mut ReplicaStats,
) {
    let attn = build_attention(&cfg.base);
    // streamable template for degraded execution on the non-cache path:
    // an `m_req`-round clone forwards bit-identically to the stream's
    // m'-prefix readout (the contract in `attention::stream`)
    let degrade_template = yoso_variant(&cfg.base.attention).map(|mut a| {
        a.kernel = cfg.base.kernel;
        a
    });
    let pool = ThreadPool::new(resolve_threads(cfg.base.threads));
    // the lease drop-guards share the cache's abandonment counter by
    // handle, so a dying request never needs the cache lock to be
    // counted
    let abandoned =
        shared.cache.as_ref().map(|c| lock_cache(c).abandoned_handle());
    let max_len = cfg.base.encoder.max_len;
    while let Some(formed) = next_batch(shared, id) {
        let FormedBatch { bucket, m_eff, entries: mut batch, fresh_faults } =
            formed;
        if fresh_faults && !shared.fault.is_empty() {
            // injected stall: this batch executes late, not never —
            // deadline sheds and aging must absorb it. With stealing
            // on, the batch is posted to the steal board first, so an
            // idle peer whole-steals it within one heartbeat instead
            // of letting it wedge behind this replica for the whole
            // stall
            let stall = batch
                .iter()
                .filter_map(|e| shared.fault.stall_ns(e.seq))
                .max();
            if let Some(ns) = stall {
                if shared.steal {
                    *lock_slot(&shared.steal_board[id]) = Some(StealSlot {
                        bucket,
                        m_eff,
                        entries: std::mem::take(&mut batch),
                        since: shared.clock.now(),
                        parked: false,
                    });
                    std::thread::sleep(Duration::from_nanos(ns));
                    match lock_slot(&shared.steal_board[id]).take() {
                        Some(s) => batch = s.entries,
                        // a peer whole-stole the wedged batch: it
                        // executes (and counts) there, not here
                        None => continue,
                    }
                } else {
                    std::thread::sleep(Duration::from_nanos(ns));
                }
            }
            // injected replica kill: requeue the batch and die;
            // supervision restarts this loop. Only the killing seq can
            // fail terminally (once its retry budget is spent — the
            // crash loop is bounded); innocent mates always requeue
            if batch.iter().any(|e| shared.fault.kill_for(e.seq)) {
                die_with_batch(shared, id, bucket, batch);
            }
        }
        let exec_start = shared.clock.now();
        stats.queue_depth.record(shared.queues.len() as f64);
        let n = batch.len();
        let width_b = shared.route.widths[bucket];
        shared.emit(
            id + 1,
            Event::new(EventKind::ExecStart, exec_start, obs::NO_SEQ)
                .with_worker(id)
                .with_width(width_b)
                .with_m_eff(m_eff)
                .with_n(n),
        );
        let m_full = shared.m_full;
        let params = Arc::clone(params);
        let attn = Arc::clone(&attn);
        let template = degrade_template.clone();
        let clock = Arc::clone(&shared.clock);
        let gw = Arc::clone(shared);
        let abandoned = abandoned.clone();
        let ecfg = cfg.base.encoder.clone();
        let (seed, chunk) = (cfg.base.seed, cfg.base.chunk_policy);
        let bucketing = cfg.bucketing;
        let timings = pool.map(batch, move |e| {
            // destructure before the catch: the reply sender must
            // survive a panic inside the forward, so the terminal
            // outcome is sent exactly once — on whichever side of the
            // catch we land. The pool's own sticky panic handler never
            // sees an isolated request panic.
            let Entry { seq, enqueued, retries, payload, .. } = e;
            let GwPayload { ids, segs, quality, reply } = payload;
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                if gw.fault.panic_for(seq) {
                    panic!("injected fault: request seq {seq} poisoned");
                }
                let width = if bucketing {
                    bucket_len(ids.len(), max_len)
                } else {
                    max_len
                };
                // quality resolution: Full pins the configured m even
                // in a stepped-down batch; Degraded pins its own m'
                // regardless of load; BestEffort takes the batch's
                // ladder decision
                let m_req = match quality {
                    Quality::Full => m_full,
                    Quality::Degraded(m) => m.clamp(1, m_full),
                    Quality::BestEffort => m_eff.clamp(1, m_full),
                };
                let degraded = m_req < m_full;
                let enc = Encoder::new(ecfg.clone(), &params);
                let (logits, cache_tag) = if let Some(cache) = &gw.cache {
                    // checkout/compute/publish: the cache lock is never
                    // held across the encode itself, so replicas stream
                    // concurrently and only serialize on the cheap
                    // probe and insert. Bit-identity of the streamed
                    // path to `serve_forward` makes hit vs miss vs
                    // batch unobservable in the logits.
                    let (hit, att) = {
                        let mut c = lock_cache(cache);
                        let hit = c.checkout(&ids, &segs, width);
                        (hit, c.template())
                    };
                    let was_hit = hit.is_some();
                    let stream = hit.unwrap_or_else(|| {
                        EncoderStream::new(&enc, &att, seed, width)
                    });
                    // lease guard from here: a panic below this line
                    // discards the session instead of publishing a
                    // half-appended stream back as a valid prefix
                    let mut lease = SessionLease::new(
                        stream,
                        Arc::clone(
                            abandoned.as_ref().expect("cache implies handle"),
                        ),
                    );
                    if gw.fault.abandon_for(seq) {
                        panic!(
                            "injected fault: seq {seq} abandons its \
                             cache lease"
                        );
                    }
                    let done = lease.stream().len();
                    if done < ids.len() {
                        lease.stream().append(
                            &enc,
                            &ids[done..],
                            &segs[done..],
                        );
                    }
                    // the session is absorbed (and published) at full
                    // m; only the readout narrows to the m'-prefix, so
                    // a degraded hit costs nothing on a later
                    // full-quality reuse of the same session
                    let logits = lease.stream().classify_at(&enc, m_req);
                    lock_cache(cache).publish(lease.complete());
                    let tag =
                        if was_hit { CacheTag::Hit } else { CacheTag::Miss };
                    (logits, tag)
                } else if degraded {
                    let att: Arc<dyn Attention> = Arc::new(YosoAttention {
                        m: m_req,
                        ..template
                            .clone()
                            .expect("degraded implies streamable")
                    });
                    let logits = serve_forward(
                        &enc, &att, chunk, seed, &ids, &segs, width,
                    );
                    (logits, CacheTag::Unspecified)
                } else {
                    let logits = serve_forward(
                        &enc, &attn, chunk, seed, &ids, &segs, width,
                    );
                    (logits, CacheTag::Unspecified)
                };
                (logits, m_req, degraded, cache_tag, width)
            }));
            match outcome {
                Ok((logits, m_req, degraded, cache_tag, width)) => {
                    let done = clock.now();
                    let queue_ms = exec_start.ms_since(enqueued);
                    let total_ms = done.ms_since(enqueued);
                    // the served-at quality: what the logits were
                    // actually computed with, not what was asked for —
                    // a BestEffort request served at full rounds
                    // reports Full
                    let quality = if degraded {
                        Quality::Degraded(m_req)
                    } else {
                        Quality::Full
                    };
                    gw.emit(
                        id + 1,
                        Event::new(EventKind::Replied, done, seq)
                            .with_worker(id)
                            .with_width(width)
                            .with_quality(quality_tag(quality))
                            .with_m_eff(m_req)
                            .with_cache(cache_tag),
                    );
                    let _ = reply.send(Ok(Response {
                        logits,
                        queue_ms,
                        total_ms,
                        m_served: m_req,
                        quality,
                        retries,
                    }));
                    Ok((queue_ms, total_ms, degraded))
                }
                // panic isolation: this request fails terminally with
                // its admission seq; its batch-mates complete normally
                Err(_) => {
                    let now = clock.now();
                    gw.emit(
                        0,
                        Event::new(EventKind::Shed, now, seq)
                            .with_worker(id)
                            .with_quality(quality_tag(quality))
                            .with_shed(ShedTag::Internal),
                    );
                    let _ = reply
                        .send(Err(Shed::InternalError { seq, retries }));
                    Err(seq)
                }
            }
        });
        let exec_end = shared.clock.now();
        shared.emit(
            id + 1,
            Event::new(EventKind::ExecEnd, exec_end, obs::NO_SEQ)
                .with_worker(id)
                .with_width(width_b)
                .with_m_eff(m_eff)
                .with_n(n),
        );
        stats.batches += 1;
        let mut failed = 0u64;
        for t in timings {
            match t {
                Ok((queue_ms, total_ms, degraded)) => {
                    stats.requests += 1;
                    if degraded {
                        stats.served_degraded += 1;
                    } else {
                        stats.served_full += 1;
                    }
                    stats.queue_wait.record(queue_ms);
                    stats.latency.record(total_ms);
                    stats.per_bucket[bucket].record(total_ms);
                }
                // the job already sent InternalError and emitted the
                // shed event; only the aggregate counter is folded here
                Err(_) => failed += 1,
            }
        }
        if failed > 0 {
            shared.failed_internal.fetch_add(failed, Ordering::SeqCst);
        }
        // feed the admission retry hint and the ladder. The EWMA keeps
        // one meaning — full-quality per-request ms — so a degraded
        // batch scales its sample back up by m_full/m_eff before
        // blending. Approximation: the non-attention layers don't scale
        // with m, and Full-pinned members of a stepped-down batch ran
        // at full m anyway, so the restated sample over-estimates —
        // which errs toward degrading earlier, the safe direction under
        // overload.
        let per_req_ms = exec_end.ms_since(exec_start) / n.max(1) as f64;
        let sample = per_req_ms * m_full as f64 / m_eff.clamp(1, m_full) as f64;
        let mut ctrl = shared.lock_ctrl();
        ctrl.svc_ewma_ms = Some(update_ewma(ctrl.svc_ewma_ms, sample));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sched::retry_hint_ms;

    #[test]
    fn bucket_layout_pow2_and_routing() {
        let l = BucketLayout::pow2(16, 128);
        assert_eq!(l.widths(), &[16, 32, 64, 128]);
        assert_eq!(l.bucket_for(1), 0);
        assert_eq!(l.bucket_for(16), 0);
        assert_eq!(l.bucket_for(17), 1);
        assert_eq!(l.bucket_for(128), 3);
        assert_eq!(l.bucket_for(4096), 3, "widest bucket takes the rest");
        // non-pow2 max_len still terminates and includes the cap
        let l = BucketLayout::pow2(16, 100);
        assert_eq!(l.widths(), &[16, 32, 64, 100]);
        // min >= max collapses to a single bucket
        let l = BucketLayout::pow2(256, 128);
        assert_eq!(l.widths(), &[128]);
    }

    #[test]
    fn bucket_layout_normalizes() {
        let l = BucketLayout { widths: vec![64, 16, 500, 16] }.normalized(128);
        assert_eq!(l.widths(), &[16, 64, 128]);
        let l = BucketLayout { widths: vec![] }.normalized(128);
        assert_eq!(l.widths(), &[128]);
    }

    #[test]
    fn retry_hint_scales_with_backlog() {
        assert_eq!(retry_hint_ms(10, Some(4.0), 2), 20);
        assert_eq!(
            retry_hint_ms(0, Some(4.0), 2),
            1,
            "hint is always >= 1 ms"
        );
    }

    #[test]
    fn retry_hint_edge_cases() {
        // cold EWMA (no batch has finished yet): estimate 1 ms/request
        assert_eq!(retry_hint_ms(8, None, 4), 2);
        // a *warm* 0.0 estimate (zero-duration service on a virtual
        // clock) is honored, not mistaken for cold — only the 1 ms
        // floor applies. The old f64 sentinel conflated the two and
        // answered 2 here.
        assert_eq!(retry_hint_ms(8, Some(0.0), 4), 1);
        // a negative EWMA can never arise, but the guard covers it too
        assert_eq!(retry_hint_ms(8, Some(-3.0), 4), 2);
        // replicas == 0 guards the division (spawn clamps to 1 anyway)
        assert_eq!(retry_hint_ms(10, Some(2.0), 0), 20);
        // saturating backlog: a huge queue x huge EWMA overflows f64 to
        // inf, and the float->int cast clamps instead of wrapping
        assert_eq!(retry_hint_ms(usize::MAX, Some(f64::MAX), 1), u64::MAX);
        // fractional estimates round up to a whole actionable ms
        assert_eq!(retry_hint_ms(1, Some(0.3), 2), 1);
        assert_eq!(retry_hint_ms(3, Some(0.5), 1), 2);
    }

    #[test]
    fn ewma_warmup_is_explicit() {
        // the first sample becomes the estimate as-is — including 0.0,
        // the value the old sentinel encoding could never warm up from
        assert_eq!(update_ewma(None, 0.0), 0.0);
        assert_eq!(update_ewma(None, 5.0), 5.0);
        // warm updates blend 80/20
        assert!((update_ewma(Some(0.0), 10.0) - 2.0).abs() < 1e-12);
        assert!((update_ewma(Some(2.0), 0.0) - 1.6).abs() < 1e-12);
    }

    /// A `GwShared` with inert defaults (ladder off, EDF off, cache
    /// off) for direct scheduling-core tests; tests mutate fields
    /// before wrapping in an `Arc`.
    fn test_shared(clock: impl Clock + 'static) -> GwShared {
        GwShared {
            queues: ShardedQueues::new(1),
            ctrl: Mutex::new(GwCtrl {
                svc_ewma_ms: None,
                ladder_state: LadderState::default(),
            }),
            closed: AtomicBool::new(false),
            depth: AtomicUsize::new(0),
            next_seq: AtomicU64::new(0),
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            rejected_infeasible: AtomicU64::new(0),
            shed_deadline: AtomicU64::new(0),
            failed_internal: AtomicU64::new(0),
            requeued: AtomicU64::new(0),
            replica_restarts: AtomicU64::new(0),
            stolen: AtomicU64::new(0),
            peak_queue_depth: AtomicUsize::new(0),
            steal_board: vec![Mutex::new(None)],
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            clock: Arc::new(clock),
            capacity: 8,
            replicas: 1,
            policy: ShedPolicy::Reject,
            sched: SchedPolicy::Fifo,
            batch: BatchPolicyTable::uniform(BatchPolicy {
                max_batch: 2,
                max_wait: Duration::ZERO,
            }),
            route: BucketLayout::single(32),
            vocab_size: 2005,
            max_len: 32,
            cache: None,
            ladder: DegradeLadder::none(),
            m_full: 1,
            admission_edf: false,
            trace: None,
            reserve: 0.0,
            retry_budget: 2,
            supervised: true,
            steal: false,
            heartbeat: Duration::from_millis(5),
            fault: FaultPlan::none(),
        }
    }

    /// A clock pinned at zero — admission tests need deterministic
    /// submission instants, not wall time.
    struct FrozenClock;

    impl Clock for FrozenClock {
        fn now(&self) -> Tick {
            Tick::ZERO
        }
        fn wait_until(&self, _deadline: Tick) {}
        fn is_virtual(&self) -> bool {
            true
        }
    }

    #[test]
    fn queue_full_hint_quotes_the_degraded_rate() {
        // capacity 4, warm EWMA 8 ms/req at full m=32, ladder steps to
        // m'=8 above 25 ms of backlog. Four queued requests put the
        // full-quality backlog at 32 ms, clearing the rung — the plain
        // full-quality hint would be 32 ms, but the honest hint is the
        // degraded drain time.
        let mut sh = test_shared(FrozenClock);
        sh.capacity = 4;
        sh.m_full = 32;
        sh.ladder = DegradeLadder::steps(vec![(25, 8)]);
        sh.ctrl.lock().unwrap().svc_ewma_ms = Some(8.0);
        let sub = GatewaySubmitter { shared: Arc::new(sh) };
        for _ in 0..4 {
            sub.submit(vec![1], vec![0]).expect("under capacity");
        }
        // 5th submit: queue full. Full-quality backlog 4 x 8 = 32 ms
        // clears the 25 ms rung -> m'=8, so the quoted drain is
        // 32 x 8/32 = 8 ms, not 32.
        match sub.submit(vec![1], vec![0]) {
            Err(Shed::QueueFull { retry_after_ms }) => {
                assert_eq!(retry_after_ms, 8, "hint reflects degraded rate");
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
    }

    #[test]
    fn admission_edf_rejects_infeasible_deadlines_at_the_degraded_rate() {
        // 6 queued at a warm 10 ms/req, m_full 16, rung to m'=8 above
        // 50 ms: full backlog 60 ms -> degraded drain 30 ms.
        let mut sh = test_shared(FrozenClock);
        sh.capacity = 64;
        sh.m_full = 16;
        sh.admission_edf = true;
        sh.ladder = DegradeLadder::steps(vec![(50, 8)]);
        sh.ctrl.lock().unwrap().svc_ewma_ms = Some(10.0);
        let sub = GatewaySubmitter { shared: Arc::new(sh) };
        for _ in 0..6 {
            sub.submit(vec![1], vec![0]).expect("no deadline, no EDF check");
        }
        // 20 ms < 30 ms degraded drain: infeasible, rejected with the
        // degraded-rate hint
        match sub.submit_with_deadline(
            vec![1],
            vec![0],
            Some(Duration::from_millis(20)),
        ) {
            Err(Shed::DeadlineInfeasible { retry_after_ms }) => {
                assert_eq!(retry_after_ms, 30);
            }
            other => panic!("expected DeadlineInfeasible, got {other:?}"),
        }
        assert_eq!(
            sub.shared.rejected_infeasible.load(Ordering::SeqCst),
            1
        );
        assert_eq!(
            sub.shared.rejected.load(Ordering::SeqCst),
            0,
            "EDF rejection is its own counter"
        );
        // 40 ms >= 30 ms degraded drain: feasible *because* of the
        // ladder (the full-quality drain would be 60 ms) — this is the
        // admission-side payoff of degradation
        sub.submit_with_deadline(
            vec![1],
            vec![0],
            Some(Duration::from_millis(40)),
        )
        .expect("feasible at the degraded rate");
        // a cold estimate never rejects, however short the deadline
        let mut cold = test_shared(FrozenClock);
        cold.admission_edf = true;
        cold.ladder = DegradeLadder::steps(vec![(50, 8)]);
        cold.m_full = 16;
        let cold_sub = GatewaySubmitter { shared: Arc::new(cold) };
        for _ in 0..6 {
            cold_sub.submit(vec![1], vec![0]).unwrap();
        }
        cold_sub
            .submit_with_deadline(
                vec![1],
                vec![0],
                Some(Duration::from_millis(1)),
            )
            .expect("cold estimate: admission EDF stays out of the way");
    }

    /// A clock that advances 1 ms on every read — the adversarial case
    /// for un-pinned scheduling rounds, where each extra `now()` call
    /// in a single pass observed a later instant.
    struct TickingClock(Mutex<u64>);

    impl Clock for TickingClock {
        fn now(&self) -> Tick {
            let mut ms = self.0.lock().unwrap();
            let t = Tick::from_ms(*ms);
            *ms += 1;
            t
        }
        fn wait_until(&self, _deadline: Tick) {}
        fn is_virtual(&self) -> bool {
            true
        }
    }

    #[test]
    fn round_timestamp_is_pinned_across_batch_fill() {
        // Two entries enqueued at t=0; B's deadline is 0.5 ms out. The
        // round's shed pass runs at the pinned t=0 where both are live.
        // The old code re-read the clock per popped entry during batch
        // fill, so B was judged at t=1 ms and shed even though it was
        // live when the scheduling round began.
        let shared = test_shared(TickingClock(Mutex::new(0)));
        let mk = |seq: u64, deadline: Option<Tick>| Entry {
            seq,
            enqueued: Tick::ZERO,
            deadline,
            retries: 0,
            payload: GwPayload {
                ids: vec![1],
                segs: vec![0],
                quality: Quality::default(),
                reply: channel().0,
            },
        };
        shared.queues.push(0, mk(0, None));
        shared.queues.push(0, mk(1, Some(Tick::from_nanos(500_000))));
        let formed = next_batch(&shared, 0).expect("work is queued");
        assert_eq!(formed.bucket, 0);
        assert_eq!(formed.m_eff, 1, "disabled ladder: m_eff is the full m");
        assert_eq!(
            formed.entries.len(),
            2,
            "B was live at the pinned round start"
        );
        assert!(formed.fresh_faults, "a formed batch runs the fault gate");
        assert_eq!(shared.shed_deadline.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn best_effort_reserve_caps_full_admission() {
        // capacity 8, 25% reserved for best-effort: guaranteed classes
        // admit into 6 slots, best-effort into all 8
        let mut sh = test_shared(FrozenClock);
        sh.reserve = 0.25;
        let sub = GatewaySubmitter { shared: Arc::new(sh) };
        let full = |sub: &GatewaySubmitter| {
            sub.submit_with(vec![1], vec![0], None, Quality::Full)
        };
        let be = |sub: &GatewaySubmitter| {
            sub.submit_with(vec![1], vec![0], None, Quality::BestEffort)
        };
        for i in 0..6 {
            full(&sub).unwrap_or_else(|s| {
                panic!("Full submit {i} under the cap: {s}")
            });
        }
        for _ in 0..2 {
            assert!(
                matches!(full(&sub), Err(Shed::QueueFull { .. })),
                "Full traffic stops at the unreserved remainder"
            );
        }
        // the reserved slice admits best-effort right up to capacity
        be(&sub).expect("reserved slot 7");
        be(&sub).expect("reserved slot 8");
        assert!(
            matches!(be(&sub), Err(Shed::QueueFull { .. })),
            "capacity is still the hard bound for every class"
        );
        assert_eq!(sub.shared.accepted.load(Ordering::SeqCst), 8);
        assert_eq!(sub.shared.rejected.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn live_schedule_matches_the_sim_bit_for_bit() {
        // The capacity-planning claim: the virtual-clock simulator and
        // the live gateway run the *same* scheduling core, so the sim's
        // frontier curves transfer to production. Proof obligation: an
        // identical offered trace produces an identical (bucket, seqs)
        // batch sequence from both executors. The live side drains
        // through the real `next_batch` under a frozen clock; the sim
        // side replays the trace with a zero-cost service model so its
        // single replica also schedules everything at t=0.
        use crate::serve::sim::{run, Arrival, ServiceModel, SimConfig};

        let lens: [usize; 12] = [4, 20, 9, 32, 7, 15, 28, 3, 11, 30, 6, 17];
        let deadline =
            |i: usize| (i % 3 == 0).then(|| Duration::from_millis(5 + i as u64));

        let mut sh = test_shared(FrozenClock);
        sh.capacity = 64;
        sh.sched = SchedPolicy::Conserve;
        sh.route = BucketLayout::pow2(8, 32);
        sh.queues = ShardedQueues::new(sh.route.widths.len());
        sh.batch = BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::ZERO,
        });
        let sub = GatewaySubmitter { shared: Arc::new(sh) };
        for (i, &len) in lens.iter().enumerate() {
            sub.submit_with_deadline(vec![1; len], vec![0; len], deadline(i))
                .expect("well under capacity");
        }
        sub.shared.closed.store(true, Ordering::SeqCst);
        let mut live: Vec<(usize, Vec<u64>)> = Vec::new();
        while let Some(formed) = next_batch(&sub.shared, 0) {
            live.push((
                formed.bucket,
                formed.entries.iter().map(|e| e.seq).collect(),
            ));
        }
        assert_eq!(
            live.iter().map(|(_, s)| s.len()).sum::<usize>(),
            lens.len(),
            "drain loses no admitted request"
        );

        let trace: Vec<Arrival> = lens
            .iter()
            .enumerate()
            .map(|(i, &len)| Arrival {
                at: Duration::ZERO,
                len,
                deadline: deadline(i),
            })
            .collect();
        let report = run(
            &SimConfig {
                replicas: 1,
                queue_capacity: 64,
                sched: SchedPolicy::Conserve,
                buckets: BucketLayout::pow2(8, 32),
                batch: BatchPolicyTable::uniform(BatchPolicy {
                    max_batch: 3,
                    max_wait: Duration::ZERO,
                }),
                service: ServiceModel {
                    batch_overhead: Duration::ZERO,
                    per_width: Duration::ZERO,
                },
                degrade: DegradeLadder::none(),
                m_full: 1,
                admission_edf: false,
                ..SimConfig::default()
            },
            &trace,
        );
        let simulated: Vec<(usize, Vec<u64>)> = report
            .batches
            .iter()
            .map(|b| (b.bucket, b.seqs.clone()))
            .collect();
        assert_eq!(
            live, simulated,
            "live gateway and simulator disagree on the schedule"
        );
    }

    #[test]
    fn await_reply_bounds_the_wait_and_flags_a_dropped_sender() {
        // dropped sender: immediate ReplyLost, no hang
        let (tx, rx) = channel::<GatewayReply>();
        drop(tx);
        match await_reply(&rx, Duration::from_secs(60)) {
            Err(Shed::ReplyLost { waited_ms }) => {
                assert_eq!(waited_ms, 60_000, "reports the wait budget")
            }
            other => panic!("expected ReplyLost, got {other:?}"),
        }
        // live-but-silent sender: bounded by the timeout
        let (tx, rx) = channel::<GatewayReply>();
        let t0 = std::time::Instant::now();
        assert!(matches!(
            await_reply(&rx, Duration::from_millis(50)),
            Err(Shed::ReplyLost { waited_ms: 50 })
        ));
        assert!(t0.elapsed() >= Duration::from_millis(50));
        // a reply already in the channel passes straight through
        tx.send(Err(Shed::DeadlineExpired)).unwrap();
        assert!(matches!(
            await_reply(&rx, Duration::from_millis(1)),
            Err(Shed::DeadlineExpired)
        ));
    }

    #[test]
    fn shed_rate_zero_offered_is_zero_not_nan() {
        // a gateway that served nothing (shutdown before any submit)
        // must report 0.0, not 0/0 = NaN, through every stats surface
        let stats = GatewayStats {
            accepted: 0,
            completed: 0,
            rejected: 0,
            rejected_infeasible: 0,
            shed_deadline: 0,
            failed_internal: 0,
            requeued: 0,
            replica_restarts: 0,
            stolen: 0,
            cache_abandoned: 0,
            served_full: 0,
            served_degraded: 0,
            cache_hits: 0,
            cache_misses: 0,
            batches: 0,
            peak_queue_depth: 0,
            latency: Histogram::new(),
            queue_wait: Histogram::new(),
            queue_depth: Histogram::new(),
            bucket_widths: vec![16],
            per_bucket: vec![Histogram::new()],
            per_replica: Vec::new(),
            elapsed_secs: 0.0,
            throughput_rps: 0.0,
        };
        assert_eq!(stats.shed_rate(), 0.0);
        assert!(!stats.shed_rate().is_nan());
        // same guard on the derived cache hit rate: 0 lookups is 0.0,
        // not 0/0 = NaN
        assert_eq!(stats.cache_hit_rate(), 0.0);
        assert!(!stats.cache_hit_rate().is_nan());
        // and the Display path renders the 0-traffic stats without panic
        let _ = format!("{stats}");
        // a probed cache reports the plain ratio
        let probed =
            GatewayStats { cache_hits: 3, cache_misses: 1, ..stats };
        assert_eq!(probed.cache_hit_rate(), 0.75);
    }
}
