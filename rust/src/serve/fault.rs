//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a small, seed-derived script of failures keyed by
//! request sequence number. The same plan is honored by both executors:
//! the live gateway's replica loop (panics, stalls, and abandoned cache
//! leases really happen, and supervision really recovers) and the
//! virtual-clock `serve::sim` (the identical accounting is proven with
//! exact assertions and zero wall-clock sleeps). Keying on the admission
//! `seq` — not on wall time or replica identity — is what makes a chaos
//! run reproducible: seqs are assigned deterministically at admission,
//! so a `(trace, plan)` pair names the same failure schedule on every
//! run, thread count, and kernel variant.
//!
//! The plan is carried by `GatewayConfig::fault` / the `run_faulted`
//! sim entry points. Production configs leave it empty
//! ([`FaultPlan::none`]); the empty plan is one `is_empty` branch per
//! batch on the hot path.

use crate::util::Rng;

/// One injected failure, keyed by request sequence number.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The request's own forward panics (a poisoned request): per-
    /// request isolation catches it and the request fails terminally
    /// with `Shed::InternalError`; batch-mates are untouched.
    PanicOnSeq(u64),
    /// Any replica holding this seq in a formed batch dies (a crashy
    /// replica, not a poisoned request): the supervisor respawns the
    /// worker, the batch requeues under the retry budget, and the seq
    /// fails terminally only once its budget is exhausted.
    KillReplicaOnSeq(u64),
    /// The replica serving this seq stalls for `ns` nanoseconds before
    /// executing the batch (a slow replica, not a dead one). With
    /// cross-replica stealing enabled (`GatewayConfig::steal` /
    /// `SimConfig::steal`), the wedged replica posts its batch to the
    /// steal board first, so an idle peer whole-steals and serves it
    /// within one heartbeat instead of the full stall.
    StallOnSeq { seq: u64, ns: u64 },
    /// The request panics after checking its session out of the prefix
    /// cache: the lease drop-guard must discard the session (never
    /// publish it back) and the request fails terminally. Live gateway
    /// only — the sim has no cache.
    AbandonLeaseOnSeq(u64),
}

/// A deterministic fault-injection script (see the module docs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: Vec<FaultKind>,
}

impl FaultPlan {
    /// The empty plan: no faults, and the executors' fault hooks reduce
    /// to one branch per batch.
    pub fn none() -> FaultPlan {
        FaultPlan { faults: Vec::new() }
    }

    /// A plan built from an explicit fault list (tests that need exact
    /// schedules).
    pub fn from_faults(faults: Vec<FaultKind>) -> FaultPlan {
        FaultPlan { faults }
    }

    /// A randomized-but-reproducible plan over seqs `0..max_seq`: each
    /// seq independently draws at most one fault (roughly one seq in
    /// eight is faulted, split across the four kinds). Identical
    /// `(seed, max_seq)` always yields an identical plan.
    pub fn seeded(seed: u64, max_seq: u64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA17_7E57_0000_0001);
        let mut faults = Vec::new();
        for seq in 0..max_seq {
            if !rng.bernoulli(0.125) {
                continue;
            }
            faults.push(match rng.below(4) {
                0 => FaultKind::PanicOnSeq(seq),
                1 => FaultKind::KillReplicaOnSeq(seq),
                2 => FaultKind::StallOnSeq {
                    seq,
                    ns: 1_000 * (1 + rng.below(2_000) as u64),
                },
                _ => FaultKind::AbandonLeaseOnSeq(seq),
            });
        }
        FaultPlan { faults }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn faults(&self) -> &[FaultKind] {
        &self.faults
    }

    /// Does `seq`'s own execution panic?
    pub fn panic_for(&self, seq: u64) -> bool {
        self.faults.iter().any(|f| matches!(f, FaultKind::PanicOnSeq(s) if *s == seq))
    }

    /// Does a replica holding `seq` die before executing its batch?
    pub fn kill_for(&self, seq: u64) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, FaultKind::KillReplicaOnSeq(s) if *s == seq))
    }

    /// Injected stall before executing `seq`, if any.
    pub fn stall_ns(&self, seq: u64) -> Option<u64> {
        self.faults.iter().find_map(|f| match f {
            FaultKind::StallOnSeq { seq: s, ns } if *s == seq => Some(*ns),
            _ => None,
        })
    }

    /// Does `seq` abandon its prefix-cache lease mid-encode?
    pub fn abandon_for(&self, seq: u64) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, FaultKind::AbandonLeaseOnSeq(s) if *s == seq))
    }
}

/// The `YOSO_FAULT_SEED` environment knob: an extra seed the chaos
/// tests fold into every generated fault plan, so CI can sweep fault
/// schedules the same way it sweeps threads and kernels. Unset or
/// unparsable means 0 (the default schedule).
pub fn env_seed() -> u64 {
    std::env::var("YOSO_FAULT_SEED")
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_reproducible_and_seed_sensitive() {
        let a = FaultPlan::seeded(7, 256);
        let b = FaultPlan::seeded(7, 256);
        assert_eq!(a, b, "same (seed, max_seq) -> same plan");
        assert!(!a.is_empty(), "1-in-8 over 256 seqs fires w.h.p.");
        let c = FaultPlan::seeded(8, 256);
        assert_ne!(a, c, "different seed -> different schedule");
    }

    #[test]
    fn queries_match_the_fault_list() {
        let plan = FaultPlan::from_faults(vec![
            FaultKind::PanicOnSeq(3),
            FaultKind::KillReplicaOnSeq(5),
            FaultKind::StallOnSeq { seq: 7, ns: 1234 },
            FaultKind::AbandonLeaseOnSeq(9),
        ]);
        assert!(plan.panic_for(3) && !plan.panic_for(5));
        assert!(plan.kill_for(5) && !plan.kill_for(3));
        assert_eq!(plan.stall_ns(7), Some(1234));
        assert_eq!(plan.stall_ns(3), None);
        assert!(plan.abandon_for(9) && !plan.abandon_for(7));
        assert!(FaultPlan::none().is_empty());
    }
}
