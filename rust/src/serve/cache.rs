//! Gateway prefix/session cache: re-serve shared prefixes from streamed
//! bucket tables instead of re-encoding them.
//!
//! Keyed by the content-canonical request identity — the canonicalized
//! (ids, segs) prefix plus the `bucket_len` width, hashed with a rolling
//! FNV so one O(n) pass yields every prefix's key. A request that
//! extends a cached session at the same width checks the session out,
//! appends only the new tokens (O(m·dv) each via
//! [`EncoderStream::append`]), classifies, and publishes the grown
//! session back. Because the streamed path is bit-identical to the batch
//! recompute (`tests/prop_yoso_stream.rs`), cache hits are invisible to
//! the gateway determinism contract — they only move wall-clock.
//!
//! Width is part of the key: the serving RNG stream and the hash
//! functions are width-keyed (`model::encoder::serving_rng`), so a
//! session crossing a width boundary (its `bucket_len` doubles) starts a
//! fresh stream rather than reusing tables hashed for the old width.
//!
//! Eviction is LRU under a byte budget (`approx_bytes` of each resident
//! stream). Hit/miss counters surface in `GatewayStats`.

use crate::attention::YosoAttention;
use crate::model::encoder::EncoderStream;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// One cached session, stored under its full-content prefix key.
struct CacheEntry {
    stream: EncoderStream,
    bytes: usize,
    last_used: u64,
}

/// Byte-budgeted LRU of [`EncoderStream`] sessions, keyed by canonical
/// content prefix + width. Checkout *removes* the entry (streams are
/// single-owner while a replica appends to them); publish returns the
/// grown session.
pub struct PrefixCache {
    att: YosoAttention,
    budget: usize,
    entries: HashMap<u64, CacheEntry>,
    bytes: usize,
    tick: u64,
    /// requests served from a cached prefix
    pub hits: u64,
    /// requests that started a fresh stream
    pub misses: u64,
    /// sessions discarded by a dropped [`SessionLease`] (a replica died
    /// between checkout and publish); shared with the leases by handle
    /// so the drop-guard never needs the cache lock
    abandoned: Arc<AtomicU64>,
}

/// Drop-guard around a checked-out (or freshly started) session: the
/// replica holds the stream through this lease while it appends and
/// classifies, and `complete` hands the stream back for publishing. A
/// lease dropped any other way — the owning request panicked, the
/// replica died mid-encode — **discards** the session and bumps the
/// cache's abandoned counter, so a half-appended stream is never
/// published back as if it were a valid cached prefix. Checkout already
/// removed the entry, so discarding loses a warm session (a later
/// request re-encodes from scratch: correctness by the bit-identity
/// contract, only wall-clock is lost), never corrupts one.
pub struct SessionLease {
    stream: Option<EncoderStream>,
    abandoned: Arc<AtomicU64>,
}

impl SessionLease {
    /// Wrap a session in a lease. `abandoned` is the owning cache's
    /// counter handle ([`PrefixCache::abandoned_handle`]).
    pub fn new(
        stream: EncoderStream,
        abandoned: Arc<AtomicU64>,
    ) -> SessionLease {
        SessionLease { stream: Some(stream), abandoned }
    }

    /// The leased session (present until `complete` consumes the lease).
    pub fn stream(&mut self) -> &mut EncoderStream {
        self.stream.as_mut().expect("lease already completed")
    }

    /// Defuse the guard and hand the session back for publishing.
    pub fn complete(mut self) -> EncoderStream {
        self.stream.take().expect("lease already completed")
    }
}

impl Drop for SessionLease {
    fn drop(&mut self) {
        if self.stream.take().is_some() {
            self.abandoned.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Rolling FNV over the width prefix.
fn fnv_start(width: usize) -> u64 {
    fnv_step(0xcbf29ce484222325, width as u64)
}

/// Fold one (id, seg) token into the rolling key.
fn fnv_push(h: u64, id: i32, seg: i32) -> u64 {
    fnv_step(h, (id as u32 as u64) | ((seg as u32 as u64) << 32))
}

fn fnv_step(mut h: u64, data: u64) -> u64 {
    for b in data.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h
}

impl PrefixCache {
    /// `att` is the streamable attention new sessions are built from
    /// (see `attention::yoso_variant`); `budget` bounds resident bytes.
    pub fn new(att: YosoAttention, budget: usize) -> PrefixCache {
        PrefixCache {
            att,
            budget,
            entries: HashMap::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            abandoned: Arc::new(AtomicU64::new(0)),
        }
    }

    /// The attention template for constructing fresh sessions on a miss.
    pub fn template(&self) -> YosoAttention {
        self.att.clone()
    }

    /// A clonable handle to the abandoned-lease counter, for wrapping
    /// checked-out sessions in a [`SessionLease`] without re-taking the
    /// cache lock at drop time.
    pub fn abandoned_handle(&self) -> Arc<AtomicU64> {
        Arc::clone(&self.abandoned)
    }

    /// Sessions discarded by dropped leases (never published back).
    pub fn abandoned(&self) -> u64 {
        self.abandoned.load(Ordering::Relaxed)
    }

    /// Consistency sweep after mutex-poison recovery: recompute the
    /// resident byte total from the entries themselves (the only
    /// derived field a half-completed mutation could have skewed) and
    /// re-run eviction so the budget invariant holds again. Counters
    /// are monotone telemetry and are left as-is.
    pub fn repair(&mut self) {
        self.bytes = self.entries.values().map(|e| e.bytes).sum();
        while self.bytes > self.budget && !self.entries.is_empty() {
            let lru = *self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .unwrap()
                .0;
            let evicted = self.entries.remove(&lru).unwrap();
            self.bytes -= evicted.bytes;
        }
    }

    /// Resident sessions.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Resident bytes (approximate, the eviction currency).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Take the longest cached session that is a prefix of
    /// (`ids`, `segs`) at exactly `width`, longest match first. The hit
    /// is removed — the caller appends the remaining tokens and
    /// `publish`es the grown session back. Counts one hit or one miss.
    pub fn checkout(
        &mut self,
        ids: &[i32],
        segs: &[i32],
        width: usize,
    ) -> Option<EncoderStream> {
        debug_assert_eq!(ids.len(), segs.len());
        let n = ids.len().min(segs.len());
        let mut keys = Vec::with_capacity(n);
        let mut h = fnv_start(width);
        for i in 0..n {
            h = fnv_push(h, ids[i], segs[i]);
            keys.push(h);
        }
        for k in (1..=n).rev() {
            let key = keys[k - 1];
            // verify against the stream's own content: a key collision
            // is just a miss for this prefix length
            let hit = self.entries.get(&key).is_some_and(|e| {
                e.stream.width() == width
                    && e.stream.ids() == &ids[..k]
                    && e.stream.segs() == &segs[..k]
            });
            if hit {
                let e = self.entries.remove(&key).unwrap();
                self.bytes -= e.bytes;
                self.hits += 1;
                return Some(e.stream);
            }
        }
        self.misses += 1;
        None
    }

    /// Insert (or re-insert after checkout) a session under its full
    /// content key, then evict least-recently-used sessions until the
    /// byte budget holds. An over-budget singleton evicts itself — the
    /// cache never exceeds its budget to keep one entry.
    pub fn publish(&mut self, stream: EncoderStream) {
        if stream.is_empty() {
            return;
        }
        self.tick += 1;
        let mut h = fnv_start(stream.width());
        for (&id, &seg) in stream.ids().iter().zip(stream.segs()) {
            h = fnv_push(h, id, seg);
        }
        let bytes = stream.approx_bytes();
        let entry = CacheEntry { stream, bytes, last_used: self.tick };
        if let Some(old) = self.entries.insert(h, entry) {
            self.bytes -= old.bytes;
        }
        self.bytes += bytes;
        while self.bytes > self.budget && !self.entries.is_empty() {
            let lru = *self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .unwrap()
                .0;
            let evicted = self.entries.remove(&lru).unwrap();
            self.bytes -= evicted.bytes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::encoder::{
        encoder_abi_spec, Encoder, EncoderConfig, EncoderStream,
    };
    use crate::model::ParamSet;

    fn session(
        enc: &Encoder,
        att: &YosoAttention,
        ids: &[i32],
    ) -> EncoderStream {
        let segs = vec![0i32; ids.len()];
        let mut s = EncoderStream::new(enc, att, 7, 16);
        s.append(enc, ids, &segs);
        s
    }

    #[test]
    fn checkout_finds_longest_prefix_and_counts() {
        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 0);
        let enc = Encoder::new(cfg, &params);
        let att = YosoAttention::new(4, 2, false);
        let mut cache = PrefixCache::new(att.clone(), usize::MAX);
        cache.publish(session(&enc, &att, &[5, 6]));
        cache.publish(session(&enc, &att, &[5, 6, 7]));
        assert_eq!(cache.len(), 2);

        // longest stored prefix wins
        let ids = [5, 6, 7, 8];
        let segs = [0, 0, 0, 0];
        let got = cache.checkout(&ids, &segs, 16).expect("prefix hit");
        assert_eq!(got.len(), 3, "longest prefix, not the shorter one");
        assert_eq!((cache.hits, cache.misses), (1, 0));
        // checkout removed it; the shorter prefix still hits
        let got2 = cache.checkout(&ids, &segs, 16).expect("shorter prefix");
        assert_eq!(got2.len(), 2);
        // width is part of the identity
        assert!(cache.checkout(&[5, 6], &[0, 0], 8).is_none());
        // unrelated content misses
        assert!(cache.checkout(&[9, 9], &[0, 0], 16).is_none());
        assert_eq!((cache.hits, cache.misses), (2, 2));
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 0);
        let enc = Encoder::new(cfg, &params);
        let att = YosoAttention::new(4, 2, false);
        let a = session(&enc, &att, &[1, 2]);
        let one = a.approx_bytes();
        // room for one resident session, not two
        let mut cache = PrefixCache::new(att.clone(), one + one / 2);
        cache.publish(a);
        cache.publish(session(&enc, &att, &[3, 4]));
        assert_eq!(cache.len(), 1, "older session evicted");
        assert!(cache.bytes() <= one + one / 2);
        assert!(cache.checkout(&[1, 2], &[0, 0], 16).is_none(), "A evicted");
        assert!(cache.checkout(&[3, 4], &[0, 0], 16).is_some(), "B resident");

        // an over-budget singleton evicts itself rather than pinning
        let mut tiny = PrefixCache::new(att.clone(), 1);
        tiny.publish(session(&enc, &att, &[1, 2]));
        assert!(tiny.is_empty());
        assert_eq!(tiny.bytes(), 0);
    }

    #[test]
    fn dropped_lease_discards_session_and_counts_abandonment() {
        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 0);
        let enc = Encoder::new(cfg, &params);
        let att = YosoAttention::new(4, 2, false);
        let mut cache = PrefixCache::new(att.clone(), usize::MAX);
        cache.publish(session(&enc, &att, &[5, 6]));

        // a completed lease hands the session back and counts nothing
        let got = cache.checkout(&[5, 6], &[0, 0], 16).expect("hit");
        let mut lease = SessionLease::new(got, cache.abandoned_handle());
        assert_eq!(lease.stream().len(), 2);
        cache.publish(lease.complete());
        assert_eq!(cache.abandoned(), 0);
        assert_eq!(cache.len(), 1, "completed session published back");

        // a dropped lease discards the session and counts once
        let got = cache.checkout(&[5, 6], &[0, 0], 16).expect("hit");
        drop(SessionLease::new(got, cache.abandoned_handle()));
        assert_eq!(cache.abandoned(), 1);
        assert!(cache.is_empty(), "abandoned session never re-published");
        assert!(
            cache.checkout(&[5, 6], &[0, 0], 16).is_none(),
            "next request re-encodes from scratch"
        );
    }

    #[test]
    fn repair_recomputes_bytes_and_reapplies_the_budget() {
        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 0);
        let enc = Encoder::new(cfg, &params);
        let att = YosoAttention::new(4, 2, false);
        let one = session(&enc, &att, &[1, 2]).approx_bytes();
        let mut cache = PrefixCache::new(att.clone(), one + one / 2);
        cache.publish(session(&enc, &att, &[1, 2]));
        // simulate the skew a half-completed mutation leaves behind
        cache.bytes = 0;
        cache.repair();
        assert_eq!(cache.bytes(), one, "recomputed from residents");
        assert_eq!(cache.len(), 1, "within budget: nothing evicted");

        // skew the other way: repair must also re-run eviction
        cache.publish(session(&enc, &att, &[3, 4]));
        assert_eq!(cache.len(), 1, "budget holds one session");
        cache.bytes = 0; // hide the overshoot...
        cache.entries.insert(
            999,
            CacheEntry {
                stream: session(&enc, &att, &[7, 8]),
                bytes: one,
                last_used: 0, // ...oldest, so repair evicts it
            },
        );
        cache.repair();
        assert_eq!(cache.len(), 1, "repair re-applied LRU eviction");
        assert!(cache.bytes() <= one + one / 2);
        assert!(
            cache.checkout(&[3, 4], &[0, 0], 16).is_some(),
            "the newest session survived the sweep"
        );
    }

    /// Stress the checkout/evict race: replicas checking sessions out
    /// while publishes force LRU eviction. Every hit must verify
    /// against the stream's own content (no wrong-session hit even
    /// under key churn), and the byte ledger must balance exactly —
    /// no double-freed budget bytes.
    #[test]
    fn checkout_evict_race_never_mixes_sessions_or_bytes() {
        use std::sync::Mutex;

        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 0);
        let enc = std::sync::Arc::new(Encoder::new(cfg, &params));
        let att = YosoAttention::new(4, 2, false);
        let one = session(&enc, &att, &[0, 0]).approx_bytes();
        // room for ~2 sessions while 4 threads publish: constant churn
        let cache =
            std::sync::Arc::new(Mutex::new(PrefixCache::new(att, one * 5 / 2)));

        std::thread::scope(|s| {
            for t in 0..4i32 {
                let cache = std::sync::Arc::clone(&cache);
                let enc = std::sync::Arc::clone(&enc);
                s.spawn(move || {
                    for i in 0..12i32 {
                        let key = 10 * ((i + t) % 3); // shared across threads
                        let ids = [key, key + 1];
                        let segs = [0, 0];
                        let got =
                            cache.lock().unwrap().checkout(&ids, &segs, 16);
                        let stream = match got {
                            // a hit must be *our* session, verified by
                            // content, no matter what eviction did
                            Some(st) => {
                                assert_eq!(st.ids(), &ids);
                                assert_eq!(st.segs(), &segs);
                                assert_eq!(st.width(), 16);
                                st
                            }
                            None => session(&enc, &att_of(&cache), &ids),
                        };
                        cache.lock().unwrap().publish(stream);
                    }
                });
            }
        });

        let c = cache.lock().unwrap();
        // the ledger balances: resident bytes are exactly the sum over
        // surviving entries, and the budget was never double-freed below
        let expect: usize = c.entries.values().map(|e| e.bytes).sum();
        assert_eq!(c.bytes(), expect, "byte ledger matches residents");
        assert!(c.bytes() <= one * 5 / 2, "budget holds after the storm");
        assert!(!c.is_empty(), "churn ends with live residents");
    }

    fn att_of(
        cache: &std::sync::Mutex<PrefixCache>,
    ) -> YosoAttention {
        cache.lock().unwrap().template()
    }
}
