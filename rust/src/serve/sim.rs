//! Deterministic discrete-event simulator for the gateway's scheduling
//! stack, on a [`SimClock`] — zero threads, zero wall-clock sleeps,
//! exact assertions.
//!
//! The simulator drives the **same scheduling core the live gateway
//! runs** (`serve::sched`: bucket pick, within-bucket dequeue order,
//! expiry sheds, per-bucket batch policies) over a scripted arrival
//! trace, with replicas that "execute" batches in simulated service
//! time. Every decision is replayed event by event on virtual time, so
//! tests assert scheduling behavior *exactly*: which requests formed
//! which batch on which replica at which tick, that no replica idled
//! while a bucket held work (work conservation), that within-bucket
//! dequeue order is deadline-earliest-first, and that shed accounting
//! reconciles to the request (`accepted == completed + shed_deadline`).
//!
//! # Faithfulness
//!
//! The dispatch rules mirror `gateway::next_batch` one for one:
//!
//! * an idle replica picks a bucket via [`BucketQueues::pick_bucket`]
//!   and drains it via [`BucketQueues::pop_next`] up to the bucket's
//!   [`BatchPolicyTable`] `max_batch`;
//! * a below-max batch ages up to `max_wait` counted from its first
//!   request's enqueue tick (clamped to now), topping up from its
//!   bucket as arrivals land — the live replica's condvar park + re-drain
//!   loop, as a `Waiting` state with an aging-deadline event;
//! * under [`SchedPolicy::Conserve`] a partial batch ships immediately
//!   whenever any bucket still holds work (work conservation) or a
//!   batch member's deadline would expire inside the aging wait (the
//!   deadline-aware park cap); under [`SchedPolicy::Fifo`] it always
//!   ages — the PR-3 behavior whose idle-while-backlogged ticks the
//!   audit records. The ship-or-park rule lives in one place
//!   ([`should_ship`]) so the two replica states cannot drift apart;
//! * expired entries are shed before execution, both from the queues
//!   (every event tick) and from held batches (at dispatch — the live
//!   path's post-park re-check);
//! * admission is the bounded queue: at capacity, arrivals count as
//!   `rejected` (the `Reject` policy; `Block` has no meaning without
//!   real producers to park);
//! * the degradation ladder and admission EDF mirror the gateway: the
//!   sim maintains the same full-quality EWMA service estimate (updated
//!   at batch completion; a degraded batch's sample scales back up by
//!   `m/m'`), picks each batch's `m'` off the post-pop backlog at
//!   dispatch — `next_batch`'s exact decision point, advancing the same
//!   step-up hysteresis state (`DegradeLadder::plan_at`) the live
//!   gateway does — and, with `admission_edf`, rejects warm-infeasible
//!   deadlines at admission (`rejected_infeasible`, never queued);
//! * [`run_traced`] mirrors every decision into an `obs::TraceSink`
//!   with the live gateway's exact event schema and lane layout, and
//!   tracing never changes a decision (the report is bit-identical to
//!   the untraced run);
//! * the queue layout is the [`Sharding`] knob: the run schedules over
//!   either the single-lock [`BucketQueues`] or the per-bucket-locked
//!   [`ShardedQueues`] the live gateway runs — both execute the same
//!   per-lane decision procedures, and the sweep in
//!   `tests/sim_gateway.rs` proves the schedules bit-identical, which
//!   is what licenses the sharded layout in production;
//! * with `SimConfig::steal` on, an idle replica with nothing queued
//!   supervises its peers instead of parking: it whole-steals a stalled
//!   replica's posted batch once the batch has sat a full
//!   `SimConfig::heartbeat`, and otherwise splits a peer's parked
//!   partial batch — taking the *tail* (the younger half in dequeue
//!   order), so stealing never reorders within a bucket and never
//!   loses an admitted request.
//!
//! # Capacity planning
//!
//! Because replicas "execute" in virtual time, the simulator doubles as
//! a capacity-planning instrument: [`diurnal_trace`] and
//! [`flash_crowd_trace`] script million-request load shapes, and
//! [`frontier`] sweeps replica counts over one trace to produce the
//! replica-count vs p99/goodput frontier curves a planner reads
//! deployment sizes off (`benches/cap_frontier.rs` emits them as CSV)
//! — at zero wall-clock service cost.
//!
//! What the simulator does *not* model: compute itself (no logits — the
//! bit-identity half of the contract is `tests/prop_serve_gateway.rs`'s
//! job against the real gateway), pool fan-out inside a replica, and
//! lock contention. Service time is the declared [`ServiceModel`].

use super::batcher::BatchPolicy;
use super::clock::{Clock, SimClock, Tick};
use super::fault::FaultPlan;
use super::gateway::{BucketLayout, Quality};
use super::sched::{
    admission_cap, deadline_infeasible, update_ewma, BatchPolicyTable,
    BucketQueues, DegradeLadder, Entry, LadderState, SchedPolicy,
    ShardedQueues, Sharding,
};
use crate::obs::{self, Event, EventKind, QualityTag, ShedTag, TraceSink};
use std::time::Duration;

/// Record `e` on `lane` when a sink is attached (the untraced run pays
/// one branch per would-be event — same contract as the live gateway).
fn emit(sink: Option<&TraceSink>, lane: usize, e: Event) {
    if let Some(s) = sink {
        s.emit(lane, e);
    }
}

/// One scripted arrival: offset from trace start, sequence length
/// (routes to a bucket), optional relative deadline.
#[derive(Clone, Debug)]
pub struct Arrival {
    pub at: Duration,
    pub len: usize,
    pub deadline: Option<Duration>,
}

/// Linear batch cost model: `batch_overhead + per_width x width x
/// batch_len`. Width is the routed bucket width — the same quantity the
/// real bucketed gateway's cost scales with.
#[derive(Clone, Copy, Debug)]
pub struct ServiceModel {
    pub batch_overhead: Duration,
    pub per_width: Duration,
}

impl ServiceModel {
    pub fn batch_duration(&self, width: usize, batch_len: usize) -> Duration {
        self.batch_duration_at(width, batch_len, 1, 1)
    }

    /// Degradation-aware batch cost: the width-proportional term (the
    /// attention sweep, linear in hash rounds) scales by `m_eff /
    /// m_full`; `batch_overhead` (dispatch, pool fan-out, the
    /// non-attention layers) does not — mirroring why the gateway's
    /// restated EWMA sample is a deliberate over-estimate.
    pub fn batch_duration_at(
        &self,
        width: usize,
        batch_len: usize,
        m_eff: usize,
        m_full: usize,
    ) -> Duration {
        let units = (width * batch_len).min(u32::MAX as usize) as u32;
        let m_full = m_full.max(1);
        let m_eff = m_eff.clamp(1, m_full);
        let sweep = if m_eff == m_full {
            self.per_width * units
        } else {
            (self.per_width * units).mul_f64(m_eff as f64 / m_full as f64)
        };
        self.batch_overhead + sweep
    }
}

impl Default for ServiceModel {
    fn default() -> Self {
        ServiceModel {
            batch_overhead: Duration::from_millis(1),
            per_width: Duration::from_micros(10),
        }
    }
}

/// Simulation configuration — the scheduling slice of `GatewayConfig`.
#[derive(Clone, Debug)]
pub struct SimConfig {
    pub replicas: usize,
    pub queue_capacity: usize,
    pub sched: SchedPolicy,
    pub buckets: BucketLayout,
    pub batch: BatchPolicyTable,
    pub service: ServiceModel,
    /// overload degradation ladder (disabled: every batch runs at
    /// `m_full`, the pre-ladder behavior — and, since `m_eff ==
    /// m_full`, bit-identical reports to the pre-ladder simulator)
    pub degrade: DegradeLadder,
    /// the full-quality hash-round count the [`ServiceModel`]'s
    /// width-proportional term is calibrated at
    pub m_full: usize,
    /// mirror of `GatewayConfig::admission_edf`
    pub admission_edf: bool,
    /// queue layout the run schedules over. Both layouts execute the
    /// same decision procedures and produce bit-identical schedules
    /// (the sweep in `tests/sim_gateway.rs`); the default resolves
    /// `YOSO_SHARDS` so CI can sweep the whole suite across both.
    pub shards: Sharding,
    /// cross-replica batch stealing: an idle replica with nothing
    /// queued whole-steals a stalled peer's posted batch after
    /// [`heartbeat`](SimConfig::heartbeat), and otherwise takes the
    /// tail of a peer's parked partial batch. Off by default — every
    /// non-stealing trace's timings are unchanged.
    pub steal: bool,
    /// supervision heartbeat: how long a posted batch may sit on a
    /// stalled replica before an idle peer may whole-steal it
    pub heartbeat: Duration,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            replicas: 1,
            queue_capacity: 64,
            sched: SchedPolicy::Conserve,
            buckets: BucketLayout::pow2(8, 64),
            batch: BatchPolicyTable::uniform(BatchPolicy::default()),
            service: ServiceModel::default(),
            degrade: DegradeLadder::none(),
            m_full: 16,
            admission_edf: false,
            shards: Sharding::from_env(),
            steal: false,
            heartbeat: Duration::from_millis(5),
        }
    }
}

/// One executed batch: where, when, and exactly which requests in which
/// dequeue order.
#[derive(Clone, Debug, PartialEq)]
pub struct SimBatch {
    pub replica: usize,
    pub bucket: usize,
    pub width: usize,
    /// the ladder's hash-round budget for this batch (`m_full` when the
    /// ladder is disabled or pressure is low)
    pub m_eff: usize,
    pub formed_at: Tick,
    pub done_at: Tick,
    /// arrival seqs in dequeue order (EDF under `Conserve`, arrival
    /// order under `Fifo`)
    pub seqs: Vec<u64>,
}

/// Everything a run decided, for exact assertions.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimReport {
    pub accepted: u64,
    pub rejected: u64,
    /// admission-time EDF rejections (never queued, not in `accepted`)
    pub rejected_infeasible: u64,
    pub shed_deadline: u64,
    pub completed: u64,
    /// completions that met their deadline (`done_at <= deadline`;
    /// deadline-free requests count as met) — the overload A/B metric:
    /// degradation exists to raise this, not raw `completed`
    pub goodput: u64,
    /// completions executed below `m_full` (ladder step-downs)
    pub served_degraded: u64,
    pub peak_depth: usize,
    pub batches: Vec<SimBatch>,
    /// arrival-to-completion latency (virtual ms) per completed request
    pub latencies_ms: Vec<f64>,
    /// event ticks at which some replica sat idle (or parked aging a
    /// partial batch) while live queued work existed — the
    /// work-conservation audit. Must be empty under
    /// `SchedPolicy::Conserve`; non-empty ticks under `Fifo` are the
    /// idle-replica-parked-on-a-foreign-bucket behavior this PR retires.
    pub conservation_violations: Vec<Tick>,
    /// admitted requests that failed terminally under injected faults
    /// (own panic, or retry budget exhausted by replica kills) — the
    /// sim's `Shed::InternalError` ledger
    pub failed_internal: u64,
    /// requeue actions: a request pulled back out of a killed replica's
    /// batch (one per requeue; a request can count several times)
    pub requeued: u64,
    /// injected replica deaths survived by supervision
    pub replica_restarts: u64,
    /// cross-replica steal actions ([`SimConfig::steal`]): tail splits
    /// of a peer's parked partial plus whole-steals of a stalled
    /// replica's posted batch — one count per action, not per request
    pub stolen: u64,
    /// admissions of `BestEffort`-class arrivals ([`run_classed`])
    pub accepted_best_effort: u64,
    /// queue-full rejections of `BestEffort`-class arrivals
    pub rejected_best_effort: u64,
}

impl SimReport {
    pub fn mean_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        self.latencies_ms.iter().sum::<f64>() / self.latencies_ms.len() as f64
    }

    pub fn p99_ms(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.latencies_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::util::stats::quantile_exact(&s, 0.99)
    }

    /// The accounting identity every trace must satisfy: every admitted
    /// request reaches exactly one terminal outcome — replied, shed on
    /// deadline, or failed terminally under injected faults.
    pub fn reconciles(&self) -> bool {
        self.accepted
            == self.completed + self.shed_deadline + self.failed_internal
    }
}

/// Replica state machine: mirrors a live replica's observable modes
/// (idle in `pick`, parked in the aging wait, executing, and — under
/// [`SimConfig::steal`] — wedged by an injected stall with its formed
/// batch posted for supervision).
enum Rep {
    Idle,
    Waiting {
        bucket: usize,
        batch: Vec<Entry<()>>,
        max_batch: usize,
        age_deadline: Tick,
    },
    Busy {
        until: Tick,
        batch: SimBatch,
        entries: Vec<Entry<()>>,
    },
    /// Wedged by an injected stall while holding a formed batch
    /// (`SimConfig::steal` runs only). Peers may whole-steal the batch
    /// once it has sat [`SimConfig::heartbeat`] past `posted`;
    /// unstolen, the replica wakes at `wake` and executes with no
    /// further penalty — the completion tick is then identical to the
    /// legacy inline-stall path.
    Stalled {
        /// `done_at` is a placeholder until execution actually starts
        batch: SimBatch,
        entries: Vec<Entry<()>>,
        wake: Tick,
        posted: Tick,
    },
}

/// The run's queue layout behind one dispatch surface
/// ([`SimConfig::shards`]): both variants execute the same per-lane
/// decision procedures, so a sim driven on either produces
/// bit-identical schedules — the property `tests/sim_gateway.rs`
/// sweeps.
enum SimQueues {
    Unsharded(BucketQueues<()>),
    PerBucket(ShardedQueues<()>),
}

impl SimQueues {
    fn new(shards: Sharding, n_buckets: usize) -> SimQueues {
        match shards {
            Sharding::Unsharded => {
                SimQueues::Unsharded(BucketQueues::new(n_buckets))
            }
            Sharding::PerBucket => {
                SimQueues::PerBucket(ShardedQueues::new(n_buckets))
            }
        }
    }

    fn len(&self) -> usize {
        match self {
            SimQueues::Unsharded(q) => q.len(),
            SimQueues::PerBucket(q) => q.len(),
        }
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn push(&mut self, bucket: usize, entry: Entry<()>) {
        match self {
            SimQueues::Unsharded(q) => q.push(bucket, entry),
            SimQueues::PerBucket(q) => q.push(bucket, entry),
        }
    }

    fn requeue(&mut self, bucket: usize, entry: Entry<()>) {
        match self {
            SimQueues::Unsharded(q) => q.requeue(bucket, entry),
            SimQueues::PerBucket(q) => q.requeue(bucket, entry),
        }
    }

    fn shed_expired(&mut self, now: Tick) -> Vec<Entry<()>> {
        match self {
            SimQueues::Unsharded(q) => q.shed_expired(now),
            SimQueues::PerBucket(q) => q.shed_expired(now),
        }
    }

    fn pick_bucket(&mut self, policy: SchedPolicy) -> Option<usize> {
        match self {
            SimQueues::Unsharded(q) => q.pick_bucket(policy),
            SimQueues::PerBucket(q) => q.pick_bucket(policy),
        }
    }

    fn pop_next(
        &mut self,
        bucket: usize,
        policy: SchedPolicy,
    ) -> Option<Entry<()>> {
        match self {
            SimQueues::Unsharded(q) => q.pop_next(bucket, policy),
            SimQueues::PerBucket(q) => q.pop_next(bucket, policy),
        }
    }
}

/// Pop bucket entries into `batch` up to `max_batch` — the live
/// replica's drain loop.
fn top_up(
    queues: &mut SimQueues,
    bucket: usize,
    sched: SchedPolicy,
    batch: &mut Vec<Entry<()>>,
    max_batch: usize,
) {
    while batch.len() < max_batch {
        match queues.pop_next(bucket, sched) {
            Some(e) => batch.push(e),
            None => break,
        }
    }
}

/// The one ship-or-park rule, shared by the `Idle` and `Waiting` arms —
/// and the rule `gateway::next_batch` enforces live: ship when full,
/// when the first request's aging budget is spent, or (Conserve) when
/// other work is backlogged or a member's deadline would expire inside
/// the aging wait.
fn should_ship(
    batch: &[Entry<()>],
    max_batch: usize,
    age_deadline: Tick,
    now: Tick,
    sched: SchedPolicy,
    queues: &SimQueues,
) -> bool {
    if batch.len() >= max_batch || now >= age_deadline {
        return true;
    }
    if sched != SchedPolicy::Conserve {
        return false;
    }
    !queues.is_empty()
        || batch
            .iter()
            .filter_map(|e| e.deadline)
            .min()
            .is_some_and(|d| d <= age_deadline)
}

/// Ship a batch on `replica`: re-check member expiry (the live path's
/// post-park re-check), apply any injected faults, then go busy for the
/// modeled service time. All members expired -> back to idle (the live
/// loop's "pick again").
///
/// Fault order mirrors the live replica loop: stall first (the batch
/// runs late — or, under `steal`, is posted for supervision), then a
/// replica kill (the batch never runs — the kill-trigger members spend
/// retry budget and fail terminally once it is gone; innocent
/// batch-mates always requeue and ride a later batch), then
/// per-request panics (the poisoned member fails terminally, its
/// batch-mates execute). `AbandonLeaseOnSeq` is a no-op here: the sim
/// models scheduling, not the prefix cache, and an abandoned lease only
/// costs a warm session, never a scheduling outcome.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    replica: usize,
    bucket: usize,
    batch: Vec<Entry<()>>,
    now: Tick,
    service: &ServiceModel,
    width: usize,
    m_eff: usize,
    m_full: usize,
    queues: &mut SimQueues,
    plan: &FaultPlan,
    retry_budget: u32,
    steal: bool,
    report: &mut SimReport,
    sink: Option<&TraceSink>,
) -> Rep {
    let mut live = Vec::with_capacity(batch.len());
    for e in batch {
        if e.expired(now) {
            report.shed_deadline += 1;
            emit(
                sink,
                0,
                Event::new(EventKind::Shed, now, e.seq)
                    .with_quality(QualityTag::BestEffort)
                    .with_shed(ShedTag::Expired),
            );
        } else {
            live.push(e);
        }
    }
    if live.is_empty() {
        return Rep::Idle;
    }
    let mut stall = Duration::ZERO;
    if !plan.is_empty() {
        if let Some(ns) =
            live.iter().filter_map(|e| plan.stall_ns(e.seq)).max()
        {
            stall = Duration::from_nanos(ns);
        }
        if live.iter().any(|e| plan.kill_for(e.seq)) {
            // the replica dies holding this batch: requeue each member
            // under the retry budget, then restart — a re-pick at this
            // same tick retries the batch, so a sticky kill seq burns
            // one retry per round until it runs out of budget and
            // fails terminally. Only the members that *are* the kill
            // trigger can be doomed: an innocent batch-mate always
            // requeues (its retry count still ticks up in the ledger)
            // and completes once the cursed seq is out of the bucket.
            for mut e in live {
                if plan.kill_for(e.seq) && e.retries >= retry_budget {
                    report.failed_internal += 1;
                    emit(
                        sink,
                        0,
                        Event::new(EventKind::Shed, now, e.seq)
                            .with_worker(replica)
                            .with_shed(ShedTag::Internal),
                    );
                } else {
                    e.retries += 1;
                    report.requeued += 1;
                    emit(
                        sink,
                        replica + 1,
                        Event::new(EventKind::Requeued, now, e.seq)
                            .with_worker(replica)
                            .with_width(width),
                    );
                    queues.requeue(bucket, e);
                }
            }
            report.replica_restarts += 1;
            emit(
                sink,
                replica + 1,
                Event::new(EventKind::ReplicaDied, now, obs::NO_SEQ)
                    .with_worker(replica),
            );
            emit(
                sink,
                replica + 1,
                Event::new(EventKind::ReplicaRestarted, now, obs::NO_SEQ)
                    .with_worker(replica),
            );
            return Rep::Idle;
        }
        // per-request panic isolation: the poisoned member fails
        // terminally, its batch-mates keep executing
        let mut survivors = Vec::with_capacity(live.len());
        for e in live {
            if plan.panic_for(e.seq) {
                report.failed_internal += 1;
                emit(
                    sink,
                    0,
                    Event::new(EventKind::Shed, now, e.seq)
                        .with_worker(replica)
                        .with_shed(ShedTag::Internal),
                );
            } else {
                survivors.push(e);
            }
        }
        live = survivors;
        if live.is_empty() {
            return Rep::Idle;
        }
    }
    if steal && stall > Duration::ZERO {
        // the replica wedges before ExecStart: post the formed batch
        // for supervision instead of silently running late. An idle
        // peer whole-steals it once it has sat a full heartbeat;
        // unstolen, the victim wakes and executes with no further
        // penalty — completing at exactly the legacy inline-stall tick.
        emit(
            sink,
            replica + 1,
            Event::new(EventKind::BatchFormed, now, obs::NO_SEQ)
                .with_worker(replica)
                .with_width(width)
                .with_m_eff(m_eff)
                .with_n(live.len()),
        );
        let batch = SimBatch {
            replica,
            bucket,
            width,
            m_eff,
            formed_at: now,
            done_at: now,
            seqs: live.iter().map(|e| e.seq).collect(),
        };
        return Rep::Stalled {
            batch,
            entries: live,
            wake: now.saturating_add(stall),
            posted: now,
        };
    }
    let done = now.saturating_add(
        stall
            + service.batch_duration_at(width, live.len(), m_eff, m_full),
    );
    // the live gateway emits BatchFormed in next_batch and ExecStart at
    // the replica's next clock read; in the simulator the two instants
    // coincide by construction
    let base = Event::new(EventKind::BatchFormed, now, obs::NO_SEQ)
        .with_worker(replica)
        .with_width(width)
        .with_m_eff(m_eff)
        .with_n(live.len());
    emit(sink, replica + 1, base);
    emit(sink, replica + 1, Event { kind: EventKind::ExecStart, ..base });
    let batch = SimBatch {
        replica,
        bucket,
        width,
        m_eff,
        formed_at: now,
        done_at: done,
        seqs: live.iter().map(|e| e.seq).collect(),
    };
    Rep::Busy { until: done, batch, entries: live }
}

/// Run `trace` through the scheduling core under `cfg`. Deterministic:
/// identical inputs produce an identical report, bit for bit.
pub fn run(cfg: &SimConfig, trace: &[Arrival]) -> SimReport {
    run_traced(cfg, trace, None)
}

/// [`run`], with flight-recorder events mirrored into `sink`: the same
/// [`Event`] schema the live gateway emits, stamped with the sim's
/// virtual [`Tick`]s (lane 0 = admission/sheds, lanes `1..=replicas` =
/// batch execution), so the reconciliation property test and the Chrome
/// exporter run unchanged against either executor. Tracing never
/// changes a scheduling decision: the report is bit-identical to the
/// untraced run.
pub fn run_traced(
    cfg: &SimConfig,
    trace: &[Arrival],
    sink: Option<&TraceSink>,
) -> SimReport {
    run_inner(cfg, trace, sink, &FaultPlan::none(), 0, &[], 0.0)
}

/// [`run`], with `plan`'s injected faults applied by the simulated
/// replicas under a per-request `retry_budget` — the deterministic twin
/// of the live gateway's supervised fault path. A fault-free plan makes
/// this identical to [`run`].
pub fn run_faulted(
    cfg: &SimConfig,
    trace: &[Arrival],
    plan: &FaultPlan,
    retry_budget: u32,
) -> SimReport {
    run_faulted_traced(cfg, trace, plan, retry_budget, None)
}

/// [`run_faulted`] with flight-recorder events mirrored into `sink`,
/// including the fault-path kinds (`Requeued`, `ReplicaDied`,
/// `ReplicaRestarted`, and `Shed`/`internal_error`).
pub fn run_faulted_traced(
    cfg: &SimConfig,
    trace: &[Arrival],
    plan: &FaultPlan,
    retry_budget: u32,
    sink: Option<&TraceSink>,
) -> SimReport {
    run_inner(cfg, trace, sink, plan, retry_budget, &[], 0.0)
}

/// [`run`], with per-arrival admission classes: `classes[i]` is the
/// class of `trace[i]` (missing entries default to `BestEffort`), and
/// `reserve` is the fraction of queue capacity held back from
/// non-`BestEffort` admissions — the sim twin of
/// `GatewayConfig::best_effort_reserve`. The per-class admit/reject
/// tallies land in `accepted_best_effort` / `rejected_best_effort`.
pub fn run_classed(
    cfg: &SimConfig,
    trace: &[Arrival],
    classes: &[Quality],
    reserve: f64,
) -> SimReport {
    run_inner(cfg, trace, None, &FaultPlan::none(), 0, classes, reserve)
}

fn quality_of(class: Quality) -> QualityTag {
    match class {
        Quality::Full => QualityTag::Full,
        Quality::Degraded(_) => QualityTag::Degraded,
        Quality::BestEffort => QualityTag::BestEffort,
    }
}

/// Width cycle for the synthetic capacity-planning traces: a
/// deterministic mix of short interactive and long analytical
/// requests, repeated round-robin so every run is reproducible.
const PLAN_WIDTHS: [usize; 8] = [4, 8, 8, 12, 16, 24, 40, 64];

/// Deterministic diurnal arrival trace: `n` requests whose
/// instantaneous arrival rate swings sinusoidally 19:1 between peak
/// and trough over each `period` "day", around a mean of one request
/// per `mean_gap`. Lengths cycle through [`PLAN_WIDTHS`]; every fourth
/// request carries `deadline`. Pure arithmetic — no RNG — so a
/// million-request day is bit-reproducible everywhere.
pub fn diurnal_trace(
    n: usize,
    mean_gap: Duration,
    period: Duration,
    deadline: Option<Duration>,
) -> Vec<Arrival> {
    let period_s = period.as_secs_f64().max(1e-9);
    let gap_s = mean_gap.as_secs_f64();
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            // rate multiplier in [0.1, 1.9] around the mean
            let phase = (t / period_s) * std::f64::consts::TAU;
            let rate = 1.0 + 0.9 * phase.sin();
            t += gap_s / rate.max(0.1);
            Arrival {
                at: Duration::from_secs_f64(t),
                len: PLAN_WIDTHS[i % PLAN_WIDTHS.len()],
                deadline: if i % 4 == 0 { deadline } else { None },
            }
        })
        .collect()
}

/// Deterministic flash-crowd trace: steady one-per-`base_gap`
/// arrivals, except a contiguous crowd of `crowd_frac` of all requests
/// lands at `crowd_mult`x the base rate, centered mid-trace. Lengths
/// and deadlines as in [`diurnal_trace`].
pub fn flash_crowd_trace(
    n: usize,
    base_gap: Duration,
    crowd_frac: f64,
    crowd_mult: f64,
    deadline: Option<Duration>,
) -> Vec<Arrival> {
    let gap_s = base_gap.as_secs_f64();
    let crowd_len = (n as f64 * crowd_frac.clamp(0.0, 1.0)) as usize;
    let crowd_start = (n - crowd_len) / 2;
    let mut t = 0.0f64;
    (0..n)
        .map(|i| {
            let in_crowd =
                i >= crowd_start && i < crowd_start + crowd_len;
            t += if in_crowd {
                gap_s / crowd_mult.max(1.0)
            } else {
                gap_s
            };
            Arrival {
                at: Duration::from_secs_f64(t),
                len: PLAN_WIDTHS[i % PLAN_WIDTHS.len()],
                deadline: if i % 4 == 0 { deadline } else { None },
            }
        })
        .collect()
}

/// One capacity-planning point: a simulated deployment size and the
/// service levels one trace achieved at it.
#[derive(Clone, Debug)]
pub struct FrontierPoint {
    pub replicas: usize,
    pub offered: u64,
    pub accepted: u64,
    pub rejected: u64,
    pub completed: u64,
    pub goodput: u64,
    pub shed_deadline: u64,
    pub mean_ms: f64,
    pub p99_ms: f64,
    pub peak_depth: usize,
    pub stolen: u64,
}

/// Sweep `replica_counts`, running `trace` under `base` (replicas
/// overridden) at each count: the replica-count vs latency/goodput
/// frontier a capacity planner reads deployment sizes off. Pure
/// simulation — a million-request day costs zero wall-clock service
/// time, so the whole sweep runs in CI (`benches/cap_frontier.rs`
/// emits it as CSV).
pub fn frontier(
    base: &SimConfig,
    trace: &[Arrival],
    replica_counts: &[usize],
) -> Vec<FrontierPoint> {
    replica_counts
        .iter()
        .map(|&n| {
            let mut cfg = base.clone();
            cfg.replicas = n.max(1);
            let r = run(&cfg, trace);
            FrontierPoint {
                replicas: cfg.replicas,
                offered: trace.len() as u64,
                accepted: r.accepted,
                rejected: r.rejected + r.rejected_infeasible,
                completed: r.completed,
                goodput: r.goodput,
                shed_deadline: r.shed_deadline,
                mean_ms: r.mean_ms(),
                p99_ms: r.p99_ms(),
                peak_depth: r.peak_depth,
                stolen: r.stolen,
            }
        })
        .collect()
}

fn run_inner(
    cfg: &SimConfig,
    trace: &[Arrival],
    sink: Option<&TraceSink>,
    plan: &FaultPlan,
    retry_budget: u32,
    classes: &[Quality],
    reserve: f64,
) -> SimReport {
    let clock = SimClock::new();
    let widths = cfg.buckets.widths().to_vec();
    let widest = *widths.last().expect("non-empty layout");
    let replicas = cfg.replicas.max(1);
    let capacity = cfg.queue_capacity.max(1);
    let m_full = cfg.m_full.max(1);
    // the live gateway's svc_ewma_ms, fed the same way (per-request
    // batch time restated at full quality, explicit warm-up)
    let mut svc_ewma_ms: Option<f64> = None;
    // the live gateway's ladder hysteresis state: advanced only at
    // batch formation (`plan_at`), peeked read-only at admission
    let mut ladder_state = LadderState::default();

    // arrivals in time order; equal ticks keep trace order, and seqs
    // are assigned in that order at admission (like the gateway's
    // under-lock seq counter)
    let mut arrivals: Vec<(Tick, usize)> = trace
        .iter()
        .enumerate()
        .map(|(i, a)| (Tick::ZERO.saturating_add(a.at), i))
        .collect();
    arrivals.sort_by_key(|&(t, i)| (t, i));

    let mut queues = SimQueues::new(cfg.shards, widths.len());
    let mut reps: Vec<Rep> = (0..replicas).map(|_| Rep::Idle).collect();
    let mut report = SimReport::default();
    let mut ai = 0usize;
    let mut next_seq = 0u64;
    let mut steps = 0usize;
    // livelock backstop, scaled so million-request capacity-planning
    // traces fit: a healthy run takes O(1) event ticks per arrival
    let step_cap = 1_000_000usize.max(trace.len().saturating_mul(8));

    loop {
        steps += 1;
        assert!(
            steps < step_cap,
            "sim failed to converge after {step_cap} events — scheduling \
             livelock?"
        );
        let now = clock.now();

        // 1. completions due now — and stalled replicas whose injected
        // stall has released (no steal arrived in time): they start
        // executing at the wake tick with no further penalty
        for r in reps.iter_mut() {
            let waking =
                matches!(r, Rep::Stalled { wake, .. } if *wake <= now);
            if waking {
                if let Rep::Stalled { mut batch, entries, .. } =
                    std::mem::replace(r, Rep::Idle)
                {
                    let done = now.saturating_add(
                        cfg.service.batch_duration_at(
                            batch.width,
                            entries.len(),
                            batch.m_eff,
                            m_full,
                        ),
                    );
                    batch.done_at = done;
                    emit(
                        sink,
                        batch.replica + 1,
                        Event::new(EventKind::ExecStart, now, obs::NO_SEQ)
                            .with_worker(batch.replica)
                            .with_width(batch.width)
                            .with_m_eff(batch.m_eff)
                            .with_n(entries.len()),
                    );
                    *r = Rep::Busy { until: done, batch, entries };
                }
            }
            let due = matches!(r, Rep::Busy { until, .. } if *until <= now);
            if due {
                if let Rep::Busy { batch, entries, .. } =
                    std::mem::replace(r, Rep::Idle)
                {
                    let m_served = batch.m_eff.clamp(1, m_full);
                    let quality = if m_served < m_full {
                        QualityTag::Degraded
                    } else {
                        QualityTag::Full
                    };
                    emit(
                        sink,
                        batch.replica + 1,
                        Event::new(
                            EventKind::ExecEnd,
                            batch.done_at,
                            obs::NO_SEQ,
                        )
                        .with_worker(batch.replica)
                        .with_width(batch.width)
                        .with_m_eff(batch.m_eff)
                        .with_n(entries.len()),
                    );
                    for e in &entries {
                        report
                            .latencies_ms
                            .push(batch.done_at.ms_since(e.enqueued));
                        // goodput: completed within deadline (or none)
                        if !matches!(e.deadline, Some(d) if batch.done_at > d)
                        {
                            report.goodput += 1;
                        }
                        emit(
                            sink,
                            batch.replica + 1,
                            Event::new(
                                EventKind::Replied,
                                batch.done_at,
                                e.seq,
                            )
                            .with_worker(batch.replica)
                            .with_width(batch.width)
                            .with_quality(quality)
                            .with_m_eff(m_served),
                        );
                    }
                    report.completed += entries.len() as u64;
                    if batch.m_eff < m_full {
                        report.served_degraded += entries.len() as u64;
                    }
                    // the gateway replica's EWMA feed: per-request
                    // batch time, restated at full quality so the
                    // estimate keeps one meaning as the ladder steps
                    let per_req = batch.done_at.ms_since(batch.formed_at)
                        / entries.len() as f64;
                    let sample = per_req * m_full as f64
                        / batch.m_eff.clamp(1, m_full) as f64;
                    svc_ewma_ms = Some(update_ewma(svc_ewma_ms, sample));
                    report.batches.push(batch);
                }
            }
        }

        // 2. admissions due now (bounded queue: at capacity -> reject;
        // non-BestEffort classes see the reserve-shrunk cap, like
        // `submit_with` under `best_effort_reserve`)
        while ai < arrivals.len() && arrivals[ai].0 <= now {
            let (at, idx) = arrivals[ai];
            ai += 1;
            let a = &trace[idx];
            let class =
                classes.get(idx).copied().unwrap_or(Quality::BestEffort);
            let best_effort = matches!(class, Quality::BestEffort);
            let cap = admission_cap(capacity, reserve, best_effort);
            let bucket = cfg.buckets.bucket_for(a.len);
            if queues.len() >= cap {
                report.rejected += 1;
                if best_effort {
                    report.rejected_best_effort += 1;
                }
                emit(
                    sink,
                    0,
                    Event::new(EventKind::Shed, at, obs::NO_SEQ)
                        .with_width(widths[bucket])
                        .with_shed(ShedTag::QueueFull),
                );
                continue;
            }
            if cfg.admission_edf {
                if let Some(d) = a.deadline {
                    // read-only peek, like the gateway's admission path:
                    // a pending hysteresis step-up quotes its held rung
                    let plan = cfg.degrade.peek_at(
                        &ladder_state,
                        queues.len(),
                        svc_ewma_ms,
                        replicas,
                        m_full,
                    );
                    if deadline_infeasible(&plan, d) {
                        report.rejected_infeasible += 1;
                        emit(
                            sink,
                            0,
                            Event::new(EventKind::Shed, at, obs::NO_SEQ)
                                .with_width(widths[bucket])
                                .with_shed(ShedTag::Infeasible),
                        );
                        continue;
                    }
                }
            }
            let seq = next_seq;
            next_seq += 1;
            report.accepted += 1;
            if best_effort {
                report.accepted_best_effort += 1;
            }
            let entry = Entry {
                seq,
                enqueued: at,
                deadline: a.deadline.map(|d| at.saturating_add(d)),
                retries: 0,
                payload: (),
            };
            queues.push(bucket, entry);
            report.peak_depth = report.peak_depth.max(queues.len());
            if sink.is_some() {
                let base = Event::new(EventKind::Admitted, at, seq)
                    .with_width(widths[bucket])
                    .with_quality(quality_of(class))
                    .with_n(a.len);
                emit(sink, 0, base);
                emit(sink, 0, Event { kind: EventKind::Queued, ..base });
            }
        }

        // 3. queue-side expiry sheds (live path: shed_expired at the
        // top of every next_batch round)
        for e in queues.shed_expired(now) {
            report.shed_deadline += 1;
            emit(
                sink,
                0,
                Event::new(EventKind::Shed, now, e.seq)
                    .with_quality(QualityTag::BestEffort)
                    .with_shed(ShedTag::Expired),
            );
        }

        // 4. dispatch to fixpoint — each pass mirrors one replica's
        // next_batch round; replica index order makes ties deterministic
        loop {
            let mut changed = false;
            for r in 0..reps.len() {
                match std::mem::replace(&mut reps[r], Rep::Idle) {
                    Rep::Idle => {
                        let Some(b) = queues.pick_bucket(cfg.sched) else {
                            // nothing queued anywhere. With stealing on,
                            // an idle replica supervises its peers
                            // instead of parking: first whole-steal a
                            // stalled replica's posted batch once it has
                            // sat a full heartbeat, else split a peer's
                            // parked partial. Lowest victim index wins —
                            // deterministic, like every other pick.
                            if cfg.steal {
                                let hb = cfg.heartbeat;
                                let stalled = (0..reps.len()).find(|&v| {
                                    v != r
                                        && matches!(
                                            &reps[v],
                                            Rep::Stalled { posted, .. }
                                                if now >= posted
                                                    .saturating_add(hb)
                                        )
                                });
                                if let Some(v) = stalled {
                                    if let Rep::Stalled {
                                        mut batch,
                                        entries,
                                        ..
                                    } = std::mem::replace(
                                        &mut reps[v],
                                        Rep::Idle,
                                    ) {
                                        // whole-steal: the batch was
                                        // already formed (and fault-
                                        // checked) on the victim — the
                                        // thief only executes it, so no
                                        // second BatchFormed and no
                                        // fault re-check
                                        report.stolen += 1;
                                        let done = now.saturating_add(
                                            cfg.service.batch_duration_at(
                                                batch.width,
                                                entries.len(),
                                                batch.m_eff,
                                                m_full,
                                            ),
                                        );
                                        batch.replica = r;
                                        batch.done_at = done;
                                        let base = Event::new(
                                            EventKind::Stolen,
                                            now,
                                            obs::NO_SEQ,
                                        )
                                        .with_worker(r)
                                        .with_width(batch.width)
                                        .with_m_eff(batch.m_eff)
                                        .with_n(entries.len());
                                        emit(sink, r + 1, base);
                                        emit(sink, r + 1, Event {
                                            kind: EventKind::ExecStart,
                                            ..base
                                        });
                                        reps[r] = Rep::Busy {
                                            until: done,
                                            batch,
                                            entries,
                                        };
                                        changed = true;
                                    }
                                    continue;
                                }
                                let parked = (0..reps.len()).find(|&v| {
                                    v != r
                                        && matches!(
                                            &reps[v],
                                            Rep::Waiting { batch, .. }
                                                if batch.len() >= 2
                                        )
                                });
                                if let Some(v) = parked {
                                    if let Rep::Waiting {
                                        bucket,
                                        mut batch,
                                        ..
                                    } = std::mem::replace(
                                        &mut reps[v],
                                        Rep::Idle,
                                    ) {
                                        report.stolen += 1;
                                        // the victim keeps the older
                                        // (front) half — every stolen
                                        // seq comes after every kept
                                        // seq in dequeue order, so
                                        // stealing never reorders
                                        // within the bucket. Both
                                        // halves ship now (the steal
                                        // exists to stop work parking
                                        // while a replica idles):
                                        // victim first, thief second,
                                        // each advancing the ladder at
                                        // its own dispatch like any two
                                        // back-to-back batches. The
                                        // tail's first execution is on
                                        // the thief, so injected faults
                                        // apply there as usual.
                                        let keep = (batch.len() + 1) / 2;
                                        let tail = batch.split_off(keep);
                                        emit(
                                            sink,
                                            r + 1,
                                            Event::new(
                                                EventKind::Stolen,
                                                now,
                                                obs::NO_SEQ,
                                            )
                                            .with_worker(r)
                                            .with_width(widths[bucket])
                                            .with_n(tail.len()),
                                        );
                                        let m_eff = cfg
                                            .degrade
                                            .plan_at(
                                                &mut ladder_state,
                                                now,
                                                queues.len(),
                                                svc_ewma_ms,
                                                replicas,
                                                m_full,
                                            )
                                            .m_eff;
                                        reps[v] = dispatch(
                                            v,
                                            bucket,
                                            batch,
                                            now,
                                            &cfg.service,
                                            widths[bucket],
                                            m_eff,
                                            m_full,
                                            &mut queues,
                                            plan,
                                            retry_budget,
                                            cfg.steal,
                                            &mut report,
                                            sink,
                                        );
                                        let m_eff = cfg
                                            .degrade
                                            .plan_at(
                                                &mut ladder_state,
                                                now,
                                                queues.len(),
                                                svc_ewma_ms,
                                                replicas,
                                                m_full,
                                            )
                                            .m_eff;
                                        reps[r] = dispatch(
                                            r,
                                            bucket,
                                            tail,
                                            now,
                                            &cfg.service,
                                            widths[bucket],
                                            m_eff,
                                            m_full,
                                            &mut queues,
                                            plan,
                                            retry_budget,
                                            cfg.steal,
                                            &mut report,
                                            sink,
                                        );
                                        changed = true;
                                    }
                                    continue;
                                }
                            }
                            continue;
                        };
                        let policy = cfg.batch.policy_for(widths[b], widest);
                        let mut batch = Vec::new();
                        top_up(
                            &mut queues,
                            b,
                            cfg.sched,
                            &mut batch,
                            policy.max_batch,
                        );
                        let age_deadline = batch[0]
                            .enqueued
                            .saturating_add(policy.max_wait)
                            .max(now);
                        let ship = should_ship(
                            &batch,
                            policy.max_batch,
                            age_deadline,
                            now,
                            cfg.sched,
                            &queues,
                        );
                        reps[r] = if ship {
                            // next_batch's decision point: the rung is
                            // picked off the backlog the batch leaves
                            // behind it (post-pop queue depth), and this
                            // is the one site that advances the ladder's
                            // hysteresis state — exactly like the live
                            // gateway
                            let m_eff = cfg
                                .degrade
                                .plan_at(
                                    &mut ladder_state,
                                    now,
                                    queues.len(),
                                    svc_ewma_ms,
                                    replicas,
                                    m_full,
                                )
                                .m_eff;
                            dispatch(
                                r,
                                b,
                                batch,
                                now,
                                &cfg.service,
                                widths[b],
                                m_eff,
                                m_full,
                                &mut queues,
                                plan,
                                retry_budget,
                                cfg.steal,
                                &mut report,
                                sink,
                            )
                        } else {
                            Rep::Waiting {
                                bucket: b,
                                batch,
                                max_batch: policy.max_batch,
                                age_deadline,
                            }
                        };
                        changed = true;
                    }
                    Rep::Waiting { bucket, mut batch, max_batch, age_deadline } => {
                        let before = batch.len();
                        top_up(
                            &mut queues,
                            bucket,
                            cfg.sched,
                            &mut batch,
                            max_batch,
                        );
                        let ship = should_ship(
                            &batch,
                            max_batch,
                            age_deadline,
                            now,
                            cfg.sched,
                            &queues,
                        );
                        if ship {
                            let m_eff = cfg
                                .degrade
                                .plan_at(
                                    &mut ladder_state,
                                    now,
                                    queues.len(),
                                    svc_ewma_ms,
                                    replicas,
                                    m_full,
                                )
                                .m_eff;
                            reps[r] = dispatch(
                                r,
                                bucket,
                                batch,
                                now,
                                &cfg.service,
                                widths[bucket],
                                m_eff,
                                m_full,
                                &mut queues,
                                plan,
                                retry_budget,
                                cfg.steal,
                                &mut report,
                                sink,
                            );
                            changed = true;
                        } else {
                            if batch.len() != before {
                                changed = true;
                            }
                            reps[r] = Rep::Waiting {
                                bucket,
                                batch,
                                max_batch,
                                age_deadline,
                            };
                        }
                    }
                    busy => reps[r] = busy,
                }
            }
            if !changed {
                break;
            }
        }

        // 5. work-conservation audit: after the fixpoint, a non-busy
        // replica alongside live queued work is a conservation breach
        // (the queues were expiry-swept at this tick, so "work" is
        // live). A stalled replica is wedged, not idle-by-choice — it
        // cannot take work, so it does not count against conservation.
        if !queues.is_empty()
            && reps.iter().any(|r| {
                !matches!(r, Rep::Busy { .. } | Rep::Stalled { .. })
            })
        {
            report.conservation_violations.push(now);
        }

        // 6. advance to the next event (arrival, completion, or aging
        // deadline); none left -> the trace is fully drained
        let mut next: Option<Tick> = None;
        if ai < arrivals.len() {
            next = Some(arrivals[ai].0);
        }
        for r in &reps {
            let t = match r {
                Rep::Busy { until, .. } => Some(*until),
                Rep::Waiting { age_deadline, .. } => Some(*age_deadline),
                // a stalled replica wakes at `wake`; the heartbeat
                // expiry is also an event — that is the tick an idle
                // peer becomes entitled to whole-steal the batch
                Rep::Stalled { wake, posted, .. } => {
                    let hb = posted.saturating_add(cfg.heartbeat);
                    Some(if hb > now { (*wake).min(hb) } else { *wake })
                }
                Rep::Idle => None,
            };
            if let Some(t) = t {
                next = Some(match next {
                    Some(n) => n.min(t),
                    None => t,
                });
            }
        }
        match next {
            Some(t) => clock.advance_to(t),
            None => break,
        }
    }
    debug_assert!(queues.is_empty(), "sim ended with queued work");
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::batcher::BatchPolicy;

    fn cfg(sched: SchedPolicy) -> SimConfig {
        SimConfig {
            replicas: 1,
            queue_capacity: 64,
            sched,
            buckets: BucketLayout::single(8),
            batch: BatchPolicyTable::uniform(BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(10),
            }),
            service: ServiceModel {
                batch_overhead: Duration::from_millis(1),
                per_width: Duration::from_micros(125), // 1 ms per width-8 request
            },
            degrade: DegradeLadder::none(),
            m_full: 32,
            ..SimConfig::default()
        }
    }

    fn arr(at_ms: u64, len: usize) -> Arrival {
        Arrival { at: Duration::from_millis(at_ms), len, deadline: None }
    }

    #[test]
    fn full_batch_ships_instantly_with_exact_timing() {
        // two arrivals at t=0 fill max_batch=2: the batch forms at t=0
        // and completes at overhead + 2 x 1 ms = 3 ms, exactly
        let report = run(&cfg(SchedPolicy::Conserve), &[arr(0, 4), arr(0, 8)]);
        assert_eq!(report.accepted, 2);
        assert_eq!(report.completed, 2);
        assert!(report.reconciles());
        assert_eq!(report.batches.len(), 1);
        let b = &report.batches[0];
        assert_eq!(b.formed_at, Tick::ZERO);
        assert_eq!(b.done_at, Tick::from_ms(3));
        assert_eq!(b.seqs, vec![0, 1]);
        // exact virtual latency, computed through the same Tick math
        let lat = Tick::from_ms(3).ms_since(Tick::ZERO);
        assert_eq!(report.latencies_ms, vec![lat, lat]);
        assert!(report.conservation_violations.is_empty());
    }

    #[test]
    fn lone_partial_batch_ages_exactly_max_wait() {
        // a single arrival with an otherwise-empty queue waits the full
        // aging budget (work conservation is vacuous — no other work),
        // then ships alone: formed at exactly t=10ms
        for sched in [SchedPolicy::Fifo, SchedPolicy::Conserve] {
            let report = run(&cfg(sched), &[arr(0, 4)]);
            assert_eq!(report.batches.len(), 1, "{sched:?}");
            assert_eq!(report.batches[0].formed_at, Tick::from_ms(10));
            assert_eq!(report.batches[0].done_at, Tick::from_ms(12));
            assert!(report.conservation_violations.is_empty(), "{sched:?}");
        }
    }

    #[test]
    fn late_arrival_tops_up_a_waiting_batch() {
        // second arrival lands mid-aging-wait: it must join the parked
        // batch (the live condvar wake + re-drain), shipping at its
        // arrival tick, not at the aging deadline
        let report = run(&cfg(SchedPolicy::Conserve), &[arr(0, 4), arr(4, 4)]);
        assert_eq!(report.batches.len(), 1);
        let b = &report.batches[0];
        assert_eq!(b.formed_at, Tick::from_ms(4));
        assert_eq!(b.seqs, vec![0, 1]);
        assert!(report.reconciles());
    }

    #[test]
    fn capacity_overflow_rejects_exactly() {
        let mut c = cfg(SchedPolicy::Conserve);
        c.queue_capacity = 2;
        // long service keeps the replica busy from t=0; three arrivals
        // at t=1 hit a capacity-2 queue: third rejects
        c.service.batch_overhead = Duration::from_millis(100);
        let report = run(
            &c,
            &[arr(0, 8), arr(0, 8), arr(1, 4), arr(1, 4), arr(1, 4)],
        );
        assert_eq!(report.accepted, 4);
        assert_eq!(report.rejected, 1);
        assert_eq!(report.completed, 4);
        assert!(report.reconciles());
    }

    #[test]
    fn deadline_member_cuts_the_aging_park_short() {
        // a deadline-bearing request absorbed into a parked partial
        // batch must not age into a shed: under Conserve the park is
        // capped and the batch ships the moment such a member joins;
        // Fifo (the verbatim PR-3 baseline) still parks the full aging
        // budget and sheds it — which is exactly the A/B point
        let trace = vec![
            arr(0, 4),
            Arrival {
                at: Duration::from_millis(1),
                len: 4,
                deadline: Some(Duration::from_millis(5)),
            },
        ];
        let mut c = cfg(SchedPolicy::Conserve);
        // cap 3 so two members still leave the batch partial
        c.batch = BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::from_millis(10),
        });
        let report = run(&c, &trace);
        assert_eq!(report.completed, 2);
        assert_eq!(report.shed_deadline, 0);
        assert_eq!(report.batches.len(), 1);
        // shipped the instant the deadline-bearing member joined, well
        // inside its 6 ms absolute deadline
        assert_eq!(report.batches[0].formed_at, Tick::from_ms(1));

        let mut f = cfg(SchedPolicy::Fifo);
        f.batch = c.batch.clone();
        let fifo = run(&f, &trace);
        assert_eq!(fifo.shed_deadline, 1);
        assert_eq!(fifo.completed, 1);
        assert!(fifo.reconciles());
    }

    #[test]
    fn admission_edf_rejects_warm_infeasible_arrivals_exactly() {
        // width-8, 4 ms/request full quality, no overhead; no ladder
        let mut c = cfg(SchedPolicy::Conserve);
        c.admission_edf = true;
        c.m_full = 8;
        c.batch = BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
        });
        c.service = ServiceModel {
            batch_overhead: Duration::ZERO,
            per_width: Duration::from_micros(500),
        };
        let mut trace = vec![arr(0, 8)]; // warms the EWMA to 4 ms
        for _ in 0..3 {
            trace.push(Arrival {
                at: Duration::from_millis(4),
                len: 8,
                deadline: Some(Duration::from_millis(2)),
            });
        }
        let report = run(&c, &trace);
        // at t=4 the EWMA is warm (the t=0 request completed at t=4,
        // completions land before admissions at the same tick). Burst
        // admission: the first sees an empty queue (backlog 0 ms,
        // feasible), the second and third see 1 queued x 4 ms = 4 ms >
        // 2 ms — infeasible, rejected at the door
        assert_eq!(report.rejected_infeasible, 2);
        assert_eq!(report.accepted, 2);
        assert_eq!(report.completed, 2);
        assert_eq!(report.rejected, 0);
        assert!(report.reconciles());
        // the admitted burst request runs 4..8 ms against an absolute
        // deadline of 6 ms: completed late, so it is not goodput — only
        // the deadline-free warm-up counts
        assert_eq!(report.goodput, 1);
        // cold estimates never EDF-reject: the same burst with no
        // warm-up admits everything
        let cold = run(&c, &trace[1..].to_vec());
        assert_eq!(cold.rejected_infeasible, 0);
        assert_eq!(cold.accepted, 3);
    }

    #[test]
    fn disabled_ladder_reports_full_quality_everywhere() {
        let report = run(&cfg(SchedPolicy::Conserve), &[arr(0, 4), arr(0, 8)]);
        assert!(report.batches.iter().all(|b| b.m_eff == 32));
        assert_eq!(report.served_degraded, 0);
        // deadline-free completions all count as goodput
        assert_eq!(report.goodput, report.completed);
    }

    #[test]
    fn stealing_splits_a_parked_partial_and_preserves_order() {
        // three same-bucket arrivals at t=0, two replicas, max_batch 4:
        // replica 0 drains all three into a partial and parks on the
        // 10 ms aging wait; replica 1 finds nothing queued. With
        // stealing on it splits the park instead of idling — the
        // victim keeps the older front half [0, 1], the thief takes
        // the tail [2], and both ship at t=0. Exact timings: thief
        // 1 + 1 = 2 ms, victim 1 + 2 = 3 ms.
        let mut c = cfg(SchedPolicy::Conserve);
        c.replicas = 2;
        c.batch = BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(10),
        });
        c.steal = true;
        let trace = [arr(0, 4), arr(0, 4), arr(0, 4)];
        let report = run(&c, &trace);
        assert_eq!(report.stolen, 1);
        assert_eq!(report.completed, 3);
        assert_eq!(report.goodput, 3);
        assert!(report.reconciles());
        assert_eq!(report.batches.len(), 2);
        // batches land in completion order: the stolen tail first
        let thief = &report.batches[0];
        assert_eq!(thief.replica, 1);
        assert_eq!(thief.seqs, vec![2]);
        assert_eq!(thief.formed_at, Tick::ZERO);
        assert_eq!(thief.done_at, Tick::from_ms(2));
        let victim = &report.batches[1];
        assert_eq!(victim.replica, 0);
        assert_eq!(victim.seqs, vec![0, 1], "victim keeps the front half");
        assert_eq!(victim.done_at, Tick::from_ms(3));
        assert_eq!(report.latencies_ms, vec![2.0, 3.0, 3.0]);

        // the no-steal baseline parks the full aging wait instead
        c.steal = false;
        let parked = run(&c, &trace);
        assert_eq!(parked.stolen, 0);
        assert_eq!(parked.batches.len(), 1);
        assert_eq!(parked.batches[0].formed_at, Tick::from_ms(10));
        assert!(
            report.mean_ms() < parked.mean_ms(),
            "stealing must beat parking on a drained-early peer: {} vs {}",
            report.mean_ms(),
            parked.mean_ms()
        );
    }

    #[test]
    fn stalled_batch_is_whole_stolen_within_the_heartbeat() {
        // one request, two replicas, a 20 ms injected stall on seq 0.
        // With stealing on, the stalled replica posts its formed batch;
        // the idle peer whole-steals it at exactly posted + heartbeat
        // (2 ms) and completes at 2 + 2 = 4 ms — instead of the legacy
        // 20 + 2 = 22 ms wedge.
        use crate::serve::fault::FaultKind;
        let mut c = cfg(SchedPolicy::Conserve);
        c.replicas = 2;
        c.batch = BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 1,
            max_wait: Duration::ZERO,
        });
        c.steal = true;
        c.heartbeat = Duration::from_millis(2);
        let plan = FaultPlan::from_faults(vec![FaultKind::StallOnSeq {
            seq: 0,
            ns: 20_000_000,
        }]);
        let trace = [arr(0, 4)];
        let report = run_faulted(&c, &trace, &plan, 0);
        assert_eq!(report.stolen, 1, "supervision must trip on the stall");
        assert_eq!(report.completed, 1);
        assert_eq!(report.failed_internal, 0);
        assert_eq!(report.requeued, 0);
        assert!(report.reconciles());
        let b = &report.batches[0];
        assert_eq!(b.replica, 1, "the thief executes the stolen batch");
        assert_eq!(b.seqs, vec![0]);
        assert_eq!(b.formed_at, Tick::ZERO);
        // stolen at the heartbeat bound, not a tick later
        assert_eq!(b.done_at, Tick::from_ms(4));

        // the no-steal baseline rides out the whole stall
        c.steal = false;
        let wedged = run_faulted(&c, &trace, &plan, 0);
        assert_eq!(wedged.stolen, 0);
        assert_eq!(wedged.batches[0].done_at, Tick::from_ms(22));
        assert_eq!(wedged.batches[0].replica, 0);
    }

    #[test]
    fn innocent_batch_mates_survive_a_neighbors_crash_loop() {
        // the retry-budget semantics fix, exactly: only the member that
        // *is* the kill trigger spends budget. Batch [0, 1] with a
        // sticky kill on seq 1 at budget 0: seq 1 fails terminally on
        // the first pick, seq 0 requeues once and completes — under
        // the old rule (every member budget-checked) seq 0 would have
        // been doomed alongside its neighbor.
        use crate::serve::fault::FaultKind;
        let c = cfg(SchedPolicy::Conserve);
        let plan = FaultPlan::from_faults(vec![
            FaultKind::KillReplicaOnSeq(1),
        ]);
        let trace = [arr(0, 4), arr(0, 4)];
        let report = run_faulted(&c, &trace, &plan, 0);
        assert_eq!(report.accepted, 2);
        assert_eq!(
            report.completed, 1,
            "the innocent batch-mate must survive the neighbor's kill"
        );
        assert_eq!(report.failed_internal, 1);
        assert_eq!(report.requeued, 1);
        assert_eq!(report.replica_restarts, 1);
        assert!(report.reconciles());
        // the survivor re-parks alone and ships at its aging deadline
        assert_eq!(report.batches.len(), 1);
        assert_eq!(report.batches[0].seqs, vec![0]);
        assert_eq!(report.batches[0].formed_at, Tick::from_ms(10));

        // budget 2: the cursed seq burns 0, 1, 2 across three picks
        // (the innocent requeues all three times), then the clean batch
        // executes
        let report = run_faulted(&c, &trace, &plan, 2);
        assert_eq!(report.completed, 1);
        assert_eq!(report.failed_internal, 1);
        assert_eq!(report.requeued, 5);
        assert_eq!(report.replica_restarts, 3);
        assert!(report.reconciles());
    }

    #[test]
    fn capacity_frontier_sweeps_replicas_on_a_flash_crowd() {
        // a 2000-request flash crowd that overloads one replica (mean
        // service ~2.7 ms vs a 0.2 ms crowd gap) but not sixteen: the
        // frontier must show goodput rising and p99 falling with
        // replica count — the curve a capacity planner reads off.
        let base = SimConfig {
            queue_capacity: 64,
            sched: SchedPolicy::Conserve,
            buckets: BucketLayout::pow2(8, 64),
            batch: BatchPolicyTable::uniform(BatchPolicy {
                max_batch: 1,
                max_wait: Duration::ZERO,
            }),
            service: ServiceModel {
                batch_overhead: Duration::ZERO,
                per_width: Duration::from_micros(100),
            },
            ..SimConfig::default()
        };
        let trace = flash_crowd_trace(
            2000,
            Duration::from_millis(2),
            0.3,
            10.0,
            Some(Duration::from_millis(50)),
        );
        let counts = [1usize, 2, 4, 8, 16];
        let pts = frontier(&base, &trace, &counts);
        assert_eq!(pts.len(), counts.len());
        for (p, &n) in pts.iter().zip(&counts) {
            assert_eq!(p.replicas, n);
            assert_eq!(p.offered, 2000);
            assert_eq!(p.accepted + p.rejected, p.offered);
            assert!(p.goodput <= p.completed);
            assert!(p.completed <= p.accepted);
        }
        let first = pts.first().unwrap();
        let last = pts.last().unwrap();
        assert!(
            first.rejected > 0,
            "one replica must overflow the queue during the crowd"
        );
        assert!(
            last.goodput > first.goodput,
            "more replicas must raise goodput on an overload trace: \
             {} at {} replicas vs {} at {}",
            last.goodput,
            last.replicas,
            first.goodput,
            first.replicas
        );
        assert!(
            last.p99_ms <= first.p99_ms,
            "more replicas must not worsen p99: {} vs {}",
            last.p99_ms,
            first.p99_ms
        );
    }

    #[test]
    fn planning_traces_are_deterministic_and_time_ordered() {
        let d = diurnal_trace(
            1000,
            Duration::from_millis(1),
            Duration::from_millis(200),
            Some(Duration::from_millis(30)),
        );
        let f = flash_crowd_trace(
            1000,
            Duration::from_millis(1),
            0.2,
            8.0,
            Some(Duration::from_millis(30)),
        );
        for trace in [&d, &f] {
            assert_eq!(trace.len(), 1000);
            for w in trace.windows(2) {
                assert!(w[0].at <= w[1].at, "arrivals must be time-ordered");
            }
            let deadlines =
                trace.iter().filter(|a| a.deadline.is_some()).count();
            assert_eq!(deadlines, 250, "every fourth request is deadlined");
        }
        // bit-reproducible: the same parameters yield the same trace
        let d2 = diurnal_trace(
            1000,
            Duration::from_millis(1),
            Duration::from_millis(200),
            Some(Duration::from_millis(30)),
        );
        for (a, b) in d.iter().zip(&d2) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.len, b.len);
            assert_eq!(a.deadline, b.deadline);
        }
    }

    #[test]
    fn identical_runs_are_bit_identical() {
        let trace: Vec<Arrival> = (0..20)
            .map(|i| Arrival {
                at: Duration::from_millis(i * 3 % 17),
                len: 1 + (i as usize * 5) % 8,
                deadline: (i % 4 == 0).then(|| Duration::from_millis(30)),
            })
            .collect();
        let c = cfg(SchedPolicy::Conserve);
        assert_eq!(run(&c, &trace), run(&c, &trace));
    }
}
