//! The gateway's scheduling core: cross-bucket pick, within-bucket
//! dequeue order, per-bucket batch policies, and deadline sheds — as
//! plain data-structure decisions over a payload-generic queue set.
//!
//! Both consumers run **exactly this code**: the live
//! [`gateway`](super::gateway) replicas (payload = request bytes + reply
//! channel) and the deterministic [`sim`](super::sim) harness (payload =
//! nothing), so every scheduling property the simulator proves on a
//! virtual clock is a property of the production dequeue path, not of a
//! model of it.
//!
//! # Policies
//!
//! [`SchedPolicy::Fifo`] is the PR-3 scheduler, kept verbatim as the A/B
//! baseline: pick the bucket whose head request arrived first, serve
//! each bucket in arrival order, and always age a below-max batch up to
//! its `max_wait`. Its failure mode under skew is an idle replica parked
//! on a sparse foreign bucket's aging wait while a deep bucket backs up.
//!
//! [`SchedPolicy::Conserve`] is the work-conserving deadline-aware
//! scheduler. The bucket pick is deadline-first **across** buckets:
//! while any queued entry carries a deadline, an idle replica serves
//! the bucket holding the globally most urgent one (a deep bucket must
//! never starve another bucket's deadline); with no deadlines queued it
//! drains the **deepest** bucket (ties toward the oldest head). Within
//! a bucket, dequeue is **deadline-earliest-first** (deadline-free
//! requests rank last, arrival seq breaks ties — a total, deterministic
//! order); and a partial batch **never parks while any bucket still
//! holds work** — it ships immediately and the replica comes back. The
//! invariant the sim suite asserts: no replica idles while any bucket is
//! non-empty. The EDF-inherent tradeoff is documented, not hidden:
//! sustained deadline traffic preempts deadline-free backlogs (which
//! the bounded queue's shed/backpressure policies keep finite).
//!
//! # Per-bucket batch policies
//!
//! A [`BatchPolicyTable`] keys batch shape off the bucket's width:
//! narrow buckets batch wider and wait shorter (their requests are
//! cheap, so a big batch is still fast and latency budget is better
//! spent elsewhere), wide buckets keep the base policy. Exact-width
//! overrides take precedence; `scaled` mode derives the rest.
//!
//! # The degradation ladder
//!
//! YOSO has an overload knob nothing else in the attention zoo has: the
//! hash-round count `m` trades approximation error for latency linearly,
//! **per readout**, with no retraining and no session rebuild (the
//! m'-prefix contract in `attention::stream`). A [`DegradeLadder`] maps
//! the EWMA backlog estimate (the same one powering retry hints) to a
//! reduced effective `m'`: under pressure the gateway serves
//! best-effort requests at `m' ∈ {16, 8}` *before* resorting to
//! deadline sheds — shed compute, not users. The ladder also drives
//! **admission-time EDF** ([`deadline_infeasible`]): a request whose
//! relative deadline is already below the estimated (degraded-rate)
//! drain time of the queue ahead of it is rejected at admission instead
//! of queuing to die. Both the live gateway and the simulator plan off
//! this exact code, so the ladder is sim-proven the way `Conserve` was
//! (`tests/sim_gateway.rs`). An optional **step-up lag**
//! ([`DegradeLadder::with_step_up_lag`], state in [`LadderState`])
//! damps rung flapping under oscillating backlog: step-downs stay
//! immediate, step-ups wait out the lag.
//!
//! # Sharded lanes
//!
//! Each bucket's queue is a [`Lane`]: entries seq-keyed in a B-tree
//! (admission and supervised requeue are the same O(log n) insert)
//! with a lazily-pruned per-lane deadline min-heap (O(log n) EDF pops,
//! O(buckets) cross-bucket urgency scans). [`BucketQueues`] keeps all
//! lanes under the caller's one lock domain — the simulator's default;
//! [`ShardedQueues`] gives each lane its own mutex plus atomic
//! aggregate gauges so live admission only contends on its own bucket.
//! Both run the same decision procedures, and the sim sweeps the
//! [`Sharding`] knob to prove the schedules bit-identical.

use super::batcher::BatchPolicy;
use super::clock::Tick;
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Cross-bucket scheduling policy. Dequeue *within* a bucket and the
/// aging rule follow the same choice (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Globally-FIFO by arrival seq (the PR-3 scheduler, A/B baseline):
    /// oldest head wins the bucket pick, arrival order within a bucket,
    /// partial batches always age up to `max_wait`.
    Fifo,
    /// Work-conserving deadline-aware: the bucket holding the globally
    /// most urgent deadline wins the pick while any deadline is queued,
    /// otherwise the deepest bucket (ties: oldest head, then lowest
    /// index); deadline-earliest-first within a bucket; and a partial
    /// batch ships immediately whenever any bucket still holds work.
    Conserve,
}

impl SchedPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            SchedPolicy::Fifo => "fifo",
            SchedPolicy::Conserve => "conserve",
        }
    }
}

/// Per-bucket batch policy table, keyed by `BucketLayout` width.
///
/// Resolution order for a bucket of width `w` in a layout whose widest
/// bucket is `widest`:
/// 1. an exact-width override, if one was registered;
/// 2. in `scaled` mode, the base policy scaled by how much narrower
///    than `widest` the bucket is: each halving of width doubles
///    `max_batch` and halves `max_wait`, capped at 8x;
/// 3. otherwise the base policy unchanged (`uniform`).
#[derive(Clone, Debug)]
pub struct BatchPolicyTable {
    base: BatchPolicy,
    overrides: Vec<(usize, BatchPolicy)>,
    width_scaled: bool,
}

impl BatchPolicyTable {
    /// Every bucket gets `base` — the PR-3 single-policy behavior.
    pub fn uniform(base: BatchPolicy) -> BatchPolicyTable {
        BatchPolicyTable { base, overrides: Vec::new(), width_scaled: false }
    }

    /// Width-scaled: the widest bucket gets `base`; narrower buckets
    /// batch wider and wait shorter (see struct docs).
    pub fn scaled(base: BatchPolicy) -> BatchPolicyTable {
        BatchPolicyTable { base, overrides: Vec::new(), width_scaled: true }
    }

    /// Pin an exact policy for the bucket of width `width` (replaces a
    /// previous override for the same width).
    pub fn with_override(
        mut self,
        width: usize,
        policy: BatchPolicy,
    ) -> BatchPolicyTable {
        self.overrides.retain(|(w, _)| *w != width);
        self.overrides.push((width, policy));
        self
    }

    /// The policy for a bucket of `width` in a layout whose widest
    /// bucket is `widest`. `max_batch` is clamped to >= 1 — the live
    /// gateway always ships at least the request it popped, and the
    /// simulator must agree with it rather than wedge on a zero cap.
    pub fn policy_for(&self, width: usize, widest: usize) -> BatchPolicy {
        if let Some((_, p)) = self.overrides.iter().find(|(w, _)| *w == width) {
            return normalize(*p);
        }
        if !self.width_scaled {
            return normalize(self.base);
        }
        let mut halvings = 0u32;
        let mut w = width.max(1);
        while w < widest && halvings < 3 {
            w = w.saturating_mul(2);
            halvings += 1;
        }
        // The loop above caps `halvings` at 3 (the documented 8x), but a
        // shift must never be able to panic in debug builds (or wrap in
        // release) if that cap is ever raised: clamp both shifts below
        // the operand width instead of trusting the loop bound.
        let batch_shift = halvings.min(usize::BITS - 1);
        let wait_shift = halvings.min(u32::BITS - 1);
        BatchPolicy {
            max_batch: self
                .base
                .max_batch
                .saturating_mul(1usize << batch_shift)
                .max(1),
            max_wait: self.base.max_wait / (1u32 << wait_shift),
        }
    }
}

/// A batch policy as the dequeue paths may assume it: `max_batch == 0`
/// degrades to 1 (a picked request always ships).
fn normalize(p: BatchPolicy) -> BatchPolicy {
    BatchPolicy { max_batch: p.max_batch.max(1), max_wait: p.max_wait }
}

impl Default for BatchPolicyTable {
    fn default() -> Self {
        BatchPolicyTable::scaled(BatchPolicy::default())
    }
}

impl From<BatchPolicy> for BatchPolicyTable {
    fn from(base: BatchPolicy) -> Self {
        BatchPolicyTable::uniform(base)
    }
}

/// One EWMA step over per-request service-time samples (ms): the warm-up
/// is explicit — the first sample *becomes* the estimate rather than
/// being averaged against a fake prior. Samples are recorded at
/// full-quality scale (a batch served at `m'` scales its sample by
/// `m/m'` before recording), so the estimate stays comparable as the
/// ladder steps up and down.
pub fn update_ewma(prev: Option<f64>, sample_ms: f64) -> f64 {
    match prev {
        None => sample_ms,
        Some(p) => 0.8 * p + 0.2 * sample_ms,
    }
}

/// Estimated time (ms, unfloored) to drain `queued` requests at the
/// full-quality EWMA service rate across `replicas` — the raw backlog
/// pressure signal. A cold estimate (no completed batch yet) assumes
/// 1 ms/request rather than guessing from nothing.
pub fn backlog_estimate_ms(
    queued: usize,
    svc_ewma_ms: Option<f64>,
    replicas: usize,
) -> f64 {
    let per_req = match svc_ewma_ms {
        Some(ms) if ms >= 0.0 => ms,
        _ => 1.0,
    };
    queued as f64 * per_req / replicas.max(1) as f64
}

/// The retry hint a full-quality rejection carries: ceil of the backlog
/// estimate, floored at 1 ms. When a [`DegradeLadder`] is active the
/// gateway hints off [`DegradePlan::hint_ms`] instead, which reflects
/// the *degraded* service rate.
pub fn retry_hint_ms(
    queued: usize,
    svc_ewma_ms: Option<f64>,
    replicas: usize,
) -> u64 {
    hint_from_backlog(backlog_estimate_ms(queued, svc_ewma_ms, replicas))
}

fn hint_from_backlog(backlog_ms: f64) -> u64 {
    backlog_ms.ceil().max(1.0) as u64
}

/// The overload controller's decision for one scheduling moment: the
/// effective hash rounds to serve best-effort work at, and the backlog
/// drain estimate *at that degraded rate* (service time scales linearly
/// with `m`, so stepping down to `m'` divides the drain time by
/// `m / m'`). Produced by [`DegradeLadder::plan`]; consumed by retry
/// hints, admission EDF, and the batch-formation quality pick.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DegradePlan {
    /// hash rounds best-effort requests are served at right now
    pub m_eff: usize,
    /// the full-quality round count the sessions absorb at
    pub m_full: usize,
    /// estimated queue drain time at the degraded rate (ms)
    pub backlog_ms: f64,
    /// whether the EWMA behind the estimate has seen a real sample
    pub warm: bool,
}

impl DegradePlan {
    /// Retry hint off the *degraded* service rate (satellite contract:
    /// a rejection under a half-stepped ladder must not quote the
    /// Full-quality drain time). Ceil, floored at 1 ms.
    pub fn hint_ms(&self) -> u64 {
        hint_from_backlog(self.backlog_ms)
    }

    /// Is this plan serving below full quality?
    pub fn degraded(&self) -> bool {
        self.m_eff < self.m_full
    }
}

/// Admission-time EDF feasibility: with a *warm* backlog estimate, a
/// request whose relative deadline is below the estimated degraded-rate
/// drain time of the work already queued ahead of it cannot start
/// before it expires — reject it at admission (with the degraded retry
/// hint) instead of queuing it to die as a deadline shed. Cold
/// estimates never reject: one guess must not turn away real traffic.
pub fn deadline_infeasible(plan: &DegradePlan, deadline: Duration) -> bool {
    plan.warm && plan.backlog_ms > deadline.as_secs_f64() * 1e3
}

/// The graceful-degradation ladder: backlog-pressure thresholds (ms of
/// estimated full-quality drain time) mapped to reduced hash-round
/// counts. Empty = disabled (every request serves at full quality, the
/// pre-ladder behavior). See the module docs for the policy rationale
/// and `attention::stream` for why a reduced readout is exact.
///
/// # Step-up hysteresis
///
/// A purely backlog-keyed rung flaps under oscillating load: each
/// served batch drains the queue below the threshold, the next decision
/// steps back up to full quality, the queue refills, and consecutive
/// batches alternate `m'` values. [`DegradeLadder::with_step_up_lag`]
/// adds the damping: stepping **down** (more degraded — protecting
/// latency) stays immediate, but stepping **up** (toward full quality)
/// only happens after the raw backlog target has stayed above the held
/// rung for the whole lag. The state lives in a caller-owned
/// [`LadderState`] evolved by [`DegradeLadder::plan_at`] at **batch
/// formation only**; admission-time consumers (retry hints, EDF) read
/// the held rung through [`DegradeLadder::peek_at`] without evolving
/// it, so live gateway and sim state machines stay bit-identical. The
/// default lag is zero, which is exactly the stateless
/// [`DegradeLadder::plan`] behavior.
#[derive(Clone, Debug, Default)]
pub struct DegradeLadder {
    /// (threshold ms, m') sorted ascending by threshold; the highest
    /// threshold at or below the current backlog estimate wins
    rungs: Vec<(u64, usize)>,
    /// how long the raw target must stay above the held rung before a
    /// step up is taken; zero = no hysteresis (legacy behavior)
    step_up_lag: Duration,
}

/// Hysteresis state for one controller instance (the live gateway keeps
/// it in `GwState`; the sim keeps a local). Mutated only by
/// [`DegradeLadder::plan_at`].
#[derive(Clone, Copy, Debug, Default)]
pub struct LadderState {
    /// the rung currently being served, once a decision has been made
    cur_m: Option<usize>,
    /// when the raw target first rose above `cur_m` (the step-up timer)
    up_since: Option<Tick>,
}

impl LadderState {
    /// The held rung, if any batch-formation decision has been made.
    pub fn current_m(&self) -> Option<usize> {
        self.cur_m
    }
}

impl DegradeLadder {
    /// Disabled: always serve at full quality.
    pub fn none() -> DegradeLadder {
        DegradeLadder::default()
    }

    /// A ladder from explicit `(backlog_ms threshold, m')` rungs.
    /// Rungs are sorted by threshold; `m' == 0` rungs are dropped.
    pub fn steps(mut rungs: Vec<(u64, usize)>) -> DegradeLadder {
        rungs.retain(|&(_, m)| m >= 1);
        rungs.sort_by_key(|&(t, _)| t);
        DegradeLadder { rungs, step_up_lag: Duration::ZERO }
    }

    /// Damp rung flapping: hold a degraded rung until the raw target has
    /// stayed *above* it for `lag` (see the struct docs). Zero disables
    /// (the default).
    pub fn with_step_up_lag(mut self, lag: Duration) -> DegradeLadder {
        self.step_up_lag = lag;
        self
    }

    /// The configured step-up lag (zero = no hysteresis).
    pub fn step_up_lag(&self) -> Duration {
        self.step_up_lag
    }

    /// The ROADMAP ladder: step to m'=16 once the estimated drain time
    /// reaches 25 ms, to m'=8 at 100 ms — shedding compute well before
    /// the deadline shedder would start shedding users.
    pub fn standard() -> DegradeLadder {
        DegradeLadder::steps(vec![(25, 16), (100, 8)])
    }

    pub fn is_enabled(&self) -> bool {
        !self.rungs.is_empty()
    }

    /// The m' of the highest rung at or below `backlog_ms`, if any.
    fn rung_for(&self, backlog_ms: f64) -> Option<usize> {
        self.rungs
            .iter()
            .rev()
            .find(|&&(t, _)| backlog_ms >= t as f64)
            .map(|&(_, m)| m)
    }

    /// One controller decision: measure pressure at the full-quality
    /// rate, pick the rung, then restate the backlog at the degraded
    /// rate (one step, no fixpoint — the rung choice deliberately keys
    /// off full-quality pressure so it is monotone in queue depth and
    /// cannot oscillate within a single decision).
    pub fn plan(
        &self,
        queued: usize,
        svc_ewma_ms: Option<f64>,
        replicas: usize,
        m_full: usize,
    ) -> DegradePlan {
        let m_full = m_full.max(1);
        let full_ms = backlog_estimate_ms(queued, svc_ewma_ms, replicas);
        let m_eff = self.rung_for(full_ms).map_or(m_full, |m| m.clamp(1, m_full));
        DegradePlan {
            m_eff,
            m_full,
            backlog_ms: full_ms * m_eff as f64 / m_full as f64,
            warm: svc_ewma_ms.is_some(),
        }
    }

    /// The raw (stateless) rung for the current pressure: the target the
    /// hysteresis machinery steps toward.
    fn target_m(&self, full_ms: f64, m_full: usize) -> usize {
        self.rung_for(full_ms).map_or(m_full, |m| m.clamp(1, m_full))
    }

    /// The batch-formation decision with step-up hysteresis: evolve
    /// `state` at `now` and return the plan actually served. Stepping
    /// down (raw target below the held rung) is immediate; stepping up
    /// waits until the target has stayed above the held rung for the
    /// whole [`step_up_lag`](Self::with_step_up_lag) (the timer resets
    /// whenever the target falls back). With a zero lag this is exactly
    /// [`plan`](Self::plan). Call this **only** where a batch is formed
    /// — state must evolve identically in the live gateway and the sim.
    pub fn plan_at(
        &self,
        state: &mut LadderState,
        now: Tick,
        queued: usize,
        svc_ewma_ms: Option<f64>,
        replicas: usize,
        m_full: usize,
    ) -> DegradePlan {
        let m_full = m_full.max(1);
        let full_ms = backlog_estimate_ms(queued, svc_ewma_ms, replicas);
        let target = self.target_m(full_ms, m_full);
        let held = state.cur_m.filter(|_| !self.step_up_lag.is_zero());
        let m_eff = match held {
            None => {
                // no hysteresis, or first decision: adopt the raw target
                state.up_since = None;
                target
            }
            Some(cur) => {
                let cur = cur.clamp(1, m_full);
                if target <= cur {
                    // step down (or hold): immediate, timer reset
                    state.up_since = None;
                    target
                } else {
                    match state.up_since {
                        None => {
                            state.up_since = Some(now);
                            cur
                        }
                        Some(t0) if now.duration_since(t0) >= self.step_up_lag => {
                            state.up_since = None;
                            target
                        }
                        Some(_) => cur,
                    }
                }
            }
        };
        state.cur_m = Some(m_eff);
        DegradePlan {
            m_eff,
            m_full,
            backlog_ms: full_ms * m_eff as f64 / m_full as f64,
            warm: svc_ewma_ms.is_some(),
        }
    }

    /// Read-only view of the rung `plan_at` would serve right now,
    /// without evolving `state` or its step-up timer: step-downs show
    /// through immediately (`target < held`), a pending step up shows
    /// the held rung. Admission-time consumers (retry hints, EDF
    /// feasibility) hint off this so a rejection under a held rung
    /// quotes the drain time actually being served.
    pub fn peek_at(
        &self,
        state: &LadderState,
        queued: usize,
        svc_ewma_ms: Option<f64>,
        replicas: usize,
        m_full: usize,
    ) -> DegradePlan {
        let m_full = m_full.max(1);
        let full_ms = backlog_estimate_ms(queued, svc_ewma_ms, replicas);
        let target = self.target_m(full_ms, m_full);
        let m_eff = if self.step_up_lag.is_zero() {
            target
        } else {
            match state.cur_m {
                None => target,
                Some(cur) => target.min(cur.clamp(1, m_full)),
            }
        };
        DegradePlan {
            m_eff,
            m_full,
            backlog_ms: full_ms * m_eff as f64 / m_full as f64,
            warm: svc_ewma_ms.is_some(),
        }
    }
}

/// One queued request as the scheduling core sees it: arrival seq,
/// timestamps, and an opaque payload (the live gateway carries the
/// request bytes and reply channel; the sim carries nothing).
#[derive(Clone, Debug)]
pub struct Entry<T> {
    /// arrival number (assigned at admission, unique, monotone)
    pub seq: u64,
    pub enqueued: Tick,
    pub deadline: Option<Tick>,
    /// times this entry was pulled back out of a dying replica's batch
    /// and requeued ([`BucketQueues::requeue`]); admission starts it at
    /// 0 and the gateway fails the request terminally once it exceeds
    /// the configured retry budget
    pub retries: u32,
    pub payload: T,
}

impl<T> Entry<T> {
    pub fn expired(&self, now: Tick) -> bool {
        matches!(self.deadline, Some(d) if now >= d)
    }

    /// Deadline-earliest-first sort key: deadline-bearing entries rank
    /// before deadline-free ones, earlier deadlines first, arrival seq
    /// as the deterministic tie-break (a total order — seqs are unique).
    pub fn urgency(&self) -> (u64, u64) {
        (self.deadline.map_or(u64::MAX, |d| d.as_nanos()), self.seq)
    }
}

/// One bucket's queue lane: entries keyed by arrival seq in a B-tree
/// (lane order **is** seq order by construction, so admission and
/// seq-position requeue are the same O(log n) insert — the old
/// `VecDeque` layout needed a linear position scan to requeue and
/// silently relied on in-order pushes), plus a lazily-pruned min-heap
/// of `(deadline_ns, seq)` keys so EDF pops cost O(log n) and the
/// cross-bucket urgency scan reads one heap top per bucket instead of
/// walking every queued entry.
///
/// Heap nodes are never removed eagerly. A node is live iff its seq is
/// still queued: a seq's deadline is assigned once at admission and
/// survives requeues unchanged, so the seq alone identifies the node
/// (requeues push equal duplicates — same key, harmless). Stale nodes
/// are discarded when they surface at the top.
#[derive(Clone, Debug)]
struct Lane<T> {
    entries: BTreeMap<u64, Entry<T>>,
    dheap: BinaryHeap<Reverse<(u64, u64)>>,
    /// queued entries in this lane carrying a deadline
    deadlined: usize,
}

impl<T> Lane<T> {
    fn new() -> Lane<T> {
        Lane {
            entries: BTreeMap::new(),
            dheap: BinaryHeap::new(),
            deadlined: 0,
        }
    }

    fn len(&self) -> usize {
        self.entries.len()
    }

    fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Oldest queued seq — the lane's front.
    fn front_seq(&self) -> Option<u64> {
        self.entries.keys().next().copied()
    }

    /// Insert in seq position, wherever in the lane that lands.
    fn insert(&mut self, entry: Entry<T>) {
        if let Some(d) = entry.deadline {
            self.deadlined += 1;
            self.dheap.push(Reverse((d.as_nanos(), entry.seq)));
        }
        let _clash = self.entries.insert(entry.seq, entry);
        debug_assert!(_clash.is_none(), "arrival seqs are unique");
    }

    fn pop_front(&mut self) -> Option<Entry<T>> {
        let (_, e) = self.entries.pop_first()?;
        if e.deadline.is_some() {
            self.deadlined -= 1;
        }
        Some(e)
    }

    fn remove_seq(&mut self, seq: u64) -> Option<Entry<T>> {
        let e = self.entries.remove(&seq)?;
        if e.deadline.is_some() {
            self.deadlined -= 1;
        }
        Some(e)
    }

    /// The live minimum `(deadline_ns, seq)` among this lane's
    /// deadline-bearing entries, pruning stale heap tops on the way.
    fn urgent_deadline(&mut self) -> Option<(u64, u64)> {
        while let Some(&Reverse((d, seq))) = self.dheap.peek() {
            if self.entries.contains_key(&seq) {
                return Some((d, seq));
            }
            self.dheap.pop();
        }
        None
    }

    /// The lane's most urgent entry key — exactly the minimum of
    /// [`Entry::urgency`] over the whole lane: deadline-bearing entries
    /// compete via the heap top, deadline-free ones rank
    /// `(u64::MAX, seq)` so the front seq stands in for all of them.
    fn min_urgency(&mut self) -> Option<(u64, u64)> {
        let front = self.front_seq()?;
        Some(match self.urgent_deadline() {
            Some(k) => k.min((u64::MAX, front)),
            None => (u64::MAX, front),
        })
    }

    /// Pop the lane's most urgent entry (EDF within the bucket).
    fn pop_urgent(&mut self) -> Option<Entry<T>> {
        let (_, seq) = self.min_urgency()?;
        self.remove_seq(seq)
    }

    /// Move every expired entry into `shed`: earliest deadlines pop off
    /// the heap, then the reaped slice is restored to seq order — the
    /// order the legacy position scan produced and observers assert on.
    fn shed_expired(&mut self, now: Tick, shed: &mut Vec<Entry<T>>) {
        if self.deadlined == 0 {
            return;
        }
        let start = shed.len();
        while let Some((d, seq)) = self.urgent_deadline() {
            if d > now.as_nanos() {
                // the heap top is the earliest live deadline; nothing
                // else in the lane can be expired
                break;
            }
            let e = self.remove_seq(seq).expect("urgent seq is queued");
            shed.push(e);
        }
        shed[start..].sort_by_key(|e| e.seq);
    }

    /// Re-derive the counter and rebuild the heap from the entries
    /// themselves (poisoned-lock recovery). Returns true when the
    /// counter was stale.
    fn recount(&mut self) -> bool {
        let actual =
            self.entries.values().filter(|e| e.deadline.is_some()).count();
        let stale = actual != self.deadlined;
        self.deadlined = actual;
        self.dheap = self
            .entries
            .values()
            .filter_map(|e| e.deadline.map(|d| Reverse((d.as_nanos(), e.seq))))
            .collect();
        stale
    }
}

/// Per-bucket queues plus the pick/pop/shed decisions — the data half
/// of the scheduler, shared bit-for-bit by the live gateway and the
/// simulator. One [`Lane`] per bucket; this variant keeps all lanes
/// under the caller's single lock domain (the simulator's default, and
/// the layout every schedule property was originally proven on — see
/// [`ShardedQueues`] for the per-bucket-locked twin the live gateway
/// runs).
#[derive(Clone, Debug)]
pub struct BucketQueues<T> {
    lanes: Vec<Lane<T>>,
    /// queued entries carrying a deadline (maintained by push/pop/shed):
    /// lets the expiry sweep and the Conserve urgency scan short-circuit
    /// to O(1) on the common deadline-free workload
    deadlined: usize,
}

impl<T> BucketQueues<T> {
    pub fn new(n_buckets: usize) -> BucketQueues<T> {
        BucketQueues {
            lanes: (0..n_buckets.max(1)).map(|_| Lane::new()).collect(),
            deadlined: 0,
        }
    }

    pub fn n_buckets(&self) -> usize {
        self.lanes.len()
    }

    pub fn depth(&self, bucket: usize) -> usize {
        self.lanes[bucket].len()
    }

    /// Total queued entries across buckets (the admission gauge).
    pub fn len(&self) -> usize {
        self.lanes.iter().map(|l| l.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.iter().all(|l| l.is_empty())
    }

    /// Admit an entry to its bucket's lane. Lanes are seq-keyed, so the
    /// lane's front is its oldest entry whether or not pushes arrive in
    /// seq order (sharded admission assigns seqs before lane locks, so
    /// they may not).
    pub fn push(&mut self, bucket: usize, entry: Entry<T>) {
        if entry.deadline.is_some() {
            self.deadlined += 1;
        }
        self.lanes[bucket].insert(entry);
    }

    /// Re-insert an entry that was already dequeued (pulled back out of
    /// a dying replica's batch) **in seq position**, not at the back:
    /// `Fifo`'s oldest-head pick and the deadline-free EDF fast path
    /// (`pop_front`) rely on front-is-oldest, so a requeue that
    /// appended would let younger arrivals overtake the victim. With
    /// seq-keyed lanes this is the same O(log n) insert as admission —
    /// the old linear position scan is gone. The entry keeps its
    /// original `enqueued` stamp and deadline, so EDF urgency and
    /// expiry sheds judge it exactly as before the crash.
    pub fn requeue(&mut self, bucket: usize, entry: Entry<T>) {
        self.push(bucket, entry);
    }

    /// Consistency sweep for poisoned-lock recovery: re-derive the
    /// `deadlined` fast-path counters (aggregate and per-lane) and
    /// rebuild the deadline heaps from the queued entries themselves (a
    /// panic between a pop and its counter decrement would otherwise
    /// leave them stale forever — an overcount only costs the O(1)
    /// shortcut, an undercount would skip expiry sheds). Returns true
    /// when anything was stale.
    pub fn recount_deadlined(&mut self) -> bool {
        let mut stale = false;
        for lane in &mut self.lanes {
            stale |= lane.recount();
        }
        let actual: usize = self.lanes.iter().map(|l| l.deadlined).sum();
        stale |= actual != self.deadlined;
        self.deadlined = actual;
        stale
    }

    /// Remove every expired entry — anywhere in a lane, not only the
    /// heads, so an EDF pop never has to step over corpses — and return
    /// them for shed accounting/reply delivery. O(1) when no queued
    /// entry carries a deadline; otherwise each lane reaps off its
    /// deadline heap instead of scanning entries.
    pub fn shed_expired(&mut self, now: Tick) -> Vec<Entry<T>> {
        if self.deadlined == 0 {
            return Vec::new();
        }
        let mut shed = Vec::new();
        for lane in &mut self.lanes {
            lane.shed_expired(now, &mut shed);
        }
        // only deadline-bearing entries can expire
        self.deadlined -= shed.len();
        shed
    }

    /// The cross-bucket pick. `Fifo`: the bucket whose head arrived
    /// first. `Conserve`: while any queued entry carries a deadline,
    /// the bucket holding the globally most urgent one (deadline-EDF
    /// across buckets — depth must never starve another bucket's
    /// deadline), found by comparing per-lane heap tops in O(buckets);
    /// otherwise the deepest bucket, ties toward the oldest head, then
    /// the lowest index. Fully deterministic either way. (`&mut`
    /// because reading a heap top may prune stale nodes.)
    pub fn pick_bucket(&mut self, policy: SchedPolicy) -> Option<usize> {
        match policy {
            SchedPolicy::Fifo => {
                let mut best: Option<(u64, usize)> = None;
                for (b, lane) in self.lanes.iter().enumerate() {
                    if let Some(head) = lane.front_seq() {
                        let better = match best {
                            None => true,
                            Some((s, _)) => head < s,
                        };
                        if better {
                            best = Some((head, b));
                        }
                    }
                }
                best.map(|(_, b)| b)
            }
            SchedPolicy::Conserve => {
                if self.deadlined > 0 {
                    // global EDF: serve the most urgent deadline first,
                    // wherever it queues
                    let mut best: Option<((u64, u64), usize)> = None;
                    for (b, lane) in self.lanes.iter_mut().enumerate() {
                        let Some(k) = lane.urgent_deadline() else {
                            continue;
                        };
                        let better = match best {
                            None => true,
                            Some((bk, _)) => k < bk,
                        };
                        if better {
                            best = Some((k, b));
                        }
                    }
                    if let Some((_, b)) = best {
                        return Some(b);
                    }
                }
                // no deadlines queued: deepest backlog wins; for
                // deadline-free entries EDF pops in seq order, so each
                // lane's front is its oldest — head seq breaks ties
                let mut best: Option<(usize, u64, usize)> = None;
                for (b, lane) in self.lanes.iter().enumerate() {
                    let Some(head) = lane.front_seq() else {
                        continue;
                    };
                    let better = match best {
                        None => true,
                        Some((d, s, _)) => {
                            lane.len() > d || (lane.len() == d && head < s)
                        }
                    };
                    if better {
                        best = Some((lane.len(), head, b));
                    }
                }
                best.map(|(_, _, b)| b)
            }
        }
    }

    /// Pop bucket `b`'s next entry in policy order: arrival order under
    /// `Fifo`, deadline-earliest-first under `Conserve`.
    pub fn pop_next(
        &mut self,
        bucket: usize,
        policy: SchedPolicy,
    ) -> Option<Entry<T>> {
        let lane = &mut self.lanes[bucket];
        let popped = match policy {
            SchedPolicy::Fifo => lane.pop_front(),
            SchedPolicy::Conserve => {
                if lane.deadlined == 0 {
                    // no deadlines in this lane: EDF degenerates to seq
                    // order, and the lane is seq-keyed
                    lane.pop_front()
                } else {
                    lane.pop_urgent()
                }
            }
        };
        if let Some(e) = &popped {
            if e.deadline.is_some() {
                self.deadlined -= 1;
            }
        }
        popped
    }
}

/// Which queue layout schedules a run. The live gateway always runs
/// [`Sharding::PerBucket`]; the simulator defaults to
/// [`Sharding::Unsharded`] and sweeps both to prove the schedules
/// bit-identical (`tests/sim_gateway.rs`) — which is what licenses the
/// sharded layout in production: same decision procedure, only the
/// lock domain changed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Sharding {
    /// all lanes under one logical lock (the PR 5 layout)
    #[default]
    Unsharded,
    /// one locked lane per bucket plus atomic aggregate gauges
    PerBucket,
}

impl Sharding {
    pub fn label(&self) -> &'static str {
        match self {
            Sharding::Unsharded => "unsharded",
            Sharding::PerBucket => "per-bucket",
        }
    }

    /// Resolve the layout from `YOSO_SHARDS` (`per-bucket` / `sharded`
    /// select [`Sharding::PerBucket`]; anything else, or unset, keeps
    /// [`Sharding::Unsharded`]). CI's scheduler-stress sweep drives this
    /// knob so every simulator property runs under both lock domains —
    /// [`crate::serve::sim::SimConfig::default`] picks it up.
    pub fn from_env() -> Sharding {
        match std::env::var("YOSO_SHARDS").as_deref() {
            Ok("per-bucket") | Ok("per_bucket") | Ok("sharded") => {
                Sharding::PerBucket
            }
            _ => Sharding::Unsharded,
        }
    }
}

/// The sharded twin of [`BucketQueues`]: one independently locked
/// [`Lane`] per bucket plus atomic aggregate gauges, so admission into
/// bucket `b` contends only with consumers of bucket `b` — never with
/// admissions or pops elsewhere — and the hot gauges (`len`, the
/// `deadlined` fast-path check) read without any lock.
///
/// Every decision runs the same per-lane procedures as `BucketQueues`,
/// so a single-threaded caller gets bit-identical schedules from
/// either layout (the sim sweep in `tests/sim_gateway.rs` proves it).
/// Under concurrency, `pick_bucket` reads each lane's top briefly in
/// index order rather than holding a global snapshot; a pick can race
/// a pop, in which case `pop_next` comes back `None` and the caller
/// simply re-picks.
///
/// Seqs are assigned before lane locks are taken, so two admissions
/// may land in a lane out of seq order; the seq-keyed lanes make that
/// a non-event — lane order is seq order by construction.
#[derive(Debug)]
pub struct ShardedQueues<T> {
    lanes: Vec<Mutex<Lane<T>>>,
    len: AtomicUsize,
    deadlined: AtomicUsize,
}

impl<T> ShardedQueues<T> {
    pub fn new(n_buckets: usize) -> ShardedQueues<T> {
        ShardedQueues {
            lanes: (0..n_buckets.max(1))
                .map(|_| Mutex::new(Lane::new()))
                .collect(),
            len: AtomicUsize::new(0),
            deadlined: AtomicUsize::new(0),
        }
    }

    /// Lock one lane, recovering from poison. Lane operations never
    /// run caller code while holding the lock, so poisoning requires a
    /// panic elsewhere unwinding through a guard — recover rather than
    /// wedge the scheduler, and let the supervisor's
    /// [`recount_deadlined`](ShardedQueues::recount_deadlined) resync
    /// the gauges.
    fn lane(&self, bucket: usize) -> MutexGuard<'_, Lane<T>> {
        match self.lanes[bucket].lock() {
            Ok(g) => g,
            Err(poisoned) => {
                self.lanes[bucket].clear_poison();
                poisoned.into_inner()
            }
        }
    }

    pub fn n_buckets(&self) -> usize {
        self.lanes.len()
    }

    pub fn depth(&self, bucket: usize) -> usize {
        self.lane(bucket).len()
    }

    /// Total queued entries (the admission gauge) — a lock-free read.
    pub fn len(&self) -> usize {
        self.len.load(Ordering::SeqCst)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admit an entry to its bucket's lane, touching only that lane's
    /// lock (see [`BucketQueues::push`] for the ordering contract).
    pub fn push(&self, bucket: usize, entry: Entry<T>) {
        if entry.deadline.is_some() {
            self.deadlined.fetch_add(1, Ordering::SeqCst);
        }
        self.lane(bucket).insert(entry);
        self.len.fetch_add(1, Ordering::SeqCst);
    }

    /// Seq-position requeue — identical to [`push`](ShardedQueues::push)
    /// now that lanes are seq-keyed: position is where the seq was all
    /// along (see [`BucketQueues::requeue`]).
    pub fn requeue(&self, bucket: usize, entry: Entry<T>) {
        self.push(bucket, entry);
    }

    /// Remove a specific queued seq (the gateway uses this to un-admit
    /// an entry that raced shutdown). `None` if a consumer already
    /// popped it.
    pub fn remove(&self, bucket: usize, seq: u64) -> Option<Entry<T>> {
        let removed = self.lane(bucket).remove_seq(seq);
        if let Some(e) = &removed {
            self.len.fetch_sub(1, Ordering::SeqCst);
            if e.deadline.is_some() {
                self.deadlined.fetch_sub(1, Ordering::SeqCst);
            }
        }
        removed
    }

    /// Reap expired entries across all lanes (see
    /// [`BucketQueues::shed_expired`]). O(1) when nothing queued
    /// carries a deadline.
    pub fn shed_expired(&self, now: Tick) -> Vec<Entry<T>> {
        if self.deadlined.load(Ordering::SeqCst) == 0 {
            return Vec::new();
        }
        let mut shed = Vec::new();
        for bucket in 0..self.lanes.len() {
            self.lane(bucket).shed_expired(now, &mut shed);
        }
        if !shed.is_empty() {
            self.len.fetch_sub(shed.len(), Ordering::SeqCst);
            self.deadlined.fetch_sub(shed.len(), Ordering::SeqCst);
        }
        shed
    }

    /// Cross-bucket pick — the [`BucketQueues::pick_bucket`] procedure
    /// over per-lane tops, locking one lane at a time.
    pub fn pick_bucket(&self, policy: SchedPolicy) -> Option<usize> {
        match policy {
            SchedPolicy::Fifo => {
                let mut best: Option<(u64, usize)> = None;
                for b in 0..self.lanes.len() {
                    let Some(head) = self.lane(b).front_seq() else {
                        continue;
                    };
                    let better = match best {
                        None => true,
                        Some((s, _)) => head < s,
                    };
                    if better {
                        best = Some((head, b));
                    }
                }
                best.map(|(_, b)| b)
            }
            SchedPolicy::Conserve => {
                if self.deadlined.load(Ordering::SeqCst) > 0 {
                    let mut best: Option<((u64, u64), usize)> = None;
                    for b in 0..self.lanes.len() {
                        let Some(k) = self.lane(b).urgent_deadline() else {
                            continue;
                        };
                        let better = match best {
                            None => true,
                            Some((bk, _)) => k < bk,
                        };
                        if better {
                            best = Some((k, b));
                        }
                    }
                    if let Some((_, b)) = best {
                        return Some(b);
                    }
                }
                let mut best: Option<(usize, u64, usize)> = None;
                for b in 0..self.lanes.len() {
                    let lane = self.lane(b);
                    let Some(head) = lane.front_seq() else {
                        continue;
                    };
                    let depth = lane.len();
                    drop(lane);
                    let better = match best {
                        None => true,
                        Some((d, s, _)) => {
                            depth > d || (depth == d && head < s)
                        }
                    };
                    if better {
                        best = Some((depth, head, b));
                    }
                }
                best.map(|(_, _, b)| b)
            }
        }
    }

    /// Pop bucket `b`'s next entry in policy order (see
    /// [`BucketQueues::pop_next`]). May return `None` even after a
    /// successful pick when a concurrent consumer drained the lane
    /// first — callers re-pick.
    pub fn pop_next(
        &self,
        bucket: usize,
        policy: SchedPolicy,
    ) -> Option<Entry<T>> {
        let mut lane = self.lane(bucket);
        let popped = match policy {
            SchedPolicy::Fifo => lane.pop_front(),
            SchedPolicy::Conserve => {
                if lane.deadlined == 0 {
                    lane.pop_front()
                } else {
                    lane.pop_urgent()
                }
            }
        };
        drop(lane);
        if let Some(e) = &popped {
            self.len.fetch_sub(1, Ordering::SeqCst);
            if e.deadline.is_some() {
                self.deadlined.fetch_sub(1, Ordering::SeqCst);
            }
        }
        popped
    }

    /// Re-derive both aggregate gauges and every lane's heap/counter
    /// from the queued entries themselves (poisoned-lock recovery,
    /// mirroring [`BucketQueues::recount_deadlined`]). Returns true
    /// when anything was stale.
    pub fn recount_deadlined(&self) -> bool {
        let mut stale = false;
        let mut len = 0usize;
        let mut deadlined = 0usize;
        for bucket in 0..self.lanes.len() {
            let mut lane = self.lane(bucket);
            stale |= lane.recount();
            len += lane.len();
            deadlined += lane.deadlined;
        }
        stale |= self.len.swap(len, Ordering::SeqCst) != len;
        stale |= self.deadlined.swap(deadlined, Ordering::SeqCst) != deadlined;
        stale
    }
}

/// Per-class admission capacity: the queue slots a request of the given
/// class may fill. `reserve` is the fraction of total capacity held
/// back for `BestEffort` traffic (rounded to whole slots, clamped into
/// [0, 1]); best-effort requests see the full queue, while
/// `Full`/`Degraded` requests stop `round(capacity x reserve)` slots
/// early — so latency-insensitive traffic cannot be crowded out
/// entirely by reserved-quality clients. `reserve == 0.0` (the default)
/// is exactly the classless bounded queue.
pub fn admission_cap(
    capacity: usize,
    reserve: f64,
    best_effort: bool,
) -> usize {
    if best_effort {
        return capacity;
    }
    let reserved =
        (capacity as f64 * reserve.clamp(0.0, 1.0)).round() as usize;
    capacity - reserved.min(capacity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn entry(seq: u64, deadline_ms: Option<u64>) -> Entry<()> {
        Entry {
            seq,
            enqueued: Tick::from_ms(seq),
            deadline: deadline_ms.map(Tick::from_ms),
            retries: 0,
            payload: (),
        }
    }

    #[test]
    fn requeue_restores_seq_position_and_deadline_count() {
        let mut qs: BucketQueues<()> = BucketQueues::new(1);
        for seq in 0..4 {
            qs.push(0, entry(seq, (seq == 2).then_some(100)));
        }
        // pull seq 1 (deadline-free) and seq 2 (deadlined) out the way
        // a dying replica's batch would hold them, then requeue
        let a = qs.pop_next(0, SchedPolicy::Fifo).unwrap();
        let b = qs.pop_next(0, SchedPolicy::Fifo).unwrap();
        let c = qs.pop_next(0, SchedPolicy::Fifo).unwrap();
        assert_eq!((a.seq, b.seq, c.seq), (0, 1, 2));
        qs.requeue(0, b);
        qs.requeue(0, c);
        // seq order restored: 1, 2, 3 — the requeued entries sit ahead
        // of the younger arrival, not behind it
        assert_eq!(qs.pop_next(0, SchedPolicy::Fifo).unwrap().seq, 1);
        assert_eq!(qs.deadlined, 1, "requeue re-counted the deadline");
        assert_eq!(qs.pop_next(0, SchedPolicy::Fifo).unwrap().seq, 2);
        assert_eq!(qs.deadlined, 0);
        assert_eq!(qs.pop_next(0, SchedPolicy::Fifo).unwrap().seq, 3);
    }

    #[test]
    fn recount_deadlined_repairs_a_stale_counter() {
        let mut qs: BucketQueues<()> = BucketQueues::new(2);
        qs.push(0, entry(0, Some(50)));
        qs.push(1, entry(1, None));
        assert!(!qs.recount_deadlined(), "consistent counter is a no-op");
        qs.deadlined = 7; // a panic between pop and decrement
        assert!(qs.recount_deadlined());
        assert_eq!(qs.deadlined, 1);
    }

    #[test]
    fn admission_cap_reserves_whole_slots_for_best_effort() {
        // best-effort always sees the full queue
        assert_eq!(admission_cap(8, 0.25, true), 8);
        // reserved classes stop round(8 x 0.25) = 2 slots early
        assert_eq!(admission_cap(8, 0.25, false), 6);
        // zero reserve is the classless bounded queue
        assert_eq!(admission_cap(8, 0.0, false), 8);
        // clamped: a nonsense reserve never underflows
        assert_eq!(admission_cap(8, 2.0, false), 0);
        assert_eq!(admission_cap(8, -1.0, false), 8);
        // rounding, not truncation: 10 x 0.25 = 2.5 -> 3 slots
        assert_eq!(admission_cap(10, 0.25, false), 7);
    }

    #[test]
    fn fifo_picks_oldest_head_and_pops_in_arrival_order() {
        let mut qs: BucketQueues<()> = BucketQueues::new(3);
        qs.push(1, entry(2, None));
        qs.push(2, entry(0, Some(1)));
        qs.push(2, entry(3, None));
        // bucket 2's head (seq 0) is globally oldest
        assert_eq!(qs.pick_bucket(SchedPolicy::Fifo), Some(2));
        assert_eq!(qs.pop_next(2, SchedPolicy::Fifo).unwrap().seq, 0);
        // now bucket 1's head (seq 2) beats bucket 2's (seq 3)
        assert_eq!(qs.pick_bucket(SchedPolicy::Fifo), Some(1));
        assert_eq!(qs.pop_next(1, SchedPolicy::Fifo).unwrap().seq, 2);
        assert_eq!(qs.pop_next(1, SchedPolicy::Fifo).map(|e| e.seq), None);
    }

    #[test]
    fn conserve_picks_deepest_bucket_when_no_deadlines() {
        let mut qs: BucketQueues<()> = BucketQueues::new(3);
        qs.push(0, entry(0, None));
        qs.push(2, entry(1, None));
        qs.push(2, entry(2, None));
        // bucket 2 is deepest despite bucket 0 holding the oldest entry
        assert_eq!(qs.pick_bucket(SchedPolicy::Conserve), Some(2));
        // depth tie: the oldest head breaks it
        qs.push(0, entry(3, None));
        assert_eq!(qs.depth(0), 2);
        assert_eq!(qs.depth(2), 2);
        assert_eq!(qs.pick_bucket(SchedPolicy::Conserve), Some(0));
    }

    #[test]
    fn conserve_deadline_beats_depth_across_buckets() {
        // the starvation guard: a deep deadline-free bucket must never
        // starve another bucket's deadline — the pick is deadline-EDF
        // across buckets whenever any deadline is queued
        let mut qs: BucketQueues<()> = BucketQueues::new(3);
        for s in 0..5 {
            qs.push(2, entry(s, None));
        }
        qs.push(0, entry(5, Some(40)));
        assert_eq!(qs.depth(2), 5);
        assert_eq!(qs.depth(0), 1);
        assert_eq!(qs.pick_bucket(SchedPolicy::Conserve), Some(0));
        // among deadlines, the globally most urgent wins regardless of
        // where it queues (earlier deadline in bucket 1)
        qs.push(1, entry(6, Some(10)));
        assert_eq!(qs.pick_bucket(SchedPolicy::Conserve), Some(1));
        // pop both deadlines -> back to deepest-bucket behavior
        assert_eq!(qs.pop_next(1, SchedPolicy::Conserve).unwrap().seq, 6);
        assert_eq!(qs.pop_next(0, SchedPolicy::Conserve).unwrap().seq, 5);
        assert_eq!(qs.pick_bucket(SchedPolicy::Conserve), Some(2));
        // FIFO is oblivious to deadlines either way
        qs.push(0, entry(7, Some(1)));
        assert_eq!(qs.pick_bucket(SchedPolicy::Fifo), Some(2));
    }

    #[test]
    fn deadlined_counter_tracks_push_pop_shed() {
        let mut qs: BucketQueues<()> = BucketQueues::new(2);
        assert_eq!(qs.deadlined, 0);
        // deadline-free traffic keeps the sweep on its O(1) fast path
        qs.push(0, entry(0, None));
        assert_eq!(qs.deadlined, 0);
        assert!(qs.shed_expired(Tick::from_ms(1_000_000)).is_empty());
        qs.push(1, entry(1, Some(10)));
        qs.push(1, entry(2, Some(20)));
        assert_eq!(qs.deadlined, 2);
        // popping (either policy) decrements for deadline-bearers only
        assert_eq!(qs.pop_next(0, SchedPolicy::Conserve).unwrap().seq, 0);
        assert_eq!(qs.deadlined, 2);
        assert_eq!(qs.pop_next(1, SchedPolicy::Fifo).unwrap().seq, 1);
        assert_eq!(qs.deadlined, 1);
        // shedding the rest drains the counter
        assert_eq!(qs.shed_expired(Tick::from_ms(20)).len(), 1);
        assert_eq!(qs.deadlined, 0);
        assert!(qs.is_empty());
    }

    #[test]
    fn conserve_pops_deadline_earliest_first() {
        let mut qs: BucketQueues<()> = BucketQueues::new(1);
        qs.push(0, entry(0, None));
        qs.push(0, entry(1, Some(300)));
        qs.push(0, entry(2, Some(100)));
        qs.push(0, entry(3, Some(100))); // deadline tie -> seq order
        qs.push(0, entry(4, Some(200)));
        let order: Vec<u64> = (0..5)
            .map(|_| qs.pop_next(0, SchedPolicy::Conserve).unwrap().seq)
            .collect();
        // deadlines 100(seq2), 100(seq3), 200, 300, then deadline-free
        assert_eq!(order, vec![2, 3, 4, 1, 0]);
    }

    #[test]
    fn shed_expired_reaps_mid_queue_not_only_heads() {
        let mut qs: BucketQueues<()> = BucketQueues::new(2);
        qs.push(0, entry(0, None));
        qs.push(0, entry(1, Some(10)));
        qs.push(0, entry(2, None));
        qs.push(1, entry(3, Some(50)));
        let shed = qs.shed_expired(Tick::from_ms(20));
        assert_eq!(shed.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![1]);
        assert_eq!(qs.len(), 3);
        // exactly-at-deadline counts as expired (now >= d)
        let shed = qs.shed_expired(Tick::from_ms(50));
        assert_eq!(shed.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![3]);
        assert_eq!(qs.len(), 2);
    }

    #[test]
    fn policy_table_uniform_scaled_and_overrides() {
        let base = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(8),
        };
        let uniform = BatchPolicyTable::uniform(base);
        assert_eq!(uniform.policy_for(8, 128).max_batch, 8);
        assert_eq!(uniform.policy_for(128, 128).max_wait, base.max_wait);

        let scaled = BatchPolicyTable::scaled(base);
        // widest bucket keeps the base policy
        assert_eq!(scaled.policy_for(128, 128).max_batch, 8);
        // one halving: 2x batch, half the wait
        assert_eq!(scaled.policy_for(64, 128).max_batch, 16);
        assert_eq!(
            scaled.policy_for(64, 128).max_wait,
            Duration::from_millis(4)
        );
        // scaling caps at 8x no matter how narrow the bucket
        assert_eq!(scaled.policy_for(8, 128).max_batch, 64);
        assert_eq!(scaled.policy_for(1, 4096).max_batch, 64);
        assert_eq!(
            scaled.policy_for(8, 128).max_wait,
            Duration::from_millis(1)
        );

        // a zero cap degrades to 1 (the dequeue paths always ship the
        // entry they popped; the sim must agree with the live gateway)
        let zero = BatchPolicyTable::uniform(BatchPolicy {
            max_batch: 0,
            max_wait: Duration::from_millis(1),
        });
        assert_eq!(zero.policy_for(8, 128).max_batch, 1);
        assert_eq!(
            BatchPolicyTable::uniform(base)
                .with_override(8, BatchPolicy {
                    max_batch: 0,
                    max_wait: Duration::ZERO,
                })
                .policy_for(8, 128)
                .max_batch,
            1
        );

        // exact-width override beats scaling; re-override replaces
        let pinned = BatchPolicyTable::scaled(base)
            .with_override(64, BatchPolicy {
                max_batch: 2,
                max_wait: Duration::from_millis(1),
            })
            .with_override(64, BatchPolicy {
                max_batch: 3,
                max_wait: Duration::from_millis(2),
            });
        assert_eq!(pinned.policy_for(64, 128).max_batch, 3);
        assert_eq!(pinned.policy_for(32, 128).max_batch, 32);
    }

    #[test]
    fn ladder_plan_scales_backlog_and_hint_to_the_degraded_rate() {
        let ladder = DegradeLadder::steps(vec![(25, 16), (100, 8)]);
        // below the first rung: full quality, hint matches the plain one
        let p = ladder.plan(10, Some(1.0), 1, 32);
        assert_eq!((p.m_eff, p.m_full), (32, 32));
        assert!(!p.degraded());
        assert_eq!(p.hint_ms(), retry_hint_ms(10, Some(1.0), 1));
        // past the first rung: m'=16 halves the drain estimate — the
        // hint must quote the degraded rate, not the full-quality EWMA
        let p = ladder.plan(50, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 16);
        assert!(p.degraded());
        assert_eq!(p.backlog_ms, 25.0);
        assert_eq!(p.hint_ms(), 25);
        assert!(p.hint_ms() < retry_hint_ms(50, Some(1.0), 1));
        // deepest rung at heavy pressure
        let p = ladder.plan(400, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 8);
        assert_eq!(p.backlog_ms, 100.0);
        // a rung below the session's own m clamps to m_full
        let p = ladder.plan(50, Some(1.0), 1, 8);
        assert_eq!(p.m_eff, 8);
        assert!(!p.degraded());
        // replicas divide the pressure signal before the rung pick
        let p = ladder.plan(50, Some(1.0), 4, 32);
        assert_eq!(p.m_eff, 32, "12.5 ms of backlog is below every rung");
        // disabled ladder: the plan is the identity signal
        let p = DegradeLadder::none().plan(50, Some(2.0), 2, 32);
        assert!(!DegradeLadder::none().is_enabled());
        assert_eq!(p.m_eff, 32);
        assert_eq!(p.hint_ms(), retry_hint_ms(50, Some(2.0), 2));
    }

    #[test]
    fn hysteresis_steps_down_immediately_but_lags_step_up() {
        let ladder = DegradeLadder::steps(vec![(25, 16), (100, 8)])
            .with_step_up_lag(Duration::from_millis(50));
        assert_eq!(ladder.step_up_lag(), Duration::from_millis(50));
        let mut st = LadderState::default();
        assert_eq!(st.current_m(), None);
        // heavy backlog: first decision adopts the deep rung directly
        let p = ladder.plan_at(&mut st, Tick::from_ms(0), 400, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 8);
        assert_eq!(st.current_m(), Some(8));
        // backlog clears: raw target is 32, but the rung holds for lag
        let p = ladder.plan_at(&mut st, Tick::from_ms(10), 0, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 8, "step up must wait out the lag");
        // the read-only peek shows the held rung without evolving state
        let peek = ladder.peek_at(&st, 0, Some(1.0), 1, 32);
        assert_eq!(peek.m_eff, 8);
        // pressure returns mid-lag: timer resets, rung still 8
        let p = ladder.plan_at(&mut st, Tick::from_ms(30), 400, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 8);
        let p = ladder.plan_at(&mut st, Tick::from_ms(40), 0, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 8, "timer restarted by the mid-lag relapse");
        // ... and a peeked step *down* shows through immediately
        let peek = ladder.peek_at(&st, 400, Some(1.0), 1, 32);
        assert_eq!(peek.m_eff, 8);
        // 50 ms after the restart the step up finally lands
        let p = ladder.plan_at(&mut st, Tick::from_ms(90), 0, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 32);
        assert_eq!(st.current_m(), Some(32));
        // intermediate steps lag too: 8 -> 16 needs its own full lag
        let p = ladder.plan_at(&mut st, Tick::from_ms(100), 400, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 8, "step down from 32 is immediate");
        let p = ladder.plan_at(&mut st, Tick::from_ms(110), 50, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 8, "raw target 16 is a step up: held");
        let p = ladder.plan_at(&mut st, Tick::from_ms(161), 50, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 16, "lag elapsed: adopt the 16 rung");
    }

    #[test]
    fn zero_lag_plan_at_and_peek_match_stateless_plan() {
        let ladder = DegradeLadder::standard();
        let mut st = LadderState::default();
        for (t, queued) in [(0u64, 400usize), (1, 0), (2, 400), (3, 0), (4, 50)] {
            let stateless = ladder.plan(queued, Some(1.0), 1, 32);
            let at = ladder.plan_at(&mut st, Tick::from_ms(t), queued, Some(1.0), 1, 32);
            assert_eq!(at, stateless, "lag-0 plan_at must be the legacy plan");
            let peek = ladder.peek_at(&st, queued, Some(1.0), 1, 32);
            assert_eq!(peek, stateless, "lag-0 peek must be the legacy plan");
        }
    }

    #[test]
    fn admission_edf_rejects_only_warm_infeasible_deadlines() {
        let ladder = DegradeLadder::standard();
        // warm + degraded: 200 queued at 1 ms -> 200 ms full-quality
        // pressure -> m'=8 rung -> 50 ms drain at the degraded rate
        let p = ladder.plan(200, Some(1.0), 1, 32);
        assert_eq!(p.m_eff, 8);
        assert_eq!(p.backlog_ms, 50.0);
        assert!(deadline_infeasible(&p, Duration::from_millis(40)));
        assert!(
            !deadline_infeasible(&p, Duration::from_millis(50)),
            "a deadline exactly at the estimate is still feasible"
        );
        // the degraded rate must drive the check: the full-quality
        // estimate (200 ms) would wrongly reject a 120 ms deadline the
        // ladder can in fact meet
        assert!(!deadline_infeasible(&p, Duration::from_millis(120)));
        let full = DegradeLadder::none().plan(200, Some(1.0), 1, 32);
        assert!(deadline_infeasible(&full, Duration::from_millis(120)));
        // a cold estimate never rejects — one guess must not turn away
        // real traffic before the first batch completes
        let cold = ladder.plan(10_000, None, 1, 32);
        assert!(!cold.warm);
        assert!(!deadline_infeasible(&cold, Duration::from_millis(1)));
    }

    #[test]
    fn ewma_warmup_is_explicit_and_steps_blend() {
        assert_eq!(update_ewma(None, 7.5), 7.5);
        assert_eq!(update_ewma(Some(10.0), 20.0), 0.8 * 10.0 + 0.2 * 20.0);
        // hint floors at 1 ms and assumes 1 ms/request when cold
        assert_eq!(retry_hint_ms(0, Some(5.0), 1), 1);
        assert_eq!(retry_hint_ms(8, None, 2), 4);
    }

    #[test]
    fn urgency_is_a_total_deterministic_order() {
        let a = entry(0, Some(10));
        let b = entry(1, Some(10));
        let c = entry(2, None);
        assert!(a.urgency() < b.urgency(), "deadline tie breaks by seq");
        assert!(b.urgency() < c.urgency(), "deadline-free ranks last");
        assert!(a.expired(Tick::from_ms(10)), "expiry is inclusive");
        assert!(!a.expired(Tick::from_ms(9)));
        assert!(!c.expired(Tick::from_nanos(u64::MAX)));
    }

    /// Satellite regression: the width-scaling shift must be total — no
    /// panic (debug) or wrap (release) at any width ratio, however the
    /// halvings cap evolves. The documented 8x cap still holds.
    #[test]
    fn policy_table_scaling_never_overflows_at_extreme_width_ratios() {
        let base = BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(8),
        };
        let scaled = BatchPolicyTable::scaled(base);
        // the most extreme spread expressible: width 1 vs usize::MAX
        let p = scaled.policy_for(1, usize::MAX);
        assert_eq!(p.max_batch, 64, "8x cap holds at any ratio");
        assert_eq!(p.max_wait, Duration::from_millis(1));
        // width 0 normalizes to 1 first
        assert_eq!(scaled.policy_for(0, usize::MAX).max_batch, 64);
        // a huge base cap saturates instead of wrapping
        let big = BatchPolicyTable::scaled(BatchPolicy {
            max_batch: usize::MAX,
            max_wait: Duration::ZERO,
        });
        assert_eq!(big.policy_for(1, usize::MAX).max_batch, usize::MAX);
        assert_eq!(big.policy_for(1, usize::MAX).max_wait, Duration::ZERO);
    }

    /// Satellite regression: with sharded admission, seqs are assigned
    /// before lane locks, so pushes can land out of seq order — and a
    /// supervised requeue must still land in seq position among them.
    #[test]
    fn requeue_lands_in_seq_position_amid_out_of_order_admissions() {
        let mut qs: BucketQueues<()> = BucketQueues::new(1);
        // out-of-order admission: 0, 20, then 10
        qs.push(0, entry(0, None));
        qs.push(0, entry(20, Some(500)));
        qs.push(0, entry(10, None));
        assert_eq!(qs.pop_next(0, SchedPolicy::Fifo).unwrap().seq, 0);
        let victim = qs.pop_next(0, SchedPolicy::Fifo).unwrap();
        assert_eq!(victim.seq, 10);
        // younger arrival shows up while the victim is in-flight
        qs.push(0, entry(15, None));
        qs.requeue(0, victim);
        assert_eq!(qs.deadlined, 1);
        let order: Vec<u64> = std::iter::from_fn(|| {
            qs.pop_next(0, SchedPolicy::Fifo).map(|e| e.seq)
        })
        .collect();
        assert_eq!(order, vec![10, 15, 20], "requeue sits ahead of 15");
        assert_eq!(qs.deadlined, 0);
    }

    /// A deadline-bearing requeue must re-arm the lane's deadline heap:
    /// EDF pops and expiry sheds see the requeued entry exactly as
    /// before the crash.
    #[test]
    fn requeued_deadline_entry_keeps_edf_and_shed_behavior() {
        let mut qs: BucketQueues<()> = BucketQueues::new(1);
        qs.push(0, entry(0, None));
        qs.push(0, entry(1, Some(100)));
        qs.push(0, entry(2, Some(50)));
        // EDF pops the most urgent; pretend its replica died twice
        for _ in 0..2 {
            let victim = qs.pop_next(0, SchedPolicy::Conserve).unwrap();
            assert_eq!(victim.seq, 2);
            qs.requeue(0, victim);
        }
        assert_eq!(qs.deadlined, 2);
        // the duplicate heap nodes from the requeues are harmless:
        // expiry at t=50 reaps exactly seq 2, once
        let shed = qs.shed_expired(Tick::from_ms(50));
        assert_eq!(shed.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![2]);
        assert_eq!(qs.deadlined, 1);
        assert_eq!(qs.pop_next(0, SchedPolicy::Conserve).unwrap().seq, 1);
        assert_eq!(qs.pop_next(0, SchedPolicy::Conserve).unwrap().seq, 0);
        assert_eq!(qs.deadlined, 0);
    }

    /// The sharded layout must reproduce the unsharded layout's
    /// decisions bit for bit when driven single-threaded: same picks,
    /// same pops, same sheds, same gauges, over a scripted mix of
    /// admissions, requeues, and expiries under both policies.
    #[test]
    fn sharded_queues_match_unsharded_decisions_bit_for_bit() {
        for policy in [SchedPolicy::Fifo, SchedPolicy::Conserve] {
            let mut un: BucketQueues<()> = BucketQueues::new(3);
            let sh: ShardedQueues<()> = ShardedQueues::new(3);
            // deterministic scripted trace: a spread of buckets,
            // deadlines, and out-of-order seqs
            let script: Vec<(usize, u64, Option<u64>)> = vec![
                (0, 0, None),
                (2, 1, Some(40)),
                (2, 3, None),
                (1, 2, Some(10)),
                (0, 5, Some(25)),
                (1, 4, None),
                (2, 7, Some(40)),
                (0, 6, None),
            ];
            for &(b, seq, dl) in &script {
                un.push(b, entry(seq, dl));
                sh.push(b, entry(seq, dl));
            }
            assert_eq!(un.len(), sh.len());
            // interleave picks/pops with an expiry shed and a requeue
            let mut popped_un = Vec::new();
            let mut popped_sh = Vec::new();
            for round in 0..script.len() + 2 {
                if round == 3 {
                    let now = Tick::from_ms(25);
                    let a: Vec<u64> =
                        un.shed_expired(now).iter().map(|e| e.seq).collect();
                    let b: Vec<u64> =
                        sh.shed_expired(now).iter().map(|e| e.seq).collect();
                    assert_eq!(a, b, "shed order diverged ({policy:?})");
                }
                let pick_un = un.pick_bucket(policy);
                let pick_sh = sh.pick_bucket(policy);
                assert_eq!(pick_un, pick_sh, "pick diverged ({policy:?})");
                let Some(b) = pick_un else { break };
                let e_un = un.pop_next(b, policy).unwrap();
                let e_sh = sh.pop_next(b, policy).unwrap();
                assert_eq!(e_un.seq, e_sh.seq, "pop diverged ({policy:?})");
                if round == 1 {
                    // a supervised requeue mid-trace
                    un.requeue(b, e_un);
                    sh.requeue(b, e_sh);
                } else {
                    popped_un.push(e_un.seq);
                    popped_sh.push(e_sh.seq);
                }
                assert_eq!(un.len(), sh.len(), "gauges diverged");
            }
            assert_eq!(popped_un, popped_sh);
            assert!(un.is_empty());
            assert!(sh.is_empty());
        }
    }

    /// Sharded gauges stay exact through push/pop/shed/remove, and the
    /// recovery recount reports staleness only when there is some.
    #[test]
    fn sharded_gauges_track_push_pop_shed_and_remove() {
        let sh: ShardedQueues<()> = ShardedQueues::new(2);
        assert!(sh.is_empty());
        sh.push(0, entry(0, None));
        sh.push(1, entry(1, Some(10)));
        sh.push(1, entry(2, Some(20)));
        assert_eq!((sh.len(), sh.depth(0), sh.depth(1)), (3, 1, 2));
        // un-admit a specific seq (the shutdown-race path)
        let removed = sh.remove(1, 2).unwrap();
        assert_eq!(removed.seq, 2);
        assert!(sh.remove(1, 2).is_none(), "second take misses");
        assert_eq!(sh.len(), 2);
        // expiry reaps the remaining deadline
        let shed = sh.shed_expired(Tick::from_ms(10));
        assert_eq!(shed.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![1]);
        assert_eq!(sh.len(), 1);
        assert!(!sh.recount_deadlined(), "consistent gauges are a no-op");
        assert_eq!(sh.pop_next(0, SchedPolicy::Conserve).unwrap().seq, 0);
        assert!(sh.is_empty());
        assert!(sh.pop_next(0, SchedPolicy::Fifo).is_none());
    }
}
