//! Dynamic batching policy: max-batch-or-max-wait, the same policy the
//! serving systems the paper's efficiency claims target (vLLM-style
//! routers) use for non-autoregressive models.
//!
//! Time comes off an injected [`Clock`]: under [`SystemClock`] the
//! aging behavior is the production wall-clock behavior; under
//! [`SimClock`](super::clock::SimClock) `next_batch` never touches the
//! wall clock — it drains what is queued and *advances virtual time* to
//! the aging deadline — so the aging tests below assert exact virtual
//! durations instead of sleeping and hoping.
//!
//! This is the single-queue batcher behind the plain `serve::Server`.
//! The multi-bucket gateway applies the same max-batch-or-max-wait
//! policy per bucket (`BatchPolicyTable`), but schedules over the
//! sharded per-bucket lanes in [`super::sched::ShardedQueues`] — one
//! lock per bucket, not one queue — so its aging waits park on a
//! condvar other replicas (and thieves) can preempt.

use super::clock::{Clock, SystemClock};
use super::Request;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) }
    }
}

pub struct Batcher {
    pub policy: BatchPolicy,
    clock: Arc<dyn Clock>,
}

impl Batcher {
    /// Production batcher on a fresh wall clock. Serve loops that stamp
    /// `Request::enqueued` themselves should share one clock via
    /// [`Batcher::with_clock`] so stamps and aging live on one timeline.
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher::with_clock(policy, Arc::new(SystemClock::new()))
    }

    pub fn with_clock(policy: BatchPolicy, clock: Arc<dyn Clock>) -> Batcher {
        Batcher { policy, clock }
    }

    /// Collect the next batch. Blocks for the first request; then drains
    /// until max_batch or until the first request has aged max_wait
    /// **counted from its `enqueued` timestamp**, not from when `recv`
    /// returned — a request that already sat in the channel while the
    /// executor was busy must not wait the full `max_wait` again. A
    /// request aged past the budget still gets one non-blocking drain of
    /// whatever is already queued (batching stays free when the queue is
    /// deep). Returns None when the channel is closed and drained.
    pub fn next_batch(&self, rx: &Receiver<Request>) -> Option<Vec<Request>> {
        let first = rx.recv().ok()?;
        // clamped to now: an over-aged first request makes the deadline
        // "immediately", never a deadline in the past
        let deadline = first
            .enqueued
            .saturating_add(self.policy.max_wait)
            .max(self.clock.now());
        let mut batch = vec![first];
        while batch.len() < self.policy.max_batch {
            let now = self.clock.now();
            if now >= deadline {
                // wait budget spent: take what is queued, without blocking
                match rx.try_recv() {
                    Ok(req) => batch.push(req),
                    Err(_) => break,
                }
                continue;
            }
            if self.clock.is_virtual() {
                // virtual time: never wall-block — drain what is queued,
                // then let the waiter advance the clock to the deadline
                match rx.try_recv() {
                    Ok(req) => batch.push(req),
                    Err(_) => self.clock.wait_until(deadline),
                }
                continue;
            }
            match rx.recv_timeout(deadline.duration_since(now)) {
                Ok(req) => batch.push(req),
                Err(_) => break, // timeout or disconnect: ship what we have
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::super::clock::{SimClock, Tick};
    use super::*;
    use crate::util::Rng;
    use std::sync::mpsc::channel;

    fn req(clock: &SimClock) -> (Request, std::sync::mpsc::Receiver<super::super::Response>) {
        let (tx, rx) = channel();
        (
            Request {
                input_ids: vec![1, 2, 3],
                segment_ids: vec![0, 0, 0],
                reply: tx,
                enqueued: clock.now(),
            },
            rx,
        )
    }

    fn sim_batcher(
        clock: &Arc<SimClock>,
        max_batch: usize,
        max_wait_ms: u64,
    ) -> Batcher {
        Batcher::with_clock(
            BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(max_wait_ms),
            },
            Arc::clone(clock) as Arc<dyn Clock>,
        )
    }

    #[test]
    fn collects_up_to_max_batch() {
        let clock = Arc::new(SimClock::new());
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for _ in 0..5 {
            let (r, k) = req(&clock);
            keep.push(k);
            tx.send(r).unwrap();
        }
        let b = sim_batcher(&clock, 3, 50);
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 3);
        // a full batch ships instantly: zero virtual time consumed
        assert_eq!(clock.now(), Tick::ZERO);
        let batch2 = b.next_batch(&rx).unwrap();
        assert_eq!(batch2.len(), 2);
        // the short batch aged its full (virtual) wait budget, exactly
        assert_eq!(clock.now(), Tick::from_ms(50));
    }

    #[test]
    fn respects_max_wait_exactly() {
        let clock = Arc::new(SimClock::new());
        let (tx, rx) = channel();
        let (r, _k) = req(&clock);
        tx.send(r).unwrap();
        let b = sim_batcher(&clock, 64, 10);
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 1);
        // virtual aging is exact: the clock advanced by max_wait, to the
        // nanosecond, and no wall time was slept
        assert_eq!(clock.now(), Tick::from_ms(10));
    }

    #[test]
    fn aged_request_does_not_wait_max_wait_again() {
        // the aging regression: a request that sat in the channel past
        // max_wait (executor busy) must ship immediately — after a
        // non-blocking drain of anything else already queued. On the
        // virtual clock this is exact: zero additional time may pass.
        let clock = Arc::new(SimClock::new());
        let (tx, rx) = channel();
        let (r1, _k1) = req(&clock); // enqueued at t=0
        clock.advance(Duration::from_secs(2)); // ...then the executor was busy
        let (r2, _k2) = req(&clock);
        tx.send(r1).unwrap();
        tx.send(r2).unwrap();
        let b = sim_batcher(&clock, 64, 500);
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 2, "queued request must ride the aged batch");
        assert_eq!(
            clock.now(),
            Tick::from_ms(2000),
            "aged request waited again: the over-age deadline clamps to \
             now, so shipping must consume zero additional virtual time"
        );
    }

    #[test]
    fn none_on_closed_channel() {
        let (tx, rx) = channel::<Request>();
        drop(tx);
        let b = Batcher::new(BatchPolicy::default());
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn prop_first_request_age_never_exceeds_budget_plus_drain() {
        // batch-aging property, on the virtual clock: for random traces
        // (random enqueue ages, policies, queue depths), next_batch
        // returns by max(first.enqueued + max_wait, call time) — the
        // first request never ages past its budget beyond the one
        // non-blocking drain, and an under-aged batch never ships early
        // without being full.
        let mut rng = Rng::new(0xA61);
        for case in 0..200u64 {
            let clock = Arc::new(SimClock::new());
            let max_batch = 1 + rng.below(6);
            let max_wait_ms = 1 + rng.below(40) as u64;
            // let some time pass, then enqueue requests with staggered
            // ages (some possibly older than max_wait)
            let t0_ms = rng.below(100) as u64;
            clock.advance(Duration::from_millis(t0_ms));
            let (tx, rx) = channel();
            let n = 1 + rng.below(8);
            let mut keep = Vec::new();
            let mut first_enqueued = None;
            for i in 0..n {
                let age_ms = rng.below(60) as u64;
                let (mut r, k) = req(&clock);
                r.enqueued = Tick::from_ms(t0_ms.saturating_sub(age_ms));
                if i == 0 {
                    first_enqueued = Some(r.enqueued);
                }
                keep.push(k);
                tx.send(r).unwrap();
            }
            let b = sim_batcher(&clock, max_batch, max_wait_ms);
            let call_at = clock.now();
            let batch = b.next_batch(&rx).unwrap();
            let shipped_at = clock.now();
            let budget = first_enqueued
                .unwrap()
                .saturating_add(Duration::from_millis(max_wait_ms))
                .max(call_at);
            assert!(
                shipped_at <= budget,
                "case {case}: batch shipped at {shipped_at:?}, budget {budget:?} \
                 (max_wait {max_wait_ms} ms, n {n}, max_batch {max_batch})"
            );
            assert!(batch.len() <= max_batch, "case {case}: overfull batch");
            // everything queued must ship in FIFO batches: drain the rest
            let mut total = batch.len();
            while total < n {
                match b.next_batch(&rx) {
                    Some(more) => total += more.len(),
                    None => break,
                }
            }
            drop(tx);
            assert_eq!(total, n, "case {case}: requests lost by the batcher");
        }
    }
}
