//! Dynamic batching policy: max-batch-or-max-wait, the same policy the
//! serving systems the paper's efficiency claims target (vLLM-style
//! routers) use for non-autoregressive models.

use super::Request;
use std::sync::mpsc::Receiver;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait: Duration::from_millis(5) }
    }
}

pub struct Batcher {
    pub policy: BatchPolicy,
}

impl Batcher {
    /// Collect the next batch. Blocks for the first request; then drains
    /// until max_batch or until the first request has aged max_wait
    /// **counted from its `enqueued` timestamp**, not from when `recv`
    /// returned — a request that already sat in the channel while the
    /// executor was busy must not wait the full `max_wait` again. A
    /// request aged past the budget still gets one non-blocking drain of
    /// whatever is already queued (batching stays free when the queue is
    /// deep). Returns None when the channel is closed and drained.
    pub fn next_batch(&self, rx: &Receiver<Request>) -> Option<Vec<Request>> {
        let first = rx.recv().ok()?;
        // clamped to now: an over-aged first request makes the deadline
        // "immediately", never a deadline in the past
        let deadline = (first.enqueued + self.policy.max_wait).max(Instant::now());
        let mut batch = vec![first];
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                // wait budget spent: take what is queued, without blocking
                match rx.try_recv() {
                    Ok(req) => batch.push(req),
                    Err(_) => break,
                }
                continue;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(_) => break, // timeout or disconnect: ship what we have
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req() -> (Request, std::sync::mpsc::Receiver<super::super::Response>) {
        let (tx, rx) = channel();
        (
            Request {
                input_ids: vec![1, 2, 3],
                segment_ids: vec![0, 0, 0],
                reply: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn collects_up_to_max_batch() {
        let (tx, rx) = channel();
        let mut keep = Vec::new();
        for _ in 0..5 {
            let (r, k) = req();
            keep.push(k);
            tx.send(r).unwrap();
        }
        let b = Batcher {
            policy: BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(50) },
        };
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 3);
        let batch2 = b.next_batch(&rx).unwrap();
        assert_eq!(batch2.len(), 2);
    }

    #[test]
    fn respects_max_wait() {
        let (tx, rx) = channel();
        let (r, _k) = req();
        tx.send(r).unwrap();
        let b = Batcher {
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(10) },
        };
        let t = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn aged_request_does_not_wait_max_wait_again() {
        // the aging regression: a request that sat in the channel past
        // max_wait (executor busy) must ship immediately — after a
        // non-blocking drain of anything else already queued
        let Some(past) = Instant::now().checked_sub(Duration::from_secs(2)) else {
            return; // platform epoch too close to boot; nothing to test
        };
        let (tx, rx) = channel();
        let (mut r1, _k1) = req();
        r1.enqueued = past;
        let (r2, _k2) = req();
        tx.send(r1).unwrap();
        tx.send(r2).unwrap();
        let b = Batcher {
            policy: BatchPolicy { max_batch: 64, max_wait: Duration::from_millis(500) },
        };
        let t = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 2, "queued request must ride the aged batch");
        assert!(
            t.elapsed() < Duration::from_millis(400),
            "aged request waited max_wait again: {:?}",
            t.elapsed()
        );
    }

    #[test]
    fn none_on_closed_channel() {
        let (tx, rx) = channel::<Request>();
        drop(tx);
        let b = Batcher { policy: BatchPolicy::default() };
        assert!(b.next_batch(&rx).is_none());
    }
}
