//! Serving coordinator: request queue -> dynamic batcher -> PJRT
//! executor, vLLM-router style.
//!
//! PJRT handles are not `Send`, so the server *owns* its Runtime on a
//! dedicated thread; clients talk to it through channels. The batcher
//! collects requests until either `max_batch` is reached or the oldest
//! request has waited `max_wait_ms` — the standard dynamic-batching
//! policy — then pads the batch to the artifact's fixed batch size and
//! executes one forward.

pub mod batcher;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use server::{ServerHandle, ServeStats};

/// One inference request: token ids + segments for a single sequence.
#[derive(Debug)]
pub struct Request {
    pub input_ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    /// where to deliver the logits
    pub reply: std::sync::mpsc::Sender<Response>,
    pub enqueued: std::time::Instant,
}

/// Logits for one sequence plus timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub queue_ms: f64,
    pub total_ms: f64,
}
