//! Serving coordinator: request queue -> dynamic batcher -> executor,
//! vLLM-router style.
//!
//! PJRT handles are not `Send`, so the server *owns* its executor on a
//! dedicated thread; clients talk to it through channels (`Submitter`
//! clones for concurrent producers). The batcher collects requests until
//! either `max_batch` is reached or the oldest request has waited
//! `max_wait_ms` — the standard dynamic-batching policy.
//!
//! Executors: the PJRT artifact path (`ServerHandle::spawn`) runs one
//! fused forward per padded batch; the CPU fallback
//! (`ServerHandle::spawn_cpu`) runs the pure-Rust encoder + attention
//! zoo, fanning the batch's requests across a worker `ThreadPool` while
//! each request keeps its multi-head fan-out serial — one parallelism
//! grain per pool (see `attention::engine` for the deadlock rule).

pub mod batcher;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use server::{CpuServeConfig, ServeStats, ServerHandle, Submitter};

/// One inference request: token ids + segments for a single sequence.
#[derive(Debug)]
pub struct Request {
    pub input_ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    /// where to deliver the logits
    pub reply: std::sync::mpsc::Sender<Response>,
    pub enqueued: std::time::Instant,
}

/// Logits for one sequence plus timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub queue_ms: f64,
    pub total_ms: f64,
}
