//! Serving stack: single-loop coordinator (`server`), multi-replica
//! gateway (`gateway`), the shared scheduling core (`sched`), the time
//! abstraction (`clock`), and the deterministic scheduling simulator
//! (`sim`) — one batcher, stats, and determinism contract across all of
//! it.
//!
//! # Architecture
//!
//! * [`server::ServerHandle`] — the single serve loop. The PJRT artifact
//!   path (`spawn`) owns its non-`Send` executor on one thread; the CPU
//!   fallback (`spawn_cpu`) runs the pure-Rust encoder + attention zoo,
//!   fanning each batch's requests across a work-stealing `ThreadPool`
//!   (heads stay serial inside a request job — one parallelism grain per
//!   pool, see `attention::engine` for the deadlock rule).
//! * [`gateway::Gateway`] — the production front door over the CPU path:
//!   **N replica workers**, each owning its own params handle, attention
//!   instance, and pool shard; a **bounded queue** with a
//!   [`gateway::ShedPolicy`] (reject-with-retry-hint or block) so
//!   overload sheds instead of stacking unbounded latency;
//!   **length-bucketed batching** ([`gateway::BucketLayout`]) so batches
//!   group similar-cost requests; a **[`sched::SchedPolicy`]** choosing
//!   between the work-conserving deadline-aware scheduler (`Conserve`,
//!   default: idle replicas serve the globally most urgent deadline
//!   first and the deepest bucket otherwise, deadline-earliest-first
//!   within a bucket, partial batches never park while work exists) and
//!   the globally-FIFO A/B baseline (`Fifo`);
//!   **per-bucket batch policies** ([`sched::BatchPolicyTable`], keyed
//!   by bucket width — narrow buckets batch wider and wait shorter);
//!   **deadline-aware dequeue** (expired requests shed before execution,
//!   always reported); a **graceful-degradation ladder**
//!   ([`sched::DegradeLadder`] + per-request [`gateway::Quality`]
//!   classes: under overload, best-effort traffic steps down to fewer
//!   hash rounds — exact m'-prefix readouts, see `attention::stream` —
//!   before the deadline shedder sheds users, and
//!   `GatewayConfig::admission_edf` rejects already-infeasible deadlines
//!   at the door); and **live latency histograms**
//!   (`metrics::Histogram`) merged into [`gateway::GatewayStats`] at
//!   shutdown.
//! * [`sched`] — the scheduling decisions (bucket pick, within-bucket
//!   order, expiry sheds, per-bucket policy resolution, EWMA backlog
//!   estimation, and the degradation-ladder controller) as pure code
//!   over payload-generic queues, run bit-for-bit by both the live
//!   gateway replicas and the simulator.
//! * [`clock`] — the [`clock::Clock`] trait with wall
//!   ([`clock::SystemClock`]) and manually-advanced virtual
//!   ([`clock::SimClock`]) implementations. Every `serve` timestamp is a
//!   [`clock::Tick`] off an injected clock; nothing in this subsystem
//!   calls `Instant::now()` directly.
//! * [`sim`] — a deterministic discrete-event simulator over the
//!   scheduling core on a `SimClock`: scripted arrival traces, replicas
//!   that "execute" in simulated service time, and exact assertions on
//!   scheduling decisions (work conservation, deadline ordering, shed
//!   accounting) with zero wall-clock sleeps (`tests/sim_gateway.rs`).
//! * **Observability** — both executors emit the same typed
//!   flight-recorder events (admitted/queued/batch_formed/exec/replied/
//!   shed) into a per-lane ring-buffer `obs::TraceSink`
//!   (`GatewayConfig::trace` / [`sim::run_traced`], default off, env
//!   opt-in via `YOSO_TRACE`); `crate::obs` exports Chrome trace-event
//!   timelines, Prometheus text snapshots, and a `metrics::Recorder`
//!   bridge, and the fused kernel's per-arena phase timers land in the
//!   same timeline. `tests/trace_reconcile.rs` proves the event stream
//!   reconciles exactly with [`gateway::GatewayStats`] / `sim::SimReport`
//!   on both executors.
//!
//! # Batching policy
//!
//! [`Batcher`] collects until `max_batch` or until the *oldest* request
//! has aged `max_wait` counted from its enqueue time (a request that
//! already waited in the channel never waits the budget twice); the
//! gateway applies the same aging rule per bucket, with the per-bucket
//! policy from its `BatchPolicyTable`, and — under `Conserve` — cuts
//! the wait short whenever other buckets hold work.
//!
//! # Determinism contract
//!
//! CPU-path logits are a pure function of (config seed, request
//! content): the compute width is the content-canonical
//! `model::encoder::bucket_len` and randomness comes from the
//! width-keyed serving RNG stream (`model::encoder::serving_rng`), so
//! any two requests sharing a width share their hash draws — which is
//! what lets a streamed session extend a cached prefix bit-identically.
//! Batch placement, bucket layout, replica count, thread count, arrival
//! order, the YOSO kernel variant (`CpuServeConfig::kernel`), the
//! scheduling policy (`SchedPolicy`), and the gateway's prefix cache
//! ([`cache::PrefixCache`] — a hit replays the exact computation it
//! skips) are all wall-clock knobs only — the gateway property test
//! asserts bit-identity against the single-loop path across all of
//! them. Quality classes refine, not break, the contract:
//! `Quality::Full` and `Quality::Degraded(m')` logits are pure
//! functions of (seed, content, m') — a degraded readout is
//! bit-identical to a fresh forward configured at `m'` — while
//! `Quality::BestEffort` (the default) additionally depends on the load
//! the overload controller reacted to, the one documented exception.
//!
//! # Steady-state allocation
//!
//! With the default fused kernel, every long-lived worker (pool worker,
//! gateway replica) serves YOSO forwards out of a warm thread-local
//! `KernelArena`: the kernel's internal scratch — bucket table, codes,
//! hasher storage, sort buffers, normalized q/k copies — allocates
//! nothing after warm-up (`tests/alloc_kernel.rs` asserts zero for the
//! arena entry point). Per-request output buffers (the attention output
//! `Mat`, encoder activations, the logits vec) are still allocated per
//! forward.
//!
//! # Robustness
//!
//! No admitted request is lost. A panic inside one request's forward is
//! caught at the request boundary (the submitter gets a terminal
//! [`gateway::Shed::InternalError`]; batch-mates are unaffected); a
//! dead replica worker is detected by its supervisor, its in-flight
//! batch requeued under a bounded per-request retry budget, and the
//! worker respawned; poisoned shared state (queue mutex, prefix cache)
//! is recovered with a consistency sweep instead of cascading the
//! panic; and a prefix-cache session abandoned mid-encode is discarded
//! via its [`cache::SessionLease`] drop-guard, never published
//! corrupted. The whole contract is exercised deterministically by the
//! seeded [`fault::FaultPlan`] injection harness (`YOSO_FAULT_SEED`),
//! in both the live gateway and [`sim::run_faulted`]
//! (`tests/chaos_gateway.rs`).
//!
//! # Shutdown
//!
//! `shutdown` closes admission explicitly and drains what was accepted:
//! outstanding `Submitter`/`GatewaySubmitter` clones never pin the
//! server open, and post-shutdown submits fail fast.

pub mod batcher;
pub mod cache;
pub mod clock;
pub mod fault;
pub mod gateway;
pub mod sched;
pub mod server;
pub mod sim;

pub use batcher::{BatchPolicy, Batcher};
pub use cache::PrefixCache;
pub use clock::{Clock, SimClock, SystemClock, Tick};
pub use fault::{FaultKind, FaultPlan};
pub use gateway::{
    await_reply, BucketLayout, Gateway, GatewayConfig, GatewayReply,
    GatewayStats, GatewaySubmitter, Quality, ReplicaStats, Shed,
    ShedPolicy,
};
pub use sched::{
    BatchPolicyTable, DegradeLadder, DegradePlan, LadderState, SchedPolicy,
    Sharding,
};
pub use server::{CpuServeConfig, ServeStats, ServerHandle, Submitter};

/// One inference request: token ids + segments for a single sequence.
#[derive(Debug)]
pub struct Request {
    pub input_ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    /// where to deliver the logits
    pub reply: std::sync::mpsc::Sender<Response>,
    /// submission instant on the server's [`Clock`]
    pub enqueued: Tick,
}

/// Logits for one sequence plus timing and the served-at quality: the
/// client sees *what it actually got* — the hash-round count its logits
/// were computed with and the quality class that count realized — not
/// just aggregate gateway stats after the fact.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    /// Hash rounds these logits were computed with. Equal to the
    /// configured full `m` unless the request was served degraded
    /// (pinned `Quality::Degraded(m')`, or `BestEffort` stepped down by
    /// the overload ladder). The single-loop `server` paths always
    /// serve full quality; the artifact path, whose round count is
    /// baked into the HLO and invisible to the server, reports 0.
    pub m_served: usize,
    /// The quality class realized: `Full` when `m_served` equals the
    /// configured full `m`, otherwise `Degraded(m_served)`. A
    /// `BestEffort` submission served at full rounds reports `Full`.
    pub quality: Quality,
    /// How many times this request was pulled back out of a dying
    /// replica's batch and requeued before it was served. 0 on the
    /// clean path; a non-zero count tells the client its latency
    /// included supervised recovery, not just queueing. The single-loop
    /// `server` paths never requeue and always report 0.
    pub retries: u32,
}
