//! Serving stack: single-loop coordinator (`server`) and multi-replica
//! gateway (`gateway`), sharing one batcher, stats, and determinism
//! contract.
//!
//! # Architecture
//!
//! * [`server::ServerHandle`] — the single serve loop. The PJRT artifact
//!   path (`spawn`) owns its non-`Send` executor on one thread; the CPU
//!   fallback (`spawn_cpu`) runs the pure-Rust encoder + attention zoo,
//!   fanning each batch's requests across a work-stealing `ThreadPool`
//!   (heads stay serial inside a request job — one parallelism grain per
//!   pool, see `attention::engine` for the deadlock rule).
//! * [`gateway::Gateway`] — the production front door over the CPU path:
//!   **N replica workers**, each owning its own params handle, attention
//!   instance, and pool shard; a **bounded queue** with a
//!   [`gateway::ShedPolicy`] (reject-with-retry-hint or block) so
//!   overload sheds instead of stacking unbounded latency;
//!   **length-bucketed batching** ([`gateway::BucketLayout`]) so batches
//!   group similar-cost requests; **deadline-aware dequeue** (expired
//!   requests shed before execution, always reported); and **live
//!   latency histograms** (`metrics::Histogram`) merged into
//!   [`gateway::GatewayStats`] at shutdown.
//!
//! # Batching policy
//!
//! [`Batcher`] collects until `max_batch` or until the *oldest* request
//! has aged `max_wait` counted from its enqueue time (a request that
//! already waited in the channel never waits the budget twice); the
//! gateway applies the same aging rule per bucket.
//!
//! # Determinism contract
//!
//! CPU-path logits are a pure function of (config seed, request
//! content): randomness comes from the content-hash RNG stream and the
//! compute width is the content-canonical `model::encoder::bucket_len`.
//! Batch placement, bucket layout, replica count, thread count, arrival
//! order, and the YOSO kernel variant (`CpuServeConfig::kernel`; seed vs
//! fused, see `attention::kernel`) are all wall-clock knobs only — the
//! gateway property test asserts bit-identity against the single-loop
//! path across all of them.
//!
//! # Steady-state allocation
//!
//! With the default fused kernel, every long-lived worker (pool worker,
//! gateway replica) serves YOSO forwards out of a warm thread-local
//! `KernelArena`: the kernel's internal scratch — bucket table, codes,
//! hasher storage, sort buffers, normalized q/k copies — allocates
//! nothing after warm-up (`tests/alloc_kernel.rs` asserts zero for the
//! arena entry point). Per-request output buffers (the attention output
//! `Mat`, encoder activations, the logits vec) are still allocated per
//! forward.
//!
//! # Shutdown
//!
//! `shutdown` closes admission explicitly and drains what was accepted:
//! outstanding `Submitter`/`GatewaySubmitter` clones never pin the
//! server open, and post-shutdown submits fail fast.

pub mod batcher;
pub mod gateway;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use gateway::{
    BucketLayout, Gateway, GatewayConfig, GatewayReply, GatewayStats,
    GatewaySubmitter, ReplicaStats, Shed, ShedPolicy,
};
pub use server::{CpuServeConfig, ServeStats, ServerHandle, Submitter};

/// One inference request: token ids + segments for a single sequence.
#[derive(Debug)]
pub struct Request {
    pub input_ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    /// where to deliver the logits
    pub reply: std::sync::mpsc::Sender<Response>,
    pub enqueued: std::time::Instant,
}

/// Logits for one sequence plus timing.
#[derive(Clone, Debug)]
pub struct Response {
    pub logits: Vec<f32>,
    pub queue_ms: f64,
    pub total_ms: f64,
}
