//! Pure-Rust inference encoder (forward only) over the attention library.
//!
//! Consumes a `ParamSet` (freshly initialized or loaded from a training
//! checkpoint) and runs the same post-LN BERT architecture as the L2
//! model. Used by the serving CPU fallback, the attention-matrix dump
//! (Figure 6), and the efficiency study's full-model rows.

use super::params::ParamSet;
use crate::attention::{
    Attention, HeadTask, MultiHeadAttention, YosoAttention, YosoStream,
};
use crate::data::special;
use crate::runtime::manifest::{ArtifactSpec, Dtype, IoSpec};
use crate::tensor::{gelu, Mat};
use crate::util::Rng;
use std::sync::Arc;

#[derive(Clone, Debug)]
pub struct EncoderConfig {
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub max_len: usize,
    pub n_classes: usize,
}

impl EncoderConfig {
    /// The shared encoder geometry of all artifact families. `max_len`
    /// must be a power of two: every canonical compute width
    /// ([`bucket_len`]) is one, the serving prefix cache keys on it, and
    /// the attention zoo's FFT/Hadamard variants require it — a non-pow2
    /// cap would silently break all three (see [`pow2_floor`] for the
    /// serving entry points that floor a foreign config instead).
    pub fn base(vocab_size: usize, max_len: usize, n_classes: usize) -> Self {
        assert!(
            max_len.is_power_of_two(),
            "max_len must be a power of two, got {max_len}"
        );
        EncoderConfig {
            n_layers: 2,
            d_model: 128,
            n_heads: 2,
            d_ff: 512,
            vocab_size,
            max_len,
            n_classes,
        }
    }

    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }
}

/// The encoder's parameter ABI as an `ArtifactSpec` — the same `param:*`
/// slot list `aot.py` emits for this geometry. Lets the pure-Rust paths
/// (CPU-fallback serving, tests) initialize a `ParamSet` without an
/// artifacts directory.
pub fn encoder_abi_spec(cfg: &EncoderConfig) -> ArtifactSpec {
    let d = cfg.d_model;
    let mut inputs = Vec::new();
    let mut add = |name: &str, shape: Vec<usize>| {
        inputs.push(IoSpec {
            name: format!("param:{name}"),
            shape,
            dtype: Dtype::F32,
        });
    };
    add("tok_emb", vec![cfg.vocab_size, d]);
    add("pos_emb", vec![cfg.max_len, d]);
    add("seg_emb", vec![2, d]);
    add("emb_ln_g", vec![d]);
    add("emb_ln_b", vec![d]);
    for l in 0..cfg.n_layers {
        for (n, s) in [
            ("wq", vec![d, d]),
            ("bq", vec![d]),
            ("wk", vec![d, d]),
            ("bk", vec![d]),
            ("wv", vec![d, d]),
            ("bv", vec![d]),
            ("wo", vec![d, d]),
            ("bo", vec![d]),
            ("ln1_g", vec![d]),
            ("ln1_b", vec![d]),
            ("ff1_w", vec![d, cfg.d_ff]),
            ("ff1_b", vec![cfg.d_ff]),
            ("ff2_w", vec![cfg.d_ff, d]),
            ("ff2_b", vec![d]),
            ("ln2_g", vec![d]),
            ("ln2_b", vec![d]),
        ] {
            add(&format!("layer{l}.{n}"), s);
        }
    }
    add("mlm_w", vec![d, d]);
    add("mlm_b", vec![d]);
    add("mlm_ln_g", vec![d]);
    add("mlm_ln_b", vec![d]);
    add("mlm_out_b", vec![cfg.vocab_size]);
    add("pool_w", vec![d, d]);
    add("pool_b", vec![d]);
    add("sop_w", vec![d, 2]);
    add("sop_b", vec![2]);
    add("cls_w", vec![d, cfg.n_classes]);
    add("cls_b", vec![cfg.n_classes]);
    ArtifactSpec {
        name: "encoder_abi".into(),
        file: "/dev/null".into(),
        kind: "forward".into(),
        family: "cpu".into(),
        attention: "any".into(),
        inputs,
        outputs: vec![],
        config: Default::default(),
    }
}

pub struct Encoder<'a> {
    pub cfg: EncoderConfig,
    params: std::collections::BTreeMap<&'a str, (&'a [usize], &'a [f32])>,
}

impl<'a> Encoder<'a> {
    pub fn new(cfg: EncoderConfig, params: &'a ParamSet) -> Encoder<'a> {
        Encoder { cfg, params: params.by_name() }
    }

    fn p(&self, name: &str) -> (&[usize], &[f32]) {
        *self
            .params
            .get(name)
            .unwrap_or_else(|| panic!("missing param {name}"))
    }

    fn mat(&self, name: &str) -> Mat {
        let (shape, data) = self.p(name);
        assert_eq!(shape.len(), 2, "{name} not a matrix");
        Mat::from_vec(shape[0], shape[1], data.to_vec())
    }

    fn vec(&self, name: &str) -> &[f32] {
        self.p(name).1
    }

    /// Dense layer: x @ W + b.
    fn dense(&self, x: &Mat, w: &str, b: &str) -> Mat {
        let wm = self.mat(w);
        let bias = self.vec(b);
        let mut out = x.matmul(&wm);
        for i in 0..out.rows {
            for (o, bb) in out.row_mut(i).iter_mut().zip(bias) {
                *o += bb;
            }
        }
        out
    }

    /// Token + position + segment embeddings, layer-normed. ids: (n,).
    pub fn embed(&self, ids: &[i32], segs: &[i32]) -> Mat {
        self.embed_rows_at(ids, segs, 0)
    }

    /// `embed` for tokens sitting at sequence positions
    /// `offset..offset + ids.len()` — every step (lookup sum, layer
    /// norm) is row-local, so these rows are bit-identical to the same
    /// rows of a full-sequence `embed`. The incremental path
    /// ([`EncoderStream`]) embeds appended chunks through this.
    fn embed_rows_at(&self, ids: &[i32], segs: &[i32], offset: usize) -> Mat {
        let d = self.cfg.d_model;
        let (_, tok) = self.p("tok_emb");
        let (_, pos) = self.p("pos_emb");
        let (_, seg) = self.p("seg_emb");
        let n = ids.len();
        let mut x = Mat::zeros(n, d);
        for i in 0..n {
            let t = ids[i].max(0) as usize;
            let s = segs[i].max(0) as usize;
            let row = x.row_mut(i);
            for j in 0..d {
                row[j] = tok[t * d + j] + pos[(offset + i) * d + j] + seg[s * d + j];
            }
        }
        x.layer_norm(self.vec("emb_ln_g"), self.vec("emb_ln_b"))
    }

    /// Full encoder forward for one sequence (serial head loop via the
    /// batched `Attention::forward_batch` API). Advances `rng` once, so
    /// repeated calls draw fresh randomness (fresh hash functions for
    /// stochastic attention) like the pre-batched head loop did.
    pub fn forward(&self, ids: &[i32], segs: &[i32], attn: &dyn Attention,
                   rng: &mut Rng) -> Mat {
        let call = Rng::new(rng.next_u64());
        let mut x = self.embed(ids, segs);
        for l in 0..self.cfg.n_layers {
            x = self.layer_with(l, &x, &call, &mut |heads, base| {
                attn.forward_batch(&heads, base)
            });
        }
        x
    }

    /// Engine-parallel forward: head fan-out on `mh`'s pool. Bit-identical
    /// to `forward` for the same seed — both derive head `i` of layer `l`
    /// from the same per-call stream via `fold_in(l).fold_in(i)`. The
    /// engine's `ChunkPolicy` rides along in `mh` (it shapes YOSO hash
    /// fan-out and workspace accounting at the engine level, never the
    /// per-head streams), so thread count and policy stay wall-clock
    /// knobs here.
    pub fn forward_mh(&self, ids: &[i32], segs: &[i32],
                      attn: &Arc<dyn Attention>, mh: &MultiHeadAttention,
                      rng: &mut Rng) -> Mat {
        let call = Rng::new(rng.next_u64());
        let mut x = self.embed(ids, segs);
        for l in 0..self.cfg.n_layers {
            x = self.layer_with(l, &x, &call, &mut |heads, base| {
                mh.forward_batch(attn, heads, base)
            });
        }
        x
    }

    fn layer(&self, l: usize, x: &Mat, attn: &dyn Attention, rng: &mut Rng) -> Mat {
        let call = Rng::new(rng.next_u64());
        self.layer_with(l, x, &call, &mut |heads, base| {
            attn.forward_batch(&heads, base)
        })
    }

    /// One encoder layer; `run_heads` maps the per-head (q, k, v) tasks to
    /// per-head outputs (serial trait default or the pool-backed engine).
    /// `call` is the per-forward-call stream; layer `l` derives its head
    /// base from `call.fold_in(l)`.
    fn layer_with(&self, l: usize, x: &Mat, call: &Rng,
                  run_heads: &mut dyn FnMut(Vec<HeadTask>, &Rng) -> Vec<Mat>) -> Mat {
        let p = |s: &str| format!("layer{l}.{s}");
        let n = x.rows;
        let h = self.cfg.n_heads;
        let dh = self.cfg.d_head();

        let q = self.dense(x, &p("wq"), &p("bq"));
        let k = self.dense(x, &p("wk"), &p("bk"));
        let v = self.dense(x, &p("wv"), &p("bv"));

        let mut heads = Vec::with_capacity(h);
        for head in 0..h {
            let slice = |m: &Mat| {
                Mat::from_fn(n, dh, |i, j| m.at(i, head * dh + j))
            };
            heads.push(HeadTask { q: slice(&q), k: slice(&k), v: slice(&v) });
        }
        let base = call.fold_in(l as u64);
        let outs = run_heads(heads, &base);
        self.layer_tail(l, x, &outs)
    }

    /// Everything in layer `l` after the attention heads: concat + output
    /// projection, post-LN residual, feed-forward, second LN. Split out so
    /// the incremental path ([`EncoderStream`]), which produces its head
    /// outputs from streamed bucket tables instead of `run_heads`, shares
    /// the exact tail computation with `layer_with`.
    fn layer_tail(&self, l: usize, x: &Mat, outs: &[Mat]) -> Mat {
        let p = |s: &str| format!("layer{l}.{s}");
        let n = x.rows;
        let dh = self.cfg.d_head();
        let mut concat = Mat::zeros(n, self.cfg.d_model);
        for (head, out) in outs.iter().enumerate() {
            for i in 0..n {
                for j in 0..dh {
                    concat.set(i, head * dh + j, out.at(i, j));
                }
            }
        }
        let a = self.dense(&concat, &p("wo"), &p("bo"));

        // post-LN residual
        let mut res = x.clone();
        res.add_assign(&a);
        let x1 = res.layer_norm(self.vec(&p("ln1_g")), self.vec(&p("ln1_b")));

        let hidden = self.dense(&x1, &p("ff1_w"), &p("ff1_b")).map(gelu);
        let f = self.dense(&hidden, &p("ff2_w"), &p("ff2_b"));
        let mut res2 = x1.clone();
        res2.add_assign(&f);
        res2.layer_norm(self.vec(&p("ln2_g")), self.vec(&p("ln2_b")))
    }

    /// [CLS] pooler + classifier head over a final hidden state.
    fn pool_logits(&self, hidden: &Mat) -> Vec<f32> {
        let cls = Mat::from_vec(1, self.cfg.d_model, hidden.row(0).to_vec());
        let mut pooled = self.dense(&cls, "pool_w", "pool_b");
        for x in pooled.data.iter_mut() {
            *x = x.tanh();
        }
        let logits = self.dense(&pooled, "cls_w", "cls_b");
        logits.data
    }

    /// [CLS] pooler + classifier logits.
    pub fn classify(&self, ids: &[i32], segs: &[i32], attn: &dyn Attention,
                    rng: &mut Rng) -> Vec<f32> {
        let hidden = self.forward(ids, segs, attn, rng);
        self.pool_logits(&hidden)
    }

    /// `classify` over the engine-parallel forward.
    pub fn classify_mh(&self, ids: &[i32], segs: &[i32],
                       attn: &Arc<dyn Attention>, mh: &MultiHeadAttention,
                       rng: &mut Rng) -> Vec<f32> {
        let hidden = self.forward_mh(ids, segs, attn, mh, rng);
        self.pool_logits(&hidden)
    }

    /// Bucket-width forward entry: pad/truncate the (unpadded) request to
    /// `width` and classify. The forward runs over `width` rows, so a
    /// short request costs O(width·…) instead of O(max_len·…) — the
    /// serving paths pass [`bucket_len`] of the request's own length
    /// here, which makes the padded content (and hence the logits of a
    /// content-seeded `rng`) a pure function of the request, independent
    /// of batching, replica, or arrival order.
    pub fn classify_bucketed(&self, ids: &[i32], segs: &[i32], width: usize,
                             attn: &Arc<dyn Attention>, mh: &MultiHeadAttention,
                             rng: &mut Rng) -> Vec<f32> {
        assert!(
            width <= self.cfg.max_len,
            "bucket width {width} exceeds max_len {}",
            self.cfg.max_len
        );
        let (ids, segs) = pad_to(ids, segs, width);
        self.classify_mh(&ids, &segs, attn, mh, rng)
    }

    /// Per-head (q, k) projections of layer `l` — the Figure 6 probe.
    pub fn layer_qk(&self, l: usize, ids: &[i32], segs: &[i32], head: usize,
                    attn: &dyn Attention, rng: &mut Rng) -> (Mat, Mat) {
        let mut x = self.embed(ids, segs);
        for li in 0..l {
            x = self.layer(li, &x, attn, rng);
        }
        let p = |s: &str| format!("layer{l}.{s}");
        let q = self.dense(&x, &p("wq"), &p("bq"));
        let k = self.dense(&x, &p("wk"), &p("bk"));
        let dh = self.cfg.d_head();
        let n = x.rows;
        let qh = Mat::from_fn(n, dh, |i, j| q.at(i, head * dh + j));
        let kh = Mat::from_fn(n, dh, |i, j| k.at(i, head * dh + j));
        (qh, kh)
    }
}

/// Largest power of two <= `n` (0 for 0).
pub fn pow2_floor(n: usize) -> usize {
    if n == 0 {
        0
    } else {
        1usize << (usize::BITS - 1 - n.leading_zeros())
    }
}

/// Canonical compute width for a request of `len` tokens: the smallest
/// power of two >= `len`, floored at 8 and capped at `max_len`. A pure
/// function of the request's own length — never of which serving bucket
/// it was grouped into — so logits stay bit-identical under every bucket
/// layout (the gateway determinism contract). Power-of-two widths keep
/// the attention zoo's FFT/Hadamard variants constructible at any width,
/// and are what the serving prefix cache keys on — so a non-pow2
/// `max_len` cap is floored to a power of two rather than returned
/// verbatim (the serving entry points floor their whole config with
/// [`pow2_floor`] up front, so truncation agrees with this cap).
pub fn bucket_len(len: usize, max_len: usize) -> usize {
    let mut w = 8usize;
    while w < len {
        w *= 2;
    }
    w.min(pow2_floor(max_len))
}

/// The serving RNG stream: a pure function of (config seed, canonical
/// compute width). Width-keyed — not content-keyed — so every request
/// landing at the same `bucket_len` width shares its hash functions,
/// which is what lets the gateway prefix cache reuse a session's bucket
/// tables across requests (`serve::cache`). Logits stay a pure function
/// of (seed, content): the width itself is content-canonical. The trade,
/// relative to a per-content stream, is that same-width requests share
/// hash-function randomness instead of drawing independent samples —
/// fine for serving, where each request is classified once.
pub fn serving_rng(seed: u64, width: usize) -> Rng {
    Rng::new(seed).fold_in(width as u64)
}

/// Pad/truncate ids+segs to a model length.
pub fn pad_to(ids: &[i32], segs: &[i32], len: usize) -> (Vec<i32>, Vec<i32>) {
    let mut i = ids.to_vec();
    let mut s = segs.to_vec();
    i.resize(len, special::PAD);
    s.resize(len, 0);
    i.truncate(len);
    s.truncate(len);
    (i, s)
}

/// Append `src`'s rows to `dst` (same column count).
fn append_rows(dst: &mut Mat, src: &Mat) {
    assert_eq!(dst.cols, src.cols);
    dst.data.extend_from_slice(&src.data);
    dst.rows += src.rows;
}

/// Incremental encoder session at one canonical compute width: the
/// encoder-level owner of per-head [`YosoStream`]s, serving sliding-window
/// classification and long-document chunked encode without quadratic
/// re-encoding.
///
/// `append` costs O(per-token projections + m·dv table update) per new
/// token — layer-0 embeddings, q/k/v rows, and the per-head bucket-table
/// accumulations, all row-local, with **no** full-table rebuild and no
/// re-touching of earlier tokens (`tests/alloc_stream.rs` pins the
/// attention-level claim with the counting allocator). `classify` gathers
/// the stored layer-0 queries against the streamed tables (overlaying the
/// PAD tail of the bucketed width on scratch), then runs the remaining
/// layers densely: a bidirectional encoder's upper layers depend on every
/// token, so they are recomputed per classify — the streamed savings are
/// the layer-0 key/value side, which is exactly what grows with session
/// length.
///
/// **Bit-identity contract**: `classify` equals the batch serving path
/// (`classify_bucketed` at this width under the [`serving_rng`] stream)
/// byte-for-byte, regardless of how the session was chunked — property-
/// tested in `tests/prop_yoso_stream.rs`. This is what makes gateway
/// prefix caching (`serve::cache`) invisible to the determinism contract.
pub struct EncoderStream {
    att: YosoAttention,
    width: usize,
    /// the per-forward-call stream of the batch path, pinned at creation:
    /// layer `l`, head `i` derive `call.fold_in(l).fold_in(i)` exactly as
    /// `forward_mh` does
    call: Rng,
    ids: Vec<i32>,
    segs: Vec<i32>,
    /// layer-0 invariants of the appended tokens (row-local, so rows are
    /// final the moment a token arrives): embedded input and query rows
    x0: Mat,
    q0: Mat,
    /// one streamed bucket-table state per layer-0 head
    heads: Vec<YosoStream>,
    /// PAD-row caches for positions `pad_filled_from..width` (a PAD row
    /// at a position is config-constant, so it is computed once, lazily,
    /// as the needed tail shrinks toward the session length)
    pad_x: Mat,
    pad_q: Mat,
    pad_k: Mat,
    pad_v: Mat,
    pad_filled_from: usize,
}

impl EncoderStream {
    /// A fresh session at `width` (a power of two <= `max_len`), drawing
    /// hashers from the same [`serving_rng`] stream the batch path uses
    /// at this width.
    pub fn new(
        enc: &Encoder,
        att: &YosoAttention,
        seed: u64,
        width: usize,
    ) -> EncoderStream {
        assert!(
            width <= enc.cfg.max_len,
            "stream width {width} exceeds max_len {}",
            enc.cfg.max_len
        );
        assert!(width.is_power_of_two(), "stream width must be a power of two");
        let mut rng = serving_rng(seed, width);
        // the batch path's per-call stream: forward_mh's Rng::new(next_u64)
        let call = Rng::new(rng.next_u64());
        let base = call.fold_in(0u64);
        let dh = enc.cfg.d_head();
        let heads = (0..enc.cfg.n_heads)
            .map(|i| {
                let mut r = base.fold_in(i as u64);
                YosoStream::new(att, dh, dh, &mut r)
            })
            .collect();
        let d = enc.cfg.d_model;
        EncoderStream {
            att: att.clone(),
            width,
            call,
            ids: Vec::new(),
            segs: Vec::new(),
            x0: Mat::zeros(0, d),
            q0: Mat::zeros(0, d),
            heads,
            pad_x: Mat::zeros(width, d),
            pad_q: Mat::zeros(width, d),
            pad_k: Mat::zeros(width, d),
            pad_v: Mat::zeros(width, d),
            pad_filled_from: width,
        }
    }

    /// Tokens appended so far.
    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    /// The canonical compute width this session is pinned to.
    pub fn width(&self) -> usize {
        self.width
    }

    pub fn ids(&self) -> &[i32] {
        &self.ids
    }

    pub fn segs(&self) -> &[i32] {
        &self.segs
    }

    /// Approximate resident bytes — the prefix cache's eviction currency.
    pub fn approx_bytes(&self) -> usize {
        let mats = self.x0.data.len()
            + self.q0.data.len()
            + self.pad_x.data.len()
            + self.pad_q.data.len()
            + self.pad_k.data.len()
            + self.pad_v.data.len();
        mats * 4
            + (self.ids.len() + self.segs.len()) * 4
            + self.heads.iter().map(|h| h.approx_bytes()).sum::<usize>()
    }

    /// Fold new tokens into the session: embed at their absolute
    /// positions, project layer-0 q/k/v rows, and accumulate each head's
    /// key/value rows into its bucket tables. Per-token cost is
    /// independent of the session length — nothing already appended is
    /// touched.
    pub fn append(&mut self, enc: &Encoder, new_ids: &[i32], new_segs: &[i32]) {
        assert_eq!(new_ids.len(), new_segs.len());
        let t = new_ids.len();
        if t == 0 {
            return;
        }
        let n = self.ids.len();
        assert!(
            n + t <= self.width,
            "append past stream width {} (have {n}, adding {t})",
            self.width
        );
        let x_new = enc.embed_rows_at(new_ids, new_segs, n);
        let q_new = enc.dense(&x_new, "layer0.wq", "layer0.bq");
        let k_new = enc.dense(&x_new, "layer0.wk", "layer0.bk");
        let v_new = enc.dense(&x_new, "layer0.wv", "layer0.bv");
        let dh = enc.cfg.d_head();
        for (i, head) in self.heads.iter_mut().enumerate() {
            let kh = Mat::from_fn(t, dh, |r, c| k_new.at(r, i * dh + c));
            let vh = Mat::from_fn(t, dh, |r, c| v_new.at(r, i * dh + c));
            head.append(&kh, &vh);
        }
        append_rows(&mut self.x0, &x_new);
        append_rows(&mut self.q0, &q_new);
        self.ids.extend_from_slice(new_ids);
        self.segs.extend_from_slice(new_segs);
    }

    /// Lazily extend the PAD caches down to the current session length:
    /// position `p`'s PAD row never changes, so each is computed once
    /// even as successive classifies need shorter tails.
    fn fill_pads(&mut self, enc: &Encoder) {
        let n = self.ids.len();
        if n >= self.pad_filled_from {
            return;
        }
        let cnt = self.pad_filled_from - n;
        let pids = vec![special::PAD; cnt];
        let psegs = vec![0i32; cnt];
        let px = enc.embed_rows_at(&pids, &psegs, n);
        let pq = enc.dense(&px, "layer0.wq", "layer0.bq");
        let pk = enc.dense(&px, "layer0.wk", "layer0.bk");
        let pv = enc.dense(&px, "layer0.wv", "layer0.bv");
        for local in 0..cnt {
            let p = n + local;
            self.pad_x.row_mut(p).copy_from_slice(px.row(local));
            self.pad_q.row_mut(p).copy_from_slice(pq.row(local));
            self.pad_k.row_mut(p).copy_from_slice(pk.row(local));
            self.pad_v.row_mut(p).copy_from_slice(pv.row(local));
        }
        self.pad_filled_from = n;
    }

    /// Hash rounds the session absorbs at — the ceiling for `m_read` in
    /// the `_at` readouts.
    pub fn m(&self) -> usize {
        self.att.m
    }

    /// Full-width hidden states against the current session: layer 0
    /// gathers the stored queries from the streamed tables (PAD tail
    /// overlaid on scratch — session state is untouched, so this is
    /// repeatable), remaining layers run densely on the batch path's
    /// exact code. Bit-identical to `forward_mh` over the padded session
    /// at this width under [`serving_rng`].
    pub fn hidden(&mut self, enc: &Encoder) -> Mat {
        self.hidden_at(enc, self.att.m)
    }

    /// [`EncoderStream::hidden`], read at `m_read ≤ m` hash rounds — the
    /// serving degradation ladder's readout. Layer 0 gathers only the
    /// first `m_read` bucket tables (the m'-prefix contract in
    /// `attention::stream`) and the upper layers run their attention at
    /// `m_read` rounds, so the result is **bit-identical to a fresh
    /// `m_read`-round bucketed encode** of the same prefix at this width
    /// under [`serving_rng`] — not a mutation of the session, which
    /// stays absorbed at the full `m`.
    pub fn hidden_at(&mut self, enc: &Encoder, m_read: usize) -> Mat {
        self.fill_pads(enc);
        let n = self.ids.len();
        let w = self.width;
        let d = enc.cfg.d_model;
        let dh = enc.cfg.d_head();
        let tail = w - n;
        let x0 = &self.x0;
        let q0 = &self.q0;
        let (pad_x, pad_q) = (&self.pad_x, &self.pad_q);
        let x_full = Mat::from_fn(w, d, |i, j| {
            if i < n { x0.at(i, j) } else { pad_x.at(i, j) }
        });
        let q_full = Mat::from_fn(w, d, |i, j| {
            if i < n { q0.at(i, j) } else { pad_q.at(i, j) }
        });
        let (pad_k, pad_v) = (&self.pad_k, &self.pad_v);
        let mut outs = Vec::with_capacity(self.heads.len());
        for (i, head) in self.heads.iter_mut().enumerate() {
            let qh = Mat::from_fn(w, dh, |r, c| q_full.at(r, i * dh + c));
            let tkh = Mat::from_fn(tail, dh, |r, c| pad_k.at(n + r, i * dh + c));
            let tvh = Mat::from_fn(tail, dh, |r, c| pad_v.at(n + r, i * dh + c));
            let mut out = Mat::zeros(w, dh);
            head.finish_with_tail_into(&qh, &tkh, &tvh, m_read, &mut out);
            outs.push(out);
        }
        let mut x = enc.layer_tail(0, &x_full, &outs);
        // upper layers draw fresh hashers per call, so running them on
        // an m_read-round clone reproduces a fresh m_read-forward's
        // bytes exactly (same fold_in streams, shorter draw)
        let att_read = YosoAttention { m: m_read, ..self.att.clone() };
        for l in 1..enc.cfg.n_layers {
            x = enc.layer_with(l, &x, &self.call, &mut |heads, base| {
                att_read.forward_batch(&heads, base)
            });
        }
        x
    }

    /// [CLS] logits against the current session — the streamed
    /// equivalent of `classify_bucketed` at this width.
    pub fn classify(&mut self, enc: &Encoder) -> Vec<f32> {
        self.classify_at(enc, self.att.m)
    }

    /// [CLS] logits read at `m_read ≤ m` hash rounds: bit-identical to
    /// `classify_bucketed` at this width with an attention degraded to
    /// `m == m_read`, with zero session mutation (see
    /// [`EncoderStream::hidden_at`]).
    pub fn classify_at(&mut self, enc: &Encoder, m_read: usize) -> Vec<f32> {
        let hidden = self.hidden_at(enc, m_read);
        enc.pool_logits(&hidden)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{ChunkPolicy, Engine, SoftmaxAttention, YosoAttention};
    use crate::testing::test_threads;

    #[test]
    fn forward_shapes_and_finiteness() {
        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 0);
        let enc = Encoder::new(cfg, &params);
        let ids: Vec<i32> = (0..16).map(|i| (i % 60) + 5).collect();
        let segs = vec![0i32; 16];
        let mut rng = Rng::new(1);
        let h = enc.forward(&ids, &segs, &SoftmaxAttention, &mut rng);
        assert_eq!((h.rows, h.cols), (16, 128));
        assert!(h.data.iter().all(|x| x.is_finite()));
        let logits = enc.classify(&ids, &segs, &SoftmaxAttention, &mut rng);
        assert_eq!(logits.len(), 3);
    }

    #[test]
    fn qk_probe_shapes() {
        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 0);
        let enc = Encoder::new(cfg, &params);
        let ids = vec![5i32; 16];
        let segs = vec![0i32; 16];
        let mut rng = Rng::new(2);
        let (q, k) = enc.layer_qk(1, &ids, &segs, 0, &SoftmaxAttention, &mut rng);
        assert_eq!((q.rows, q.cols), (16, 64));
        assert_eq!((k.rows, k.cols), (16, 64));
    }

    #[test]
    fn pooled_forward_bit_identical_to_serial() {
        // Stochastic attention: identical bytes prove the fold_in head
        // streams make thread count irrelevant end-to-end.
        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 3);
        let enc = Encoder::new(cfg, &params);
        let ids: Vec<i32> = (0..16).map(|i| (i % 60) + 5).collect();
        let segs = vec![0i32; 16];
        let attn: Arc<dyn Attention> =
            Arc::new(YosoAttention::new(5, 8, false));
        let mut rng1 = Rng::new(7);
        let serial = enc.forward(&ids, &segs, attn.as_ref(), &mut rng1);
        let mh = MultiHeadAttention::new(Engine::new(test_threads(3)));
        let mut rng2 = Rng::new(7);
        let pooled = enc.forward_mh(&ids, &segs, &attn, &mh, &mut rng2);
        assert_eq!(serial.data.len(), pooled.data.len());
        for (a, b) in serial.data.iter().zip(&pooled.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut rng3 = Rng::new(7);
        let logits = enc.classify_mh(&ids, &segs, &attn, &mh, &mut rng3);
        assert_eq!(logits.len(), 3);
        // the chunk policy rides the engine without touching per-head
        // streams: an adaptive-policy engine stays bit-identical too
        let mh_adaptive = MultiHeadAttention::new(
            Engine::with_policy(test_threads(3), ChunkPolicy::adaptive(4)),
        );
        let mut rng4 = Rng::new(7);
        let adaptive = enc.forward_mh(&ids, &segs, &attn, &mh_adaptive, &mut rng4);
        for (a, b) in serial.data.iter().zip(&adaptive.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bucket_len_is_pow2_floored_and_capped() {
        assert_eq!(bucket_len(0, 128), 8);
        assert_eq!(bucket_len(5, 128), 8);
        assert_eq!(bucket_len(8, 128), 8);
        assert_eq!(bucket_len(9, 128), 16);
        assert_eq!(bucket_len(33, 128), 64);
        assert_eq!(bucket_len(100, 128), 128);
        assert_eq!(bucket_len(500, 128), 128, "caps at max_len");
        assert_eq!(bucket_len(5, 4), 4, "small max_len wins over the floor");
    }

    #[test]
    fn bucket_len_never_returns_non_pow2() {
        // regression: a non-pow2 max_len used to leak through the cap,
        // contradicting the doc and breaking prefix-cache keying
        assert_eq!(bucket_len(100, 100), 64);
        assert_eq!(bucket_len(500, 100), 64);
        assert_eq!(bucket_len(5, 100), 8, "cap only binds past the request");
        assert_eq!(bucket_len(40, 48), 32);
        assert_eq!(bucket_len(5, 6), 4, "non-pow2 cap floors below the request");
    }

    #[test]
    fn pow2_floor_cases() {
        assert_eq!(pow2_floor(0), 0);
        assert_eq!(pow2_floor(1), 1);
        assert_eq!(pow2_floor(2), 2);
        assert_eq!(pow2_floor(3), 2);
        assert_eq!(pow2_floor(8), 8);
        assert_eq!(pow2_floor(100), 64);
        assert_eq!(pow2_floor(usize::MAX), 1usize << (usize::BITS - 1));
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn base_rejects_non_pow2_max_len() {
        let _ = EncoderConfig::base(64, 48, 3);
    }

    #[test]
    fn serving_rng_is_width_keyed() {
        let mut a = serving_rng(7, 16);
        let mut b = serving_rng(7, 16);
        let mut c = serving_rng(7, 32);
        let mut d = serving_rng(8, 16);
        let x = a.next_u64();
        assert_eq!(x, b.next_u64(), "same (seed, width) must reproduce");
        assert_ne!(x, c.next_u64(), "width keys the stream");
        assert_ne!(x, d.next_u64(), "seed keys the stream");
    }

    #[test]
    fn encoder_stream_matches_bucketed_serving_path() {
        // chunked appends with interleaved classifies: every classify
        // must be bit-identical to the batch serving path over the
        // prefix appended so far, at the same width and serving stream
        let cfg = EncoderConfig::base(64, 32, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 3);
        let enc = Encoder::new(cfg, &params);
        let att = YosoAttention::new(5, 8, false);
        let attn: Arc<dyn Attention> = Arc::new(att.clone());
        let mh = MultiHeadAttention::serial();
        let seed = 21u64;
        let ids: Vec<i32> = (0..30).map(|i| (i % 60) + 4).collect();
        let segs: Vec<i32> = (0..30).map(|i| i % 2).collect();
        let width = 32;
        let mut stream = EncoderStream::new(&enc, &att, seed, width);
        for (start, end) in [(0usize, 7usize), (7, 8), (8, 30)] {
            stream.append(&enc, &ids[start..end], &segs[start..end]);
            assert_eq!(stream.len(), end);
            // twice: the PAD-tail overlay must leave session state intact
            for pass in 0..2 {
                let got = stream.classify(&enc);
                let mut rng = serving_rng(seed, width);
                let expect = enc.classify_bucketed(
                    &ids[..end],
                    &segs[..end],
                    width,
                    &attn,
                    &mh,
                    &mut rng,
                );
                assert_eq!(got.len(), expect.len());
                for (a, b) in got.iter().zip(&expect) {
                    assert_eq!(a.to_bits(), b.to_bits(), "prefix {end} pass {pass}");
                }
            }
        }
        assert!(stream.approx_bytes() > 0);
        assert_eq!(stream.width(), width);
        assert_eq!(stream.ids(), &ids[..]);
        assert_eq!(stream.segs(), &segs[..]);
    }

    #[test]
    fn classify_bucketed_matches_explicit_pad() {
        // the bucket-width entry is exactly pad_to + classify_mh — the
        // serving paths rely on this equivalence for the bit-identity
        // contract
        let cfg = EncoderConfig::base(64, 32, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 3);
        let enc = Encoder::new(cfg, &params);
        let ids: Vec<i32> = (0..11).map(|i| (i % 60) + 5).collect();
        let segs = vec![0i32; 11];
        let attn: Arc<dyn Attention> = Arc::new(YosoAttention::new(5, 8, false));
        let mh = MultiHeadAttention::serial();
        let width = bucket_len(ids.len(), 32);
        assert_eq!(width, 16);
        let mut rng1 = Rng::new(7);
        let a = enc.classify_bucketed(&ids, &segs, width, &attn, &mh, &mut rng1);
        let (pids, psegs) = pad_to(&ids, &segs, width);
        let mut rng2 = Rng::new(7);
        let b = enc.classify_mh(&pids, &psegs, &attn, &mh, &mut rng2);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn logits_independent_of_kernel_variant() {
        // the serving determinism contract extends across kernels: the
        // fused arena kernel and the seed kernel must produce identical
        // logits end-to-end through the encoder (hash codes and
        // per-bucket summation order are preserved bit-for-bit)
        use crate::attention::KernelVariant;
        let cfg = EncoderConfig::base(64, 32, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 5);
        let enc = Encoder::new(cfg, &params);
        let ids: Vec<i32> = (0..20).map(|i| (i % 60) + 4).collect();
        let segs = vec![0i32; 20];
        let mh = MultiHeadAttention::serial();
        let mut logits = Vec::new();
        for variant in [KernelVariant::Seed, KernelVariant::Fused] {
            let attn: Arc<dyn Attention> =
                Arc::new(YosoAttention::new(5, 8, false).with_kernel(variant));
            let mut rng = Rng::new(9);
            logits.push(enc.classify_bucketed(&ids, &segs, 32, &attn, &mh, &mut rng));
        }
        for (a, b) in logits[0].iter().zip(&logits[1]) {
            assert_eq!(a.to_bits(), b.to_bits(), "kernel variant changed logits");
        }
    }

    #[test]
    fn repeated_forward_draws_fresh_randomness() {
        // forward advances the caller rng: consecutive calls on the same
        // input must sample different hash functions (Monte-Carlo use).
        let cfg = EncoderConfig::base(64, 16, 3);
        let params = ParamSet::init_for(&encoder_abi_spec(&cfg), 1);
        let enc = Encoder::new(cfg, &params);
        let ids = vec![7i32; 16];
        let segs = vec![0i32; 16];
        let attn = YosoAttention::new(5, 4, false);
        let mut rng = Rng::new(3);
        let a = enc.forward(&ids, &segs, &attn, &mut rng);
        let b = enc.forward(&ids, &segs, &attn, &mut rng);
        assert!(
            a.max_abs_diff(&b) > 0.0,
            "consecutive stochastic forwards drew identical randomness"
        );
    }
}
