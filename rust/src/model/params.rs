//! Parameter initialization + named parameter sets.
//!
//! Mirrors `python/compile/model.py::init_params` *rule-for-rule*:
//! * layer-norm gains (`*_g`) -> ones
//! * biases (`b*` / `*_b`)    -> zeros
//! * depthwise conv kernels   -> 0.02 noise + unit center tap
//! * everything else          -> N(0, 0.02^2)
//!
//! (The random values differ from jax's — only the *distribution* must
//! match; artifacts take parameters as inputs, so any init works.)

use crate::runtime::manifest::ArtifactSpec;
use crate::util::Rng;
use std::collections::BTreeMap;

/// Initialize one parameter by name + shape.
pub fn init_param(name: &str, shape: &[usize], rng: &mut Rng) -> Vec<f32> {
    let count: usize = shape.iter().product();
    let short = name.rsplit('.').next().unwrap_or(name);
    if short.ends_with("_g") {
        vec![1.0; count]
    } else if short.starts_with('b') || short.ends_with("_b") {
        vec![0.0; count]
    } else if short == "conv_k" {
        // (heads, conv_size): noise + center tap 1.0
        let conv = shape[1];
        let mut v: Vec<f32> = (0..count).map(|_| 0.02 * rng.normal()).collect();
        for h in 0..shape[0] {
            v[h * conv + conv / 2] += 1.0;
        }
        v
    } else {
        (0..count).map(|_| 0.02 * rng.normal()).collect()
    }
}

/// Ordered, named parameter tensors (ABI order from the manifest).
#[derive(Clone, Debug, Default)]
pub struct ParamSet {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub values: Vec<Vec<f32>>,
}

impl ParamSet {
    /// Initialize from an artifact's `param:*` input slots.
    pub fn init_for(spec: &ArtifactSpec, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let mut set = ParamSet::default();
        for io in spec.inputs_with_prefix("param:") {
            let name = io.name.trim_start_matches("param:").to_string();
            set.values.push(init_param(&name, &io.shape, &mut rng));
            set.names.push(name);
            set.shapes.push(io.shape.clone());
        }
        set
    }

    /// Zeroed clone (Adam moment buffers).
    pub fn zeros_like(&self) -> ParamSet {
        ParamSet {
            names: self.names.clone(),
            shapes: self.shapes.clone(),
            values: self.values.iter().map(|v| vec![0.0; v.len()]).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn total_elements(&self) -> usize {
        self.values.iter().map(|v| v.len()).sum()
    }

    /// Name-indexed view.
    pub fn by_name(&self) -> BTreeMap<&str, (&[usize], &[f32])> {
        self.names
            .iter()
            .zip(self.shapes.iter().zip(&self.values))
            .map(|(n, (s, v))| (n.as_str(), (s.as_slice(), v.as_slice())))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_rules() {
        let mut rng = Rng::new(0);
        assert!(init_param("layer0.ln1_g", &[4], &mut rng).iter().all(|&x| x == 1.0));
        assert!(init_param("layer0.bq", &[4], &mut rng).iter().all(|&x| x == 0.0));
        assert!(init_param("mlm_out_b", &[4], &mut rng).iter().all(|&x| x == 0.0));
        let w = init_param("layer0.wq", &[64, 64], &mut rng);
        let std = (w.iter().map(|x| x * x).sum::<f32>() / w.len() as f32).sqrt();
        assert!((std - 0.02).abs() < 0.005, "{std}");
        let conv = init_param("layer0.conv_k", &[2, 9], &mut rng);
        assert!((conv[4] - 1.0).abs() < 0.1);
        assert!((conv[9 + 4] - 1.0).abs() < 0.1);
        assert!(conv[0].abs() < 0.1);
    }

    #[test]
    fn zeros_like_preserves_structure() {
        let p = ParamSet {
            names: vec!["a".into()],
            shapes: vec![vec![2, 2]],
            values: vec![vec![1.0; 4]],
        };
        let z = p.zeros_like();
        assert_eq!(z.values[0], vec![0.0; 4]);
        assert_eq!(z.names, p.names);
        assert_eq!(p.total_elements(), 4);
    }
}
