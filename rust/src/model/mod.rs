//! Model-side L3 components: parameter initialization matching the L2
//! `init_params` exactly (so Rust-initialized training reproduces the
//! Python-initialized runs), and a pure-Rust inference encoder over the
//! attention library (serving fallback + analysis figures).

pub mod encoder;
pub mod params;

pub use encoder::{encoder_abi_spec, Encoder, EncoderConfig};
pub use params::{init_param, ParamSet};
