//! Cache-blocked matmul kernels.
//!
//! The pure-Rust attention library's hot loop. `matmul_into` computes
//! C = A @ B with k-panel blocking so the B panel stays in L1/L2;
//! `matmul_nt_into` computes C = A @ B^T directly off B's rows (the
//! common attention pattern Q K^T) — both autovectorize well with
//! `-C target-cpu` defaults and avoid any allocation.

use super::Mat;

const BLOCK_K: usize = 64;

/// C = A @ B. C must be pre-zeroed with the right shape.
pub fn matmul_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.rows, "inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.cols), "out dims");
    let (n, k, m) = (a.rows, a.cols, b.cols);
    c.data.fill(0.0);
    for kb in (0..k).step_by(BLOCK_K) {
        let kend = (kb + BLOCK_K).min(k);
        for i in 0..n {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut c.data[i * m..(i + 1) * m];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * m..(kk + 1) * m];
                // innermost loop vectorizes: crow += aik * brow
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aik * bv;
                }
            }
        }
    }
}

/// C = A @ B^T (B stored row-major, i.e. dot products of rows).
///
/// A's rows are tiled 8 at a time so each B row streams from cache once
/// per tile instead of once per A row — ~8x less B traffic when B spills
/// L1 (the hashing and Q K^T shapes). Every element is still exactly
/// `dot(a_i, b_j)`, so outputs are bit-identical to the untiled loop and
/// hash sign bits / attention scores are unchanged.
pub fn matmul_nt_into(a: &Mat, b: &Mat, c: &mut Mat) {
    assert_eq!(a.cols, b.cols, "inner dims");
    assert_eq!((c.rows, c.cols), (a.rows, b.rows), "out dims");
    let k = a.cols;
    let m = b.rows;
    let mut i0 = 0;
    while i0 < a.rows {
        let i1 = (i0 + 8).min(a.rows);
        for j in 0..m {
            let brow = &b.data[j * k..(j + 1) * k];
            for i in i0..i1 {
                let arow = &a.data[i * k..(i + 1) * k];
                c.data[i * m + j] = dot(arow, brow);
            }
        }
        i0 = i1;
    }
}

/// Unrolled dot product (4-wide accumulators help LLVM vectorize).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for c in 0..chunks {
        let i = c * 4;
        acc[0] += a[i] * b[i];
        acc[1] += a[i + 1] * b[i + 1];
        acc[2] += a[i + 2] * b[i + 2];
        acc[3] += a[i + 3] * b[i + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for i in chunks * 4..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// y += alpha * x
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for kk in 0..a.cols {
                    s += a.at(i, kk) * b.at(kk, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(0);
        for (n, k, m) in [(3, 5, 7), (16, 64, 16), (33, 129, 65)] {
            let a = Mat::randn(n, k, 1.0, &mut rng);
            let b = Mat::randn(k, m, 1.0, &mut rng);
            let c = a.matmul(&b);
            assert!(c.max_abs_diff(&naive(&a, &b)) < 1e-3, "({n},{k},{m})");
        }
    }

    #[test]
    fn dot_matches_sum() {
        let a: Vec<f32> = (0..37).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..37).map(|i| (i * 2) as f32).collect();
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), expect);
    }

    #[test]
    fn axpy_accumulates() {
        let x = [1.0f32, 2.0, 3.0];
        let mut y = [10.0f32, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }
}
