//! Minimal f32 matrix/tensor substrate for the pure-Rust attention
//! library and model (row-major, owned storage).
//!
//! This is deliberately small: the L3 hot paths need dense matmul,
//! row-wise softmax/layernorm/l2-normalize, transpose, and elementwise
//! ops — nothing more. The HLO artifacts cover everything gradient-
//! shaped; this substrate powers inference, the efficiency benchmarks
//! (Figure 7 / Table 1), and the approximation studies (Figures 1, 6, 8).

pub mod linalg;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// i.i.d. N(0, std^2) entries.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut crate::util::Rng) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal() * std);
        }
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    pub fn t(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        out
    }

    /// self @ other, cache-blocked (see `linalg::matmul_into`).
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        linalg::matmul_into(self, other, &mut out);
        out
    }

    /// self @ other^T without materializing the transpose.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.rows);
        linalg::matmul_nt_into(self, other, &mut out);
        out
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Row-wise softmax in place.
    pub fn softmax_rows(&mut self) {
        for i in 0..self.rows {
            let row = self.row_mut(i);
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0;
            for x in row.iter_mut() {
                *x = (*x - mx).exp();
                sum += *x;
            }
            let inv = 1.0 / sum;
            for x in row.iter_mut() {
                *x *= inv;
            }
        }
    }

    /// Row-wise l2 normalization in place (gradient-safe eps inside sqrt,
    /// mirroring the L1 kernels).
    pub fn l2_normalize_rows(&mut self) {
        for i in 0..self.rows {
            let row = self.row_mut(i);
            let norm =
                (row.iter().map(|x| x * x).sum::<f32>() + 1e-12).sqrt();
            let inv = 1.0 / norm;
            for x in row.iter_mut() {
                *x *= inv;
            }
        }
    }

    /// Rows projected to the unit sphere (copy).
    pub fn unit_rows(&self) -> Mat {
        let mut m = self.clone();
        m.l2_normalize_rows();
        m
    }

    /// LayerNorm over the last axis with gain g and bias b.
    pub fn layer_norm(&self, g: &[f32], b: &[f32]) -> Mat {
        assert_eq!(g.len(), self.cols);
        assert_eq!(b.len(), self.cols);
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let row = self.row(i);
            let mean = row.iter().sum::<f32>() / self.cols as f32;
            let var = row.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
                / self.cols as f32;
            let inv = 1.0 / (var + 1e-6).sqrt();
            let orow = out.row_mut(i);
            for j in 0..self.cols {
                orow[j] = (row[j] - mean) * inv * g[j] + b[j];
            }
        }
        out
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt()
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// GELU (tanh approximation, as in BERT).
pub fn gelu(x: f32) -> f32 {
    0.5 * x
        * (1.0
            + ((2.0 / std::f32::consts::PI).sqrt() * (x + 0.044715 * x * x * x))
                .tanh())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_t_matches_explicit_transpose() {
        let mut rng = Rng::new(0);
        let a = Mat::randn(7, 5, 1.0, &mut rng);
        let b = Mat::randn(9, 5, 1.0, &mut rng);
        let direct = a.matmul_t(&b);
        let via_t = a.matmul(&b.t());
        assert!(direct.max_abs_diff(&via_t) < 1e-5);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(4, 6, 1.0, &mut rng);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::new(2);
        let mut a = Mat::randn(5, 8, 3.0, &mut rng);
        a.softmax_rows();
        for i in 0..5 {
            let s: f32 = a.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(a.row(i).iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn l2_rows_unit_norm() {
        let mut rng = Rng::new(3);
        let mut a = Mat::randn(4, 16, 2.0, &mut rng);
        a.l2_normalize_rows();
        for i in 0..4 {
            let n: f32 = a.row(i).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::new(4);
        let a = Mat::randn(3, 32, 5.0, &mut rng);
        let g = vec![1.0; 32];
        let b = vec![0.0; 32];
        let out = a.layer_norm(&g, &b);
        for i in 0..3 {
            let mean: f32 = out.row(i).iter().sum::<f32>() / 32.0;
            let var: f32 =
                out.row(i).iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / 32.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn gelu_fixed_points() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(10.0) - 10.0).abs() < 1e-3);
        assert!(gelu(-10.0).abs() < 1e-3);
    }
}
