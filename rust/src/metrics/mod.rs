//! Run metrics: named counters/gauges, step logs, and CSV/JSON emission
//! for the benchmark harness and the trainer — plus the log-bucketed
//! [`Histogram`] the serving gateway records live latency into (see
//! `serve::gateway::GatewayStats::record_into` for the bridge that lands
//! gateway percentiles/counters in the `Recorder` CSV/JSON emitters).

pub mod histogram;

pub use histogram::Histogram;

use crate::json::{to_string_pretty, Value};
use crate::util::stats::Welford;
use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

/// Accumulates scalar series keyed by name; writes CSV / JSON reports.
#[derive(Default)]
pub struct Recorder {
    series: BTreeMap<String, Vec<(f64, f64)>>, // name -> (x, y)
    aggregates: BTreeMap<String, Welford>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    /// Append one (x, y) point to a named series (e.g. step -> loss).
    pub fn push(&mut self, name: &str, x: f64, y: f64) {
        self.series.entry(name.to_string()).or_default().push((x, y));
        self.aggregates.entry(name.to_string()).or_default().push(y);
    }

    pub fn series(&self, name: &str) -> Option<&[(f64, f64)]> {
        self.series.get(name).map(|v| v.as_slice())
    }

    pub fn mean(&self, name: &str) -> Option<f64> {
        self.aggregates.get(name).map(|w| w.mean())
    }

    pub fn last(&self, name: &str) -> Option<f64> {
        self.series.get(name).and_then(|v| v.last()).map(|(_, y)| *y)
    }

    /// Write every series into one long-format CSV: series,x,y
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "series,x,y")?;
        for (name, points) in &self.series {
            for (x, y) in points {
                writeln!(f, "{name},{x},{y}")?;
            }
        }
        Ok(())
    }

    /// Summaries as a JSON object {name: {mean, n, last}}.
    pub fn to_json(&self) -> Value {
        let mut obj = BTreeMap::new();
        for (name, w) in &self.aggregates {
            obj.insert(
                name.clone(),
                Value::object(vec![
                    ("mean", Value::Number(w.mean())),
                    ("std", Value::Number(w.std())),
                    ("n", Value::Number(w.count() as f64)),
                    ("last", Value::Number(self.last(name).unwrap_or(f64::NAN))),
                ]),
            );
        }
        Value::Object(obj)
    }

    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, to_string_pretty(&self.to_json()))
    }
}

/// Format a fixed-width table row for terminal reports.
pub fn table_row(cells: &[String], widths: &[usize]) -> String {
    let mut out = String::new();
    for (c, w) in cells.iter().zip(widths) {
        out.push_str(&format!("{c:>w$} ", w = w));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_aggregate() {
        let mut r = Recorder::new();
        r.push("loss", 0.0, 4.0);
        r.push("loss", 1.0, 2.0);
        assert_eq!(r.mean("loss"), Some(3.0));
        assert_eq!(r.last("loss"), Some(2.0));
        assert_eq!(r.series("loss").unwrap().len(), 2);
    }

    #[test]
    fn csv_roundtrip_format() {
        let mut r = Recorder::new();
        r.push("a", 1.0, 2.0);
        let dir = std::env::temp_dir().join("yoso_metrics_test");
        let path = dir.join("out.csv");
        r.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("series,x,y"));
        assert!(text.contains("a,1,2"));
        let _ = std::fs::remove_dir_all(dir);
    }
}
