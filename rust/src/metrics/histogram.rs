//! Log-bucketed, mergeable latency histogram — the serving gateway's
//! live observability primitive.
//!
//! Each replica worker records into its own `Histogram` with no
//! cross-thread coordination; at shutdown (or on a stats snapshot) the
//! per-replica and per-bucket histograms merge by bucket-wise addition
//! into gateway-level aggregates, from which p50/p95/p99 are read. The
//! bucket layout is fixed (geometric, `SUBS_PER_OCTAVE` sub-buckets per
//! power of two), so two histograms are always merge-compatible and a
//! merge is exact: `merge(a, b).quantile(q)` equals the quantile of the
//! concatenated sample up to bucket resolution.
//!
//! Resolution: with 8 sub-buckets per octave, bucket boundaries are
//! `2^(1/8)` apart, so any reported quantile is within ~9% of the true
//! sample quantile — far below the run-to-run noise of a latency
//! benchmark, at 8 bytes per bucket and O(1) record cost.

use crate::util::stats::Welford;

/// Sub-buckets per power of two. 8 gives ~9% worst-case relative error.
const SUBS_PER_OCTAVE: usize = 8;
/// Smallest resolvable value: 2^MIN_EXP (in the caller's unit; for
/// milliseconds this is ~15 ns — effectively "zero" for serving).
const MIN_EXP: i32 = -16;
/// Largest resolvable value: 2^MAX_EXP (~4.7 hours in milliseconds).
const MAX_EXP: i32 = 24;
/// Geometric buckets plus one underflow (index 0) and one overflow slot.
const N_BUCKETS: usize = (MAX_EXP - MIN_EXP) as usize * SUBS_PER_OCTAVE + 2;

/// Mergeable log-bucketed histogram over non-negative samples.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    agg: Welford,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            counts: vec![0; N_BUCKETS],
            agg: Welford::default(),
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Bucket index for a value: 0 is the underflow bucket (v below the
    /// resolution floor, including 0 and negatives, which latency math
    /// can produce from clock skew), the last index is the overflow.
    fn index(v: f64) -> usize {
        if v.is_nan() || v <= 0.0 || v.log2() < MIN_EXP as f64 {
            return 0;
        }
        // f64-to-usize casts saturate, so +inf lands in the overflow slot
        let pos = ((v.log2() - MIN_EXP as f64) * SUBS_PER_OCTAVE as f64) as usize;
        (pos + 1).min(N_BUCKETS - 1)
    }

    /// Representative value of a bucket: the geometric midpoint of its
    /// bounds (the underflow bucket reports 0).
    fn representative(i: usize) -> f64 {
        if i == 0 {
            return 0.0;
        }
        let center = (i - 1) as f64 + 0.5;
        (MIN_EXP as f64 + center / SUBS_PER_OCTAVE as f64).exp2()
    }

    pub fn record(&mut self, v: f64) {
        // NaN (a degenerate latency computation) counts as 0 rather than
        // poisoning mean/min/max
        let v = if v.is_nan() { 0.0 } else { v };
        self.counts[Self::index(v)] += 1;
        self.agg.push(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Bucket-wise addition; exact because every histogram shares the
    /// one fixed layout.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.agg.merge(&other.agg);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.agg.count()
    }

    pub fn mean(&self) -> f64 {
        if self.count() == 0 {
            0.0
        } else {
            self.agg.mean()
        }
    }

    pub fn min(&self) -> f64 {
        if self.count() == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count() == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Nearest-rank quantile at bucket resolution: the representative
    /// value of the bucket holding the `ceil(q * count)`-th sample,
    /// clamped into the observed [min, max] so tiny samples do not
    /// report a bucket midpoint outside the data. 0 on an empty
    /// histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as u64).clamp(1, n);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // the overflow slot has no midpoint; report the observed max
                let rep = if i + 1 == self.counts.len() {
                    self.max
                } else {
                    Self::representative(i)
                };
                return rep.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn empty_histogram_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn quantiles_within_bucket_resolution() {
        // uniform[1, 100): log-bucketed quantiles must land within the
        // ~9% relative error the 8-sub-bucket layout guarantees
        let mut h = Histogram::new();
        let mut rng = Rng::new(42);
        let mut xs: Vec<f64> = (0..10_000)
            .map(|_| 1.0 + 99.0 * rng.uniform_f64())
            .collect();
        for &x in &xs {
            h.record(x);
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for q in [0.1, 0.5, 0.95, 0.99] {
            let exact = crate::util::stats::quantile_exact(&xs, q);
            let approx = h.quantile(q);
            assert!(
                (approx - exact).abs() / exact < 0.10,
                "q={q}: exact {exact} vs histogram {approx}"
            );
        }
        assert!((h.mean() - xs.iter().sum::<f64>() / xs.len() as f64).abs() < 1e-9);
    }

    #[test]
    fn degenerate_values_hit_underflow_not_panic() {
        let mut h = Histogram::new();
        for v in [0.0, -3.0, f64::MIN_POSITIVE, 1e-30] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        // underflow bucket reports 0, clamped into [min, max]
        assert!(h.quantile(0.5) <= 0.0);
        // far past the top bucket lands in overflow, clamped to max
        h.record(1e300);
        assert_eq!(h.quantile(1.0), 1e300);
    }

    #[test]
    fn merge_equals_concatenation() {
        let mut rng = Rng::new(7);
        let (mut a, mut b, mut all) =
            (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 0..5_000 {
            let v = (1.0 + 500.0 * rng.uniform_f64()).powi(1 + (i % 2) as i32);
            if i % 3 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        for q in [0.01, 0.5, 0.95, 0.99] {
            assert_eq!(a.quantile(q), all.quantile(q), "q={q}");
        }
    }

    #[test]
    fn monotone_in_q() {
        let mut h = Histogram::new();
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            h.record(0.1 + 10.0 * rng.uniform_f64());
        }
        let mut prev = 0.0;
        for i in 0..=20 {
            let q = i as f64 / 20.0;
            let v = h.quantile(q);
            assert!(v >= prev, "quantile not monotone at q={q}");
            prev = v;
        }
    }
}
