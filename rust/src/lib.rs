//! # yoso — linear-cost self-attention via LSH Bernoulli sampling
//!
//! Rust coordinator (L3) of the three-layer reproduction of
//! *"You Only Sample (Almost) Once: Linear Cost Self-Attention Via
//! Bernoulli Sampling"* (Zeng et al., ICML 2021).
//!
//! Layers:
//! * **L1** — Pallas kernels (`python/compile/kernels/`): LSH hashing and
//!   the YOSO forward/backward estimators, lowered into the HLO artifacts.
//! * **L2** — JAX model (`python/compile/model.py`): BERT-style encoder
//!   with a pluggable attention zoo; fused train/eval/forward steps
//!   AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: config + CLI, data pipeline, PJRT runtime that
//!   loads the artifacts, training orchestrator, serving coordinator with
//!   dynamic batching, a pure-Rust attention library (YOSO + every
//!   baseline) for the efficiency/approximation studies, metrics,
//!   checkpointing.
//!
//! Python never runs at request time: after `make artifacts`, the `yoso`
//! binary is self-contained.

pub mod attention;
pub mod bench_support;
pub mod cli;
pub mod config;
pub mod data;
pub mod json;
pub mod lsh;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;
