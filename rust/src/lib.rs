//! # yoso — linear-cost self-attention via LSH Bernoulli sampling
//!
//! Rust coordinator (L3) of the three-layer reproduction of
//! *"You Only Sample (Almost) Once: Linear Cost Self-Attention Via
//! Bernoulli Sampling"* (Zeng et al., ICML 2021).
//!
//! Layers:
//! * **L1** — Pallas kernels (`python/compile/kernels/`): LSH hashing and
//!   the YOSO forward/backward estimators, lowered into the HLO artifacts.
//! * **L2** — JAX model (`python/compile/model.py`): BERT-style encoder
//!   with a pluggable attention zoo; fused train/eval/forward steps
//!   AOT-lowered to `artifacts/*.hlo.txt`.
//! * **L3** — this crate: config + CLI, data pipeline, PJRT runtime that
//!   loads the artifacts, training orchestrator, serving stack (artifact
//!   executor + an artifact-free CPU fallback, fronted by the
//!   **multi-replica `serve::gateway`** with bounded-queue admission
//!   control, length-bucketed dynamic batching with per-bucket policies,
//!   a work-conserving deadline-earliest-first scheduler
//!   (`serve::sched`, FIFO kept for A/B) proven on a deterministic
//!   virtual-clock simulator (`serve::clock` + `serve::sim`),
//!   log-bucketed `metrics::Histogram` observability, and flight-recorder
//!   tracing (`obs`: per-request lifecycle events + kernel phase
//!   profiling, exported as Chrome timelines / Prometheus text)), a
//!   pure-Rust
//!   attention library (YOSO + every baseline) for the
//!   efficiency/approximation studies, metrics, checkpointing — and a
//!   **parallel multi-head forward engine** (`attention::engine`) that
//!   exploits the estimator's embarrassing parallelism on a
//!   `util::ThreadPool`.
//!
//! The YOSO hot path itself runs on the **fused zero-allocation kernel**
//! (`attention::kernel`): a reusable per-thread `KernelArena` (bucket
//! table, per-hash codes, counting-sort buffers, hasher storage),
//! matmul-backed hashing, and a stable bucket-sorted streaming scatter —
//! bit-identical to the preserved seed kernel (`YOSO_KERNEL=seed|fused`
//! A/Bs them; property tests hold the equality), with zero steady-state
//! heap allocation in the kernel's scratch per forward (only output
//! buffers are allocated per call).
//!
//! The engine's thread-scaling model: YOSO's m hash rounds and the
//! `[batch, heads]` fan-out are both independent work items. Each item
//! draws its randomness from a `fold_in`-derived stream of the caller's
//! seed, and task layout is fixed by an `attention::ChunkPolicy`
//! (fixed-size or adaptive) whose inputs never include the executing
//! thread count — so output bytes are identical at every thread count
//! and under either scheduler; 1 thread vs N threads is a pure
//! wall-clock knob (asserted by tests). `util::ThreadPool` is a
//! work-stealing deque scheduler with a bulk-submit (`scope`/`run_batch`)
//! path; the legacy channel scheduler survives as `util::ChannelPool`
//! for the fig7 A/B. One parallelism grain is picked per pool: benches
//! fan hash rounds, the CPU serve path fans requests and keeps heads
//! serial inside each job (jobs must never re-enter their own pool).
//! Benches select thread counts from the core count, capped by
//! `YOSO_BENCH_THREADS`; `YOSO_BENCH_SMOKE=1` shrinks every bench to a
//! CI-sized smoke run, and `YOSO_TEST_THREADS` widens scheduler tests.
//!
//! Python never runs at request time: after `make artifacts`, the `yoso`
//! binary is self-contained. Without artifacts, the offline build runs
//! against in-tree `anyhow`/`xla` stand-ins (`rust/vendor/`): literal
//! marshaling is real, PJRT compilation gates with a clear error, and
//! every pure-Rust path (attention zoo, encoder, CPU serving, benches)
//! is fully functional.

pub mod attention;
pub mod bench_support;
pub mod cli;
pub mod config;
pub mod data;
pub mod json;
pub mod lsh;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testing;
pub mod train;
pub mod util;
