//! Hand-rolled CLI argument parser (offline registry has no clap).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional args,
//! with typed accessors and defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit token list (std::env::args().skip(1) at the
    /// call site). Tokens after `--` are positional verbatim.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Args {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        let mut raw = false;
        while let Some(tok) = iter.next() {
            if raw {
                args.positional.push(tok);
            } else if tok == "--" {
                raw = true;
            } else if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} wants a float, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        // NB: a bare `--flag` followed by a non-dashed token consumes it
        // as a value; flags must be last or use `--flag=` (documented).
        let a = parse("train pos1 --steps 100 --lr=0.001 --verbose");
        assert_eq!(a.positional, vec!["train", "pos1"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!((a.get_f64("lr", 0.0) - 0.001).abs() < 1e-12);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_or("also-missing", "d"), "d");
        assert!(!a.has_flag("nope"));
    }

    #[test]
    fn double_dash_positional() {
        let a = parse("cmd -- --not-an-option");
        assert_eq!(a.positional, vec!["cmd", "--not-an-option"]);
    }

    #[test]
    fn flag_before_positional() {
        // a trailing --flag followed by a positional consumes it as value;
        // flags must either be last or use --flag= form. Document behavior.
        let a = parse("--check --steps 5");
        assert!(a.has_flag("check"));
        assert_eq!(a.get_usize("steps", 0), 5);
    }
}
