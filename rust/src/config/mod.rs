//! Typed run configuration, loadable from JSON files and overridable from
//! the CLI — the launcher's single source of truth.

use crate::cli::Args;
use crate::json::{parse, Value};
use std::path::Path;

/// Which experiment family an invocation drives.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// artifact directory (default "artifacts")
    pub artifacts_dir: String,
    /// results directory for CSV/JSON outputs (default "results")
    pub results_dir: String,
    /// checkpoint directory
    pub checkpoint_dir: String,
    pub seed: u64,
    pub train: TrainConfig,
    pub serve: ServeConfig,
}

#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    /// attention variant name as in the manifest (e.g. "yoso_32")
    pub variant: String,
    pub steps: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub lr: f64,
    pub log_every: usize,
}

#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    pub max_batch: usize,
    pub max_wait_ms: u64,
    pub workers: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: "artifacts".into(),
            results_dir: "results".into(),
            checkpoint_dir: "results/checkpoints".into(),
            seed: 42,
            train: TrainConfig {
                variant: "yoso_32".into(),
                steps: 200,
                eval_every: 50,
                eval_batches: 8,
                lr: 1e-3,
                log_every: 10,
            },
            serve: ServeConfig { max_batch: 16, max_wait_ms: 5, workers: 1 },
        }
    }
}

impl RunConfig {
    /// Load from a JSON file; missing fields keep defaults.
    pub fn from_file(path: &Path) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let v = parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = RunConfig::default();
        cfg.apply_json(&v);
        Ok(cfg)
    }

    pub fn apply_json(&mut self, v: &Value) {
        if let Some(s) = v.get("artifacts_dir").and_then(Value::as_str) {
            self.artifacts_dir = s.to_string();
        }
        if let Some(s) = v.get("results_dir").and_then(Value::as_str) {
            self.results_dir = s.to_string();
        }
        if let Some(s) = v.get("checkpoint_dir").and_then(Value::as_str) {
            self.checkpoint_dir = s.to_string();
        }
        if let Some(n) = v.get("seed").and_then(Value::as_i64) {
            self.seed = n as u64;
        }
        if let Some(t) = v.get("train") {
            if let Some(s) = t.get("variant").and_then(Value::as_str) {
                self.train.variant = s.to_string();
            }
            if let Some(n) = t.get("steps").and_then(Value::as_usize) {
                self.train.steps = n;
            }
            if let Some(n) = t.get("eval_every").and_then(Value::as_usize) {
                self.train.eval_every = n;
            }
            if let Some(n) = t.get("eval_batches").and_then(Value::as_usize) {
                self.train.eval_batches = n;
            }
            if let Some(n) = t.get("log_every").and_then(Value::as_usize) {
                self.train.log_every = n;
            }
            if let Some(f) = t.get("lr").and_then(Value::as_f64) {
                self.train.lr = f;
            }
        }
        if let Some(s) = v.get("serve") {
            if let Some(n) = s.get("max_batch").and_then(Value::as_usize) {
                self.serve.max_batch = n;
            }
            if let Some(n) = s.get("max_wait_ms").and_then(Value::as_usize) {
                self.serve.max_wait_ms = n as u64;
            }
            if let Some(n) = s.get("workers").and_then(Value::as_usize) {
                self.serve.workers = n;
            }
        }
    }

    /// CLI overrides (take precedence over file values).
    pub fn apply_args(&mut self, args: &Args) {
        if let Some(s) = args.get("artifacts") {
            self.artifacts_dir = s.to_string();
        }
        if let Some(s) = args.get("results") {
            self.results_dir = s.to_string();
        }
        if let Some(s) = args.get("variant") {
            self.train.variant = s.to_string();
        }
        self.seed = args.get_usize("seed", self.seed as usize) as u64;
        self.train.steps = args.get_usize("steps", self.train.steps);
        self.train.eval_every = args.get_usize("eval-every", self.train.eval_every);
        self.train.eval_batches =
            args.get_usize("eval-batches", self.train.eval_batches);
        self.train.lr = args.get_f64("lr", self.train.lr);
        self.train.log_every = args.get_usize("log-every", self.train.log_every);
        self.serve.max_batch = args.get_usize("max-batch", self.serve.max_batch);
        self.serve.max_wait_ms =
            args.get_usize("max-wait-ms", self.serve.max_wait_ms as usize) as u64;
        self.serve.workers = args.get_usize("workers", self.serve.workers);
    }

    /// Resolve config: optional --config file, then CLI overrides.
    pub fn resolve(args: &Args) -> anyhow::Result<RunConfig> {
        let mut cfg = match args.get("config") {
            Some(path) => RunConfig::from_file(Path::new(path))?,
            None => RunConfig::default(),
        };
        cfg.apply_args(args);
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_then_json_then_cli() {
        let mut cfg = RunConfig::default();
        let v = parse(
            r#"{"seed": 9, "train": {"steps": 77, "lr": 0.5, "variant": "softmax"}}"#,
        )
        .unwrap();
        cfg.apply_json(&v);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.train.steps, 77);
        assert_eq!(cfg.train.variant, "softmax");
        let args = Args::parse(
            "--steps 5 --variant yoso_16".split_whitespace().map(String::from),
        );
        cfg.apply_args(&args);
        assert_eq!(cfg.train.steps, 5);
        assert_eq!(cfg.train.variant, "yoso_16");
        assert_eq!(cfg.seed, 9); // untouched by CLI
    }

    #[test]
    fn partial_json_keeps_defaults() {
        let mut cfg = RunConfig::default();
        cfg.apply_json(&parse(r#"{"train": {}}"#).unwrap());
        assert_eq!(cfg.train.steps, RunConfig::default().train.steps);
    }
}
