//! Flight-recorder tracing: per-request lifecycle events and kernel
//! phase profiling, shared by the live gateway and the discrete-event
//! simulator.
//!
//! The serving stack makes rich per-request decisions — bucketed
//! batching, EDF picks, degradation rungs with per-batch `m_eff`,
//! prefix-cache hits — and this module is the instrument that records
//! them as *typed events* instead of aggregate counters, so a moved p99
//! can be decomposed into "which stage of which requests paid for it".
//!
//! # Design
//!
//! - **One event schema for both executors.** Every timestamp in
//!   `serve` flows through [`crate::serve::clock::Clock`], so the live
//!   gateway and `serve::sim` emit the *same* fixed-size [`Event`]
//!   struct stamped with the same [`Tick`] type. A sim run and a live
//!   run of one trace produce schema-identical streams — the
//!   reconciliation property test runs unchanged against both.
//! - **Per-lane ring buffers, no global lock on the hot path.** A
//!   [`TraceSink`] owns one mutex-guarded ring per lane (lane 0 =
//!   admission/scheduler events emitted under the gateway state lock,
//!   lanes 1..=replicas = one per replica worker), so concurrent
//!   replicas never contend on a shared buffer. Rings are preallocated
//!   and **drop-oldest**: a full lane overwrites its oldest event and
//!   bumps a dropped-events counter instead of allocating or blocking.
//! - **Kernel phase timers are runtime-gated and zero-alloc.** The
//!   fused kernel's per-arena [`KernelProbe`] latches the global trace
//!   gate once per forward; when the gate is off the probe is a handful
//!   of predictable branches, and the disabled hot path stays
//!   zero-allocation (asserted by `alloc_kernel` with the
//!   `bench_support::alloc_count` machinery). When on, per-phase spans
//!   accumulate into preallocated scratch and flush to a global ring
//!   with **one** lock acquisition per forward.
//!
//! # Gates
//!
//! Request-lifecycle tracing is per-gateway configuration (see
//! `GatewayConfig::trace`); kernel phase profiling is a process-global
//! flag because arenas are thread-local and outlive any one gateway.
//! Both default from the `YOSO_TRACE` env var (`1`/`true`), and the
//! global gate can be flipped in-process with [`set_trace_enabled`] so
//! benches can A/B overhead without `std::env::set_var`.
//!
//! # Timelines
//!
//! Gateway events carry [`Tick`]s on the gateway's own clock; kernel
//! spans carry nanoseconds since a process-global epoch ([`now_ns`]).
//! A [`TraceSink`] records the offset between the two at construction
//! ([`TraceSink::epoch_offset_ns`]) and the Chrome exporter shifts
//! kernel spans onto the gateway timeline, so request spans and the
//! kernel phases that served them line up in one timeline view.
//!
//! # Exporters
//!
//! [`write_chrome_trace`] / [`chrome_trace_json`] emit a Chrome
//! `trace_event` JSON timeline (load `results/trace_*.json` in
//! `chrome://tracing` or <https://ui.perfetto.dev>); [`prometheus_text`]
//! renders a Prometheus-style text snapshot of counters and latency
//! quantiles; [`record_into`] bridges the same numbers into a
//! [`metrics::Recorder`](crate::metrics::Recorder) so trace summaries
//! land in the existing CSV/JSON report path.

use crate::metrics::{Histogram, Recorder};
use crate::serve::clock::Tick;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicU32, AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Global trace gate
// ---------------------------------------------------------------------------

/// 0 = uninitialized (read `YOSO_TRACE` on first query), 1 = off, 2 = on.
static TRACE_GATE: AtomicU8 = AtomicU8::new(0);

/// Parse a `YOSO_TRACE` setting (env-free so tests never mutate the
/// process environment): `1` / `true` enable, anything else disables.
pub fn trace_setting(v: Option<&str>) -> bool {
    matches!(v, Some("1") | Some("true"))
}

/// Is tracing globally enabled? Lazily initialized from `YOSO_TRACE` on
/// first call; flip at runtime with [`set_trace_enabled`]. This is the
/// kernel-probe gate and the default for per-gateway lifecycle tracing.
pub fn trace_enabled() -> bool {
    match TRACE_GATE.load(Ordering::Relaxed) {
        0 => {
            let on = trace_setting(std::env::var("YOSO_TRACE").ok().as_deref());
            TRACE_GATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
            on
        }
        g => g == 2,
    }
}

/// Override the global trace gate (wins over `YOSO_TRACE`). Benches use
/// this to A/B traced vs untraced runs in one process, and tests use it
/// to stay deterministic without touching the environment.
pub fn set_trace_enabled(on: bool) {
    TRACE_GATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
}

/// Process-global monotonic epoch for kernel phase spans (first use
/// pins t=0). Kernel probes can't see any gateway's clock — arenas are
/// thread-local and shared across gateways — so their spans live on
/// this timeline and exporters shift them via
/// [`TraceSink::epoch_offset_ns`].
static OBS_EPOCH: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process-global observability epoch.
pub fn now_ns() -> u64 {
    OBS_EPOCH.get_or_init(Instant::now).elapsed().as_nanos().min(u64::MAX as u128) as u64
}

// ---------------------------------------------------------------------------
// Event schema
// ---------------------------------------------------------------------------

/// Request-lifecycle stages, in lifecycle order. One [`Event`] per
/// stage transition; batch-scoped stages (`BatchFormed`, `ExecStart`,
/// `ExecEnd`) are emitted once per batch with `n` = batch size.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EventKind {
    /// Passed admission control (capacity + EDF feasibility).
    Admitted,
    /// Enqueued into its width bucket.
    Queued,
    /// A batch was cut from a bucket for a replica.
    BatchFormed,
    /// A replica began executing a batch.
    ExecStart,
    /// A replica finished executing a batch.
    ExecEnd,
    /// The reply channel delivered logits for this request.
    Replied,
    /// The request was shed; see [`Event::shed`] for the reason.
    Shed,
    /// The request was pulled back out of a dying replica's batch and
    /// re-inserted into its bucket queue (retry counter bumped).
    Requeued,
    /// A replica worker's serve loop panicked; the supervisor caught it.
    ReplicaDied,
    /// The supervisor restarted a dead replica worker's serve loop.
    ReplicaRestarted,
    /// A batch (or the tail of one) changed replicas: an idle peer took
    /// work a victim replica had formed but not started executing.
    /// Emitted once per steal on the thief's lane, with `n` = entries
    /// taken and `worker` = the thief.
    Stolen,
}

impl EventKind {
    pub const ALL: [EventKind; 11] = [
        EventKind::Admitted,
        EventKind::Queued,
        EventKind::BatchFormed,
        EventKind::ExecStart,
        EventKind::ExecEnd,
        EventKind::Replied,
        EventKind::Shed,
        EventKind::Requeued,
        EventKind::ReplicaDied,
        EventKind::ReplicaRestarted,
        EventKind::Stolen,
    ];

    pub fn label(self) -> &'static str {
        match self {
            EventKind::Admitted => "admitted",
            EventKind::Queued => "queued",
            EventKind::BatchFormed => "batch_formed",
            EventKind::ExecStart => "exec_start",
            EventKind::ExecEnd => "exec_end",
            EventKind::Replied => "replied",
            EventKind::Shed => "shed",
            EventKind::Requeued => "requeued",
            EventKind::ReplicaDied => "replica_died",
            EventKind::ReplicaRestarted => "replica_restarted",
            EventKind::Stolen => "stolen",
        }
    }
}

/// Quality class tag on a [`Replied`](EventKind::Replied) event —
/// the *served-at* class, mirroring `serve::Quality` without carrying
/// the degraded `m'` (that lives in [`Event::m_eff`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum QualityTag {
    Full,
    Degraded,
    BestEffort,
    /// Not applicable (non-reply events).
    Unspecified,
}

impl QualityTag {
    pub fn label(self) -> &'static str {
        match self {
            QualityTag::Full => "full",
            QualityTag::Degraded => "degraded",
            QualityTag::BestEffort => "best_effort",
            QualityTag::Unspecified => "unspecified",
        }
    }
}

/// Prefix-cache outcome tag on a [`Replied`](EventKind::Replied) event.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum CacheTag {
    Hit,
    Miss,
    /// Not applicable (cache disabled, or a non-reply event).
    Unspecified,
}

impl CacheTag {
    pub fn label(self) -> &'static str {
        match self {
            CacheTag::Hit => "hit",
            CacheTag::Miss => "miss",
            CacheTag::Unspecified => "unspecified",
        }
    }
}

/// Shed reason tag on a [`Shed`](EventKind::Shed) event, mirroring
/// `serve::Shed` without the retry-hint payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ShedTag {
    /// Rejected at admission: bounded queue at capacity.
    QueueFull,
    /// Rejected at admission: deadline infeasible even degraded.
    Infeasible,
    /// Admitted but the deadline expired before execution.
    Expired,
    /// Gateway shut down with the request in flight.
    Closed,
    /// Admitted but failed terminally: the request's own execution
    /// panicked, or repeated replica crashes exhausted its retry budget.
    Internal,
    /// Not applicable (non-shed events).
    Unspecified,
}

impl ShedTag {
    pub fn label(self) -> &'static str {
        match self {
            ShedTag::QueueFull => "queue_full",
            ShedTag::Infeasible => "deadline_infeasible",
            ShedTag::Expired => "deadline_expired",
            ShedTag::Closed => "closed",
            ShedTag::Internal => "internal_error",
            ShedTag::Unspecified => "unspecified",
        }
    }
}

/// Sequence number sentinel for events about requests that never got a
/// sequence number (admission-time rejections).
pub const NO_SEQ: u64 = u64::MAX;

/// One fixed-size, `Copy` trace event. Both executors emit exactly this
/// struct, so "schema-identical event streams" holds by construction.
/// Fields that don't apply to a given kind carry their `Unspecified` /
/// zero defaults (see [`Event::new`]).
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// When, on the emitting gateway's (or sim's) clock.
    pub at: Tick,
    pub kind: EventKind,
    /// Request sequence number, or [`NO_SEQ`] for admission rejects.
    pub seq: u64,
    /// Replica index for batch/exec/reply events (0 = scheduler lane).
    pub worker: u32,
    /// Bucket width in tokens (0 = not applicable).
    pub width: u32,
    /// Served-at quality class (reply events).
    pub quality: QualityTag,
    /// Hash rounds actually served (reply events) or planned for the
    /// batch (batch events); 0 = not applicable.
    pub m_eff: u32,
    /// Batch size for batch-scoped events; 0 = not applicable.
    pub n: u32,
    /// Prefix-cache outcome (reply events).
    pub cache: CacheTag,
    /// Shed reason (shed events).
    pub shed: ShedTag,
}

impl Event {
    /// A bare event of `kind` at `at` about `seq`; every other field at
    /// its "not applicable" default. Chain the `with_*` builders for
    /// the fields the kind carries.
    pub fn new(kind: EventKind, at: Tick, seq: u64) -> Event {
        Event {
            at,
            kind,
            seq,
            worker: 0,
            width: 0,
            quality: QualityTag::Unspecified,
            m_eff: 0,
            n: 0,
            cache: CacheTag::Unspecified,
            shed: ShedTag::Unspecified,
        }
    }

    pub fn with_worker(mut self, worker: usize) -> Event {
        self.worker = worker as u32;
        self
    }

    pub fn with_width(mut self, width: usize) -> Event {
        self.width = width as u32;
        self
    }

    pub fn with_quality(mut self, q: QualityTag) -> Event {
        self.quality = q;
        self
    }

    pub fn with_m_eff(mut self, m: usize) -> Event {
        self.m_eff = m as u32;
        self
    }

    pub fn with_n(mut self, n: usize) -> Event {
        self.n = n as u32;
        self
    }

    pub fn with_cache(mut self, c: CacheTag) -> Event {
        self.cache = c;
        self
    }

    pub fn with_shed(mut self, s: ShedTag) -> Event {
        self.shed = s;
        self
    }

    /// Lifecycle rank for deterministic ordering of same-tick events.
    fn rank(self) -> u8 {
        match self.kind {
            EventKind::Admitted => 0,
            EventKind::Queued => 1,
            EventKind::BatchFormed => 2,
            EventKind::ExecStart => 3,
            EventKind::ExecEnd => 4,
            EventKind::Replied => 5,
            EventKind::Shed => 6,
            EventKind::Requeued => 7,
            EventKind::ReplicaDied => 8,
            EventKind::ReplicaRestarted => 9,
            EventKind::Stolen => 10,
        }
    }
}

// ---------------------------------------------------------------------------
// Ring buffer + TraceSink
// ---------------------------------------------------------------------------

/// Fixed-capacity drop-oldest ring. Preallocates on construction and
/// never allocates again: a push into a full ring overwrites the oldest
/// element and bumps `dropped`.
struct RingBuf<T: Copy> {
    buf: Vec<T>,
    cap: usize,
    head: usize, // index of the oldest element
    len: usize,
    dropped: u64,
}

impl<T: Copy> RingBuf<T> {
    fn new(cap: usize) -> RingBuf<T> {
        assert!(cap > 0, "ring capacity must be positive");
        RingBuf { buf: Vec::with_capacity(cap), cap, head: 0, len: 0, dropped: 0 }
    }

    fn push(&mut self, x: T) {
        if self.len < self.cap {
            if self.buf.len() < self.cap {
                self.buf.push(x); // fill phase: stays within capacity
            } else {
                self.buf[(self.head + self.len) % self.cap] = x;
            }
            self.len += 1;
        } else {
            self.buf[self.head] = x; // full: overwrite the oldest
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Copy out oldest-to-newest and reset to empty (capacity kept).
    fn drain_into(&mut self, out: &mut Vec<T>) {
        for i in 0..self.len {
            out.push(self.buf[(self.head + i) % self.cap]);
        }
        self.head = 0;
        self.len = 0;
    }
}

/// Per-lane ring buffers for lifecycle events. Lane 0 is the
/// scheduler/admission lane (its events are emitted under the gateway
/// state lock, so its mutex is uncontended); lanes `1..=replicas` are
/// one per replica worker. No lock is shared between lanes, so the hot
/// path never takes a global lock.
pub struct TraceSink {
    lanes: Vec<Mutex<RingBuf<Event>>>,
    epoch_offset_ns: i64,
}

impl TraceSink {
    /// Default per-lane capacity: enough for every smoke bench and test
    /// trace; sized so a sink costs single-digit MB.
    pub const DEFAULT_LANE_CAPACITY: usize = 1 << 15;

    /// `n_lanes` rings of `capacity` events each. `epoch_offset_ns` is
    /// `now_ns() - clock.now().as_nanos()` captured next to the clock
    /// the events will be stamped with — the exporter uses it to shift
    /// kernel phase spans onto the event timeline.
    pub fn new(n_lanes: usize, capacity: usize, epoch_offset_ns: i64) -> TraceSink {
        let n = n_lanes.max(1);
        TraceSink {
            lanes: (0..n).map(|_| Mutex::new(RingBuf::new(capacity))).collect(),
            epoch_offset_ns,
        }
    }

    pub fn n_lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Offset between [`now_ns`]'s epoch and the event clock's epoch.
    pub fn epoch_offset_ns(&self) -> i64 {
        self.epoch_offset_ns
    }

    /// Record `e` on `lane` (clamped into range). Constant-time, never
    /// allocates, never blocks on any other lane. Lane locks recover
    /// from poisoning: a replica that panics mid-emit leaves a ring in
    /// a consistent state (`RingBuf::push` has no partial step worth
    /// losing the whole trace over), so tracing keeps working while the
    /// supervisor restarts the worker.
    pub fn emit(&self, lane: usize, e: Event) {
        let lane = lane.min(self.lanes.len() - 1);
        self.lanes[lane]
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(e);
    }

    /// Merge every lane into one stream ordered by `(at, seq, kind)`
    /// and reset the rings. The total drop count survives draining.
    pub fn drain(&self) -> TraceLog {
        let mut events = Vec::new();
        let mut dropped = 0;
        for lane in &self.lanes {
            let mut g = lane.lock().unwrap_or_else(|p| p.into_inner());
            g.drain_into(&mut events);
            dropped += g.dropped;
        }
        events.sort_by_key(|e| (e.at, e.seq, e.rank()));
        TraceLog { events, dropped, epoch_offset_ns: self.epoch_offset_ns }
    }
}

/// A drained, time-ordered event stream plus the sink's drop counter.
#[derive(Debug)]
pub struct TraceLog {
    /// Events ordered by `(at, seq, lifecycle rank)`.
    pub events: Vec<Event>,
    /// Events overwritten before draining (ring overflow).
    pub dropped: u64,
    /// See [`TraceSink::epoch_offset_ns`].
    pub epoch_offset_ns: i64,
}

impl TraceLog {
    pub fn count(&self, kind: EventKind) -> u64 {
        self.events.iter().filter(|e| e.kind == kind).count() as u64
    }

    pub fn count_shed(&self, tag: ShedTag) -> u64 {
        self.events
            .iter()
            .filter(|e| e.kind == EventKind::Shed && e.shed == tag)
            .count() as u64
    }

    pub fn count_cache(&self, tag: CacheTag) -> u64 {
        self.events
            .iter()
            .filter(|e| e.kind == EventKind::Replied && e.cache == tag)
            .count() as u64
    }

    pub fn count_replied_quality(&self, tag: QualityTag) -> u64 {
        self.events
            .iter()
            .filter(|e| e.kind == EventKind::Replied && e.quality == tag)
            .count() as u64
    }

    /// Queued→Replied latency per completed request, in milliseconds.
    pub fn request_latencies_ms(&self) -> Vec<f64> {
        let mut queued: BTreeMap<u64, Tick> = BTreeMap::new();
        let mut out = Vec::new();
        for e in &self.events {
            match e.kind {
                EventKind::Queued => {
                    queued.entry(e.seq).or_insert(e.at);
                }
                EventKind::Replied => {
                    if let Some(&q) = queued.get(&e.seq) {
                        out.push(e.at.ms_since(q));
                    }
                }
                _ => {}
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Kernel phase profiling
// ---------------------------------------------------------------------------

/// The fused kernel's hot phases. `Hash` is the matmul-backed phase:
/// `attention::kernel` computes hash codes as a blocked matrix product
/// against the hyperplane/Hadamard projections, so there is no separate
/// matmul timer — the hash timer *is* it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Row normalization + hasher refill, once per forward.
    Prep,
    /// Hash-code computation for q and k (matmul-backed).
    Hash,
    /// Bucket-table scatter of value rows (counting-sort order).
    Scatter,
    /// Per-query gather/accumulate out of the bucket table.
    Gather,
}

pub const N_PHASES: usize = 4;

impl Phase {
    pub const ALL: [Phase; N_PHASES] = [Phase::Prep, Phase::Hash, Phase::Scatter, Phase::Gather];

    pub fn label(self) -> &'static str {
        match self {
            Phase::Prep => "prep",
            Phase::Hash => "hash",
            Phase::Scatter => "scatter",
            Phase::Gather => "gather",
        }
    }

    fn idx(self) -> usize {
        match self {
            Phase::Prep => 0,
            Phase::Hash => 1,
            Phase::Scatter => 2,
            Phase::Gather => 3,
        }
    }
}

/// One timed kernel phase occurrence, on the [`now_ns`] timeline.
#[derive(Clone, Copy, Debug)]
pub struct PhaseSpan {
    pub phase: Phase,
    /// Nanoseconds since the process-global obs epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Which probe (≈ which arena/thread) recorded it.
    pub lane: u32,
}

static PHASE_NS: [AtomicU64; N_PHASES] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
static PHASE_CALLS: [AtomicU64; N_PHASES] =
    [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
static NEXT_PROBE_LANE: AtomicU32 = AtomicU32::new(0);
/// Capacity of the global kernel span ring (~16k spans ≈ a few hundred
/// traced forwards; older spans drop first).
const KERNEL_SPAN_CAP: usize = 1 << 14;
static KERNEL_SPANS: OnceLock<Mutex<RingBuf<PhaseSpan>>> = OnceLock::new();

fn kernel_span_ring() -> &'static Mutex<RingBuf<PhaseSpan>> {
    KERNEL_SPANS.get_or_init(|| Mutex::new(RingBuf::new(KERNEL_SPAN_CAP)))
}

/// Per-arena phase timer. Lives inside `attention::KernelArena`; the
/// kernel brackets each phase with [`enter`](KernelProbe::enter) /
/// [`exit`](KernelProbe::exit) between a
/// [`begin_forward`](KernelProbe::begin_forward) /
/// [`finish_forward`](KernelProbe::finish_forward) pair.
///
/// The trace gate is latched **once** per forward: when off, every call
/// is a single predictable branch and nothing is recorded or allocated.
/// When on, spans go into a scratch `Vec` whose capacity is retained
/// across forwards (zero-alloc steady state) and are flushed to the
/// global ring with one lock per forward.
#[derive(Debug)]
pub struct KernelProbe {
    on: bool,
    lane: u32,
    open: Option<(Phase, u64)>,
    /// Per-forward scratch, flushed and cleared by `finish_forward`.
    spans: Vec<PhaseSpan>,
    pending_ns: [u64; N_PHASES],
    pending_calls: [u64; N_PHASES],
    /// Cumulative per-arena totals (kept after flushing to globals).
    totals_ns: [u64; N_PHASES],
    calls: [u64; N_PHASES],
}

impl KernelProbe {
    pub fn new() -> KernelProbe {
        KernelProbe {
            on: false,
            lane: u32::MAX,
            open: None,
            spans: Vec::new(),
            pending_ns: [0; N_PHASES],
            pending_calls: [0; N_PHASES],
            totals_ns: [0; N_PHASES],
            calls: [0; N_PHASES],
        }
    }

    /// Latch the global gate for this forward.
    pub fn begin_forward(&mut self) {
        self.on = trace_enabled();
        if self.on && self.lane == u32::MAX {
            self.lane = NEXT_PROBE_LANE.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Start timing `phase`. No-op when the latch is off.
    #[inline]
    pub fn enter(&mut self, phase: Phase) {
        if !self.on {
            return;
        }
        self.open = Some((phase, now_ns()));
    }

    /// Stop timing the phase opened by the last [`enter`](Self::enter).
    #[inline]
    pub fn exit(&mut self) {
        if !self.on {
            return;
        }
        if let Some((phase, t0)) = self.open.take() {
            let dur = now_ns().saturating_sub(t0);
            let i = phase.idx();
            self.pending_ns[i] += dur;
            self.pending_calls[i] += 1;
            self.spans.push(PhaseSpan { phase, start_ns: t0, dur_ns: dur, lane: self.lane });
        }
    }

    /// Flush this forward's accumulation: totals into the process-wide
    /// atomics, spans into the global ring (one lock), scratch cleared
    /// with capacity retained.
    pub fn finish_forward(&mut self) {
        if !self.on {
            return;
        }
        for i in 0..N_PHASES {
            if self.pending_calls[i] > 0 {
                PHASE_NS[i].fetch_add(self.pending_ns[i], Ordering::Relaxed);
                PHASE_CALLS[i].fetch_add(self.pending_calls[i], Ordering::Relaxed);
                self.totals_ns[i] += self.pending_ns[i];
                self.calls[i] += self.pending_calls[i];
                self.pending_ns[i] = 0;
                self.pending_calls[i] = 0;
            }
        }
        if !self.spans.is_empty() {
            let mut ring = kernel_span_ring().lock().unwrap();
            for &s in &self.spans {
                ring.push(s);
            }
            self.spans.clear();
        }
        self.on = false;
    }

    /// Cumulative `(nanoseconds, calls)` this arena has spent in
    /// `phase` across every traced forward.
    pub fn phase_total(&self, phase: Phase) -> (u64, u64) {
        let i = phase.idx();
        (self.totals_ns[i], self.calls[i])
    }
}

impl Default for KernelProbe {
    fn default() -> Self {
        KernelProbe::new()
    }
}

/// Process-wide kernel profile: cumulative per-phase totals plus the
/// retained individual spans (drop-oldest).
#[derive(Debug, Default)]
pub struct KernelSnapshot {
    pub totals_ns: [u64; N_PHASES],
    pub calls: [u64; N_PHASES],
    pub spans: Vec<PhaseSpan>,
    /// Spans overwritten in the global ring before this snapshot.
    pub dropped: u64,
}

impl KernelSnapshot {
    pub fn total_ns(&self, phase: Phase) -> u64 {
        self.totals_ns[phase.idx()]
    }

    pub fn calls(&self, phase: Phase) -> u64 {
        self.calls[phase.idx()]
    }

    pub fn is_empty(&self) -> bool {
        self.calls.iter().all(|&c| c == 0)
    }
}

/// Copy out the process-wide kernel profile (totals + span ring). The
/// ring is drained; totals keep accumulating.
pub fn kernel_snapshot() -> KernelSnapshot {
    let mut snap = KernelSnapshot::default();
    for i in 0..N_PHASES {
        snap.totals_ns[i] = PHASE_NS[i].load(Ordering::Relaxed);
        snap.calls[i] = PHASE_CALLS[i].load(Ordering::Relaxed);
    }
    let mut ring = kernel_span_ring().lock().unwrap();
    snap.dropped = ring.dropped;
    ring.drain_into(&mut snap.spans);
    snap
}

/// Zero the process-wide kernel profile (totals, calls, span ring, drop
/// counter) — benches call this between A/B arms.
pub fn reset_kernel_profile() {
    for i in 0..N_PHASES {
        PHASE_NS[i].store(0, Ordering::Relaxed);
        PHASE_CALLS[i].store(0, Ordering::Relaxed);
    }
    let mut ring = kernel_span_ring().lock().unwrap();
    let mut scratch = Vec::new();
    ring.drain_into(&mut scratch);
    ring.dropped = 0;
}

// ---------------------------------------------------------------------------
// Exporters
// ---------------------------------------------------------------------------

fn push_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Microseconds (Chrome's `ts` unit) from a tick, as a JSON number.
fn tick_us(t: Tick) -> f64 {
    t.as_nanos() as f64 / 1e3
}

/// Render `log` (plus kernel phase spans) as a Chrome `trace_event`
/// JSON document. Load the result in `chrome://tracing` or Perfetto:
///
/// - **pid 1 "requests"**: one async span per request from its first
///   event to `Replied`/`Shed` (args carry width, quality, `m_eff`,
///   cache outcome), plus instant markers for admission-time sheds.
/// - **pid 2 "replicas"**: one complete span per executed batch
///   (`ExecStart`→`ExecEnd`) on the owning worker's row, with
///   `BatchFormed` instants.
/// - **pid 3 "kernel"**: per-phase sub-spans from the fused kernel's
///   probes, shifted onto the event timeline via the sink's epoch
///   offset.
pub fn chrome_trace_json(log: &TraceLog, kernel: &KernelSnapshot) -> String {
    let mut out = String::with_capacity(256 + 160 * (log.events.len() + kernel.spans.len()));
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    let mut push_event = |out: &mut String, body: &str| {
        if !std::mem::take(&mut first) {
            out.push(',');
        }
        out.push_str(body);
    };

    for (pid, name) in [(1, "requests"), (2, "replicas"), (3, "kernel")] {
        let mut b = String::new();
        let _ = write!(
            b,
            "{{\"ph\":\"M\",\"pid\":{pid},\"name\":\"process_name\",\"args\":{{\"name\":"
        );
        push_json_str(&mut b, name);
        b.push_str("}}");
        push_event(&mut out, &b);
    }

    // Request async spans: first event opens, Replied/Shed closes.
    let mut open: BTreeMap<u64, Tick> = BTreeMap::new();
    let mut exec_open: BTreeMap<u32, Event> = BTreeMap::new();
    for e in &log.events {
        match e.kind {
            EventKind::Admitted | EventKind::Queued => {
                if e.seq != NO_SEQ {
                    open.entry(e.seq).or_insert(e.at);
                }
            }
            EventKind::Replied | EventKind::Shed => {
                if e.seq != NO_SEQ {
                    if let Some(t0) = open.remove(&e.seq) {
                        let outcome = if e.kind == EventKind::Replied {
                            "replied"
                        } else {
                            e.shed.label()
                        };
                        let mut b = String::new();
                        let _ = write!(
                            b,
                            "{{\"ph\":\"b\",\"cat\":\"request\",\"id\":{},\"pid\":1,\"tid\":{},\"ts\":{:.3},\"name\":\"req\"}}",
                            e.seq, e.width, tick_us(t0)
                        );
                        push_event(&mut out, &b);
                        b.clear();
                        let _ = write!(
                            b,
                            "{{\"ph\":\"e\",\"cat\":\"request\",\"id\":{},\"pid\":1,\"tid\":{},\"ts\":{:.3},\"name\":\"req\",\"args\":{{\"width\":{},\"quality\":\"{}\",\"m_eff\":{},\"cache\":\"{}\",\"outcome\":\"{}\"}}}}",
                            e.seq,
                            e.width,
                            tick_us(e.at),
                            e.width,
                            e.quality.label(),
                            e.m_eff,
                            e.cache.label(),
                            outcome
                        );
                        push_event(&mut out, &b);
                    }
                }
                if e.kind == EventKind::Shed && e.seq == NO_SEQ {
                    // admission reject: no lifecycle span, just a mark
                    let mut b = String::new();
                    let _ = write!(
                        b,
                        "{{\"ph\":\"i\",\"s\":\"p\",\"cat\":\"shed\",\"pid\":1,\"tid\":0,\"ts\":{:.3},\"name\":\"{}\"}}",
                        tick_us(e.at),
                        e.shed.label()
                    );
                    push_event(&mut out, &b);
                }
            }
            EventKind::BatchFormed => {
                let mut b = String::new();
                let _ = write!(
                    b,
                    "{{\"ph\":\"i\",\"s\":\"t\",\"cat\":\"batch\",\"pid\":2,\"tid\":{},\"ts\":{:.3},\"name\":\"batch_formed\",\"args\":{{\"width\":{},\"n\":{},\"m_eff\":{}}}}}",
                    e.worker,
                    tick_us(e.at),
                    e.width,
                    e.n,
                    e.m_eff
                );
                push_event(&mut out, &b);
            }
            EventKind::ExecStart => {
                exec_open.insert(e.worker, *e);
            }
            EventKind::ExecEnd => {
                if let Some(s) = exec_open.remove(&e.worker) {
                    let ts = tick_us(s.at);
                    let dur = (tick_us(e.at) - ts).max(0.0);
                    let mut b = String::new();
                    let _ = write!(
                        b,
                        "{{\"ph\":\"X\",\"cat\":\"exec\",\"pid\":2,\"tid\":{},\"ts\":{:.3},\"dur\":{:.3},\"name\":\"exec\",\"args\":{{\"width\":{},\"n\":{},\"m_eff\":{}}}}}",
                        e.worker, ts, dur, s.width, s.n, s.m_eff
                    );
                    push_event(&mut out, &b);
                }
            }
            EventKind::Requeued => {
                // fault recovery: an entry pulled out of a dying
                // replica's batch, marked on the worker's row
                let mut b = String::new();
                let _ = write!(
                    b,
                    "{{\"ph\":\"i\",\"s\":\"t\",\"cat\":\"fault\",\"pid\":2,\"tid\":{},\"ts\":{:.3},\"name\":\"requeued\",\"args\":{{\"seq\":{},\"width\":{}}}}}",
                    e.worker,
                    tick_us(e.at),
                    e.seq,
                    e.width
                );
                push_event(&mut out, &b);
            }
            EventKind::Stolen => {
                // work changed replicas before execution: mark the
                // thief's row with how much it took
                let mut b = String::new();
                let _ = write!(
                    b,
                    "{{\"ph\":\"i\",\"s\":\"t\",\"cat\":\"steal\",\"pid\":2,\"tid\":{},\"ts\":{:.3},\"name\":\"stolen\",\"args\":{{\"width\":{},\"n\":{}}}}}",
                    e.worker,
                    tick_us(e.at),
                    e.width,
                    e.n
                );
                push_event(&mut out, &b);
            }
            EventKind::ReplicaDied | EventKind::ReplicaRestarted => {
                // a crashed ExecStart never gets its ExecEnd: drop the
                // dangling open span so the next exec on the respawned
                // worker doesn't inherit a bogus start instant
                if e.kind == EventKind::ReplicaDied {
                    exec_open.remove(&e.worker);
                }
                let mut b = String::new();
                let _ = write!(
                    b,
                    "{{\"ph\":\"i\",\"s\":\"t\",\"cat\":\"fault\",\"pid\":2,\"tid\":{},\"ts\":{:.3},\"name\":\"{}\"}}",
                    e.worker,
                    tick_us(e.at),
                    e.kind.label()
                );
                push_event(&mut out, &b);
            }
        }
    }

    // Kernel phase sub-spans, shifted onto the event timeline.
    for s in &kernel.spans {
        let ts = (s.start_ns as i64 - log.epoch_offset_ns) as f64 / 1e3;
        let mut b = String::new();
        let _ = write!(
            b,
            "{{\"ph\":\"X\",\"cat\":\"kernel\",\"pid\":3,\"tid\":{},\"ts\":{:.3},\"dur\":{:.3},\"name\":\"{}\"}}",
            s.lane,
            ts,
            s.dur_ns as f64 / 1e3,
            s.phase.label()
        );
        push_event(&mut out, &b);
    }

    let _ = write!(
        out,
        "],\"otherData\":{{\"dropped_events\":{},\"dropped_kernel_spans\":{}}}}}",
        log.dropped, kernel.dropped
    );
    out
}

/// Write [`chrome_trace_json`] to `path`, creating parent directories.
pub fn write_chrome_trace(
    path: &Path,
    log: &TraceLog,
    kernel: &KernelSnapshot,
) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, chrome_trace_json(log, kernel))
}

/// Prometheus text-exposition snapshot of the trace: per-kind event
/// counters, shed/cache breakdowns, ring drops, request latency
/// quantiles (from Queued→Replied spans), and kernel phase totals.
pub fn prometheus_text(log: &TraceLog, kernel: &KernelSnapshot) -> String {
    let mut out = String::new();
    out.push_str("# TYPE yoso_trace_events_total counter\n");
    for k in EventKind::ALL {
        let _ = writeln!(out, "yoso_trace_events_total{{kind=\"{}\"}} {}", k.label(), log.count(k));
    }
    out.push_str("# TYPE yoso_trace_shed_total counter\n");
    for t in [
        ShedTag::QueueFull,
        ShedTag::Infeasible,
        ShedTag::Expired,
        ShedTag::Closed,
        ShedTag::Internal,
    ] {
        let _ = writeln!(out, "yoso_trace_shed_total{{reason=\"{}\"}} {}", t.label(), log.count_shed(t));
    }
    out.push_str("# TYPE yoso_trace_cache_total counter\n");
    for t in [CacheTag::Hit, CacheTag::Miss] {
        let _ = writeln!(out, "yoso_trace_cache_total{{result=\"{}\"}} {}", t.label(), log.count_cache(t));
    }
    out.push_str("# TYPE yoso_trace_dropped_total counter\n");
    let _ = writeln!(out, "yoso_trace_dropped_total {}", log.dropped);

    let lat = log.request_latencies_ms();
    if !lat.is_empty() {
        let mut h = Histogram::new();
        for &ms in &lat {
            h.record(ms);
        }
        out.push_str("# TYPE yoso_request_latency_ms summary\n");
        for (q, v) in [(0.5, h.p50()), (0.95, h.p95()), (0.99, h.p99())] {
            let _ = writeln!(out, "yoso_request_latency_ms{{quantile=\"{q}\"}} {v:.6}");
        }
        let _ = writeln!(out, "yoso_request_latency_ms_count {}", lat.len());
    }

    out.push_str("# TYPE yoso_kernel_phase_ns_total counter\n");
    for p in Phase::ALL {
        let _ = writeln!(out, "yoso_kernel_phase_ns_total{{phase=\"{}\"}} {}", p.label(), kernel.total_ns(p));
    }
    out.push_str("# TYPE yoso_kernel_phase_calls_total counter\n");
    for p in Phase::ALL {
        let _ = writeln!(out, "yoso_kernel_phase_calls_total{{phase=\"{}\"}} {}", p.label(), kernel.calls(p));
    }
    out.push_str("# TYPE yoso_kernel_spans_dropped_total counter\n");
    let _ = writeln!(out, "yoso_kernel_spans_dropped_total {}", kernel.dropped);
    out
}

/// Bridge trace summaries into a [`Recorder`] so they land in the
/// existing CSV/JSON report path next to `GatewayStats::record_into`.
pub fn record_into(log: &TraceLog, kernel: &KernelSnapshot, rec: &mut Recorder) {
    for k in EventKind::ALL {
        rec.push(&format!("trace_{}", k.label()), 0.0, log.count(k) as f64);
    }
    rec.push("trace_dropped", 0.0, log.dropped as f64);
    let lat = log.request_latencies_ms();
    if !lat.is_empty() {
        let mut h = Histogram::new();
        for &ms in &lat {
            h.record(ms);
        }
        rec.push("trace_latency_p50_ms", 0.0, h.p50());
        rec.push("trace_latency_p99_ms", 0.0, h.p99());
    }
    for p in Phase::ALL {
        rec.push(&format!("kernel_{}_ns", p.label()), 0.0, kernel.total_ns(p) as f64);
        rec.push(&format!("kernel_{}_calls", p.label()), 0.0, kernel.calls(p) as f64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, ms: u64, seq: u64) -> Event {
        Event::new(kind, Tick::from_ms(ms), seq)
    }

    #[test]
    fn trace_setting_parses_like_smoke_setting() {
        assert!(trace_setting(Some("1")));
        assert!(trace_setting(Some("true")));
        assert!(!trace_setting(Some("0")));
        assert!(!trace_setting(Some("yes")));
        assert!(!trace_setting(None));
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut r: RingBuf<u64> = RingBuf::new(3);
        for i in 0..5 {
            r.push(i);
        }
        assert_eq!(r.dropped, 2);
        let mut out = Vec::new();
        r.drain_into(&mut out);
        assert_eq!(out, vec![2, 3, 4], "oldest two were overwritten");
        assert_eq!(r.len, 0, "drain resets the ring");
        // refill after wrap still works and keeps the drop counter
        for i in 10..12 {
            r.push(i);
        }
        out.clear();
        r.drain_into(&mut out);
        assert_eq!(out, vec![10, 11]);
        assert_eq!(r.dropped, 2);
    }

    #[test]
    fn sink_merges_lanes_in_time_order() {
        let sink = TraceSink::new(2, 8, 0);
        sink.emit(1, ev(EventKind::Replied, 5, 1));
        sink.emit(0, ev(EventKind::Admitted, 1, 1));
        sink.emit(0, ev(EventKind::Queued, 1, 1));
        sink.emit(1, ev(EventKind::Replied, 3, 2));
        let log = sink.drain();
        let kinds: Vec<EventKind> = log.events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![EventKind::Admitted, EventKind::Queued, EventKind::Replied, EventKind::Replied]
        );
        // same tick orders by lifecycle rank (Admitted before Queued)
        assert_eq!(log.events[0].seq, 1);
        assert_eq!(log.events[2].seq, 2, "earlier reply first");
        assert_eq!(log.dropped, 0);
        // draining emptied the lanes
        assert!(sink.drain().events.is_empty());
    }

    #[test]
    fn log_counters_and_latency() {
        let sink = TraceSink::new(1, 16, 0);
        sink.emit(0, ev(EventKind::Admitted, 0, 1));
        sink.emit(0, ev(EventKind::Queued, 0, 1));
        sink.emit(
            0,
            ev(EventKind::Replied, 10, 1).with_quality(QualityTag::Full).with_cache(CacheTag::Hit),
        );
        sink.emit(0, ev(EventKind::Shed, 2, NO_SEQ).with_shed(ShedTag::QueueFull));
        let log = sink.drain();
        assert_eq!(log.count(EventKind::Admitted), 1);
        assert_eq!(log.count_shed(ShedTag::QueueFull), 1);
        assert_eq!(log.count_shed(ShedTag::Expired), 0);
        assert_eq!(log.count_cache(CacheTag::Hit), 1);
        assert_eq!(log.count_replied_quality(QualityTag::Full), 1);
        assert_eq!(log.request_latencies_ms(), vec![10.0]);
    }

    #[test]
    fn chrome_export_is_json_shaped_and_complete() {
        let sink = TraceSink::new(1, 16, 0);
        sink.emit(0, ev(EventKind::Queued, 0, 7).with_width(64));
        sink.emit(0, ev(EventKind::BatchFormed, 1, 7).with_width(64).with_n(1).with_m_eff(8));
        sink.emit(0, ev(EventKind::ExecStart, 1, 7).with_worker(1).with_width(64).with_n(1).with_m_eff(8));
        sink.emit(0, ev(EventKind::ExecEnd, 4, 7).with_worker(1));
        sink.emit(
            0,
            ev(EventKind::Replied, 5, 7)
                .with_width(64)
                .with_quality(QualityTag::BestEffort)
                .with_m_eff(8),
        );
        let log = sink.drain();
        let kernel = KernelSnapshot {
            totals_ns: [0, 1000, 0, 0],
            calls: [0, 1, 0, 0],
            spans: vec![PhaseSpan { phase: Phase::Hash, start_ns: 1_500_000, dur_ns: 1000, lane: 0 }],
            dropped: 0,
        };
        let json = chrome_trace_json(&log, &kernel);
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"b\"") && json.contains("\"ph\":\"e\""), "request span");
        assert!(json.contains("\"name\":\"exec\""), "batch exec span");
        assert!(json.contains("\"name\":\"hash\""), "kernel sub-span");
        assert!(json.contains("\"quality\":\"best_effort\""));
        // no trailing-comma malformations around the array
        assert!(!json.contains(",]") && !json.contains("[,"));
    }

    #[test]
    fn prometheus_snapshot_lists_all_families() {
        let sink = TraceSink::new(1, 4, 0);
        sink.emit(0, ev(EventKind::Queued, 0, 1));
        sink.emit(0, ev(EventKind::Replied, 2, 1));
        let log = sink.drain();
        let text = prometheus_text(&log, &KernelSnapshot::default());
        assert!(text.contains("yoso_trace_events_total{kind=\"replied\"} 1"));
        assert!(text.contains("yoso_trace_shed_total{reason=\"queue_full\"} 0"));
        assert!(text.contains("yoso_request_latency_ms{quantile=\"0.99\"}"));
        assert!(text.contains("yoso_kernel_phase_ns_total{phase=\"scatter\"} 0"));
        assert!(text.contains("yoso_trace_dropped_total 0"));
    }

    #[test]
    fn recorder_bridge_pushes_series() {
        let sink = TraceSink::new(1, 4, 0);
        sink.emit(0, ev(EventKind::Queued, 0, 1));
        sink.emit(0, ev(EventKind::Replied, 3, 1));
        let log = sink.drain();
        let mut rec = Recorder::new();
        record_into(&log, &KernelSnapshot::default(), &mut rec);
        assert_eq!(rec.last("trace_replied"), Some(1.0));
        assert_eq!(rec.last("trace_shed"), Some(0.0));
        assert!(rec.last("trace_latency_p50_ms").is_some());
        assert_eq!(rec.last("kernel_hash_ns"), Some(0.0));
    }

    #[test]
    fn probe_disabled_records_nothing() {
        set_trace_enabled(false);
        let mut p = KernelProbe::new();
        p.begin_forward();
        p.enter(Phase::Hash);
        p.exit();
        p.finish_forward();
        assert_eq!(p.phase_total(Phase::Hash), (0, 0));
        assert!(p.spans.is_empty());
    }

    #[test]
    fn probe_enabled_accumulates_and_flushes() {
        // NOTE: gate + globals are process-wide; this test restores the
        // gate and only asserts deltas it caused.
        set_trace_enabled(true);
        let mut p = KernelProbe::new();
        let before = kernel_snapshot();
        p.begin_forward();
        p.enter(Phase::Scatter);
        p.exit();
        p.enter(Phase::Gather);
        p.exit();
        p.finish_forward();
        set_trace_enabled(false);
        let (ns, calls) = p.phase_total(Phase::Scatter);
        assert_eq!(calls, 1);
        let _ = ns; // durations may be 0ns on coarse clocks; calls are exact
        let after = kernel_snapshot();
        assert!(after.calls(Phase::Scatter) >= before.calls(Phase::Scatter) + 1);
        assert!(after.calls(Phase::Gather) >= before.calls(Phase::Gather) + 1);
        assert!(p.spans.is_empty(), "finish_forward flushed the scratch");
        assert!(p.spans.capacity() >= 2, "scratch capacity retained for reuse");
    }
}
