//! `yoso` — launcher CLI for the YOSO reproduction.
//!
//! Subcommands:
//!   info                          list artifacts and their ABIs
//!   train    --family pretrain --variant yoso_32 [--steps N --lr F]
//!   finetune --task mrpc --variant yoso_32 --checkpoint PATH
//!   lra      --task listops --variant yoso_32
//!   serve    --variant yoso_32 [--requests N]   demo serving run
//!            [--cpu]    artifact-free multi-replica CPU gateway
//!            [--trace]  flight recorder -> results/trace_serve.json
//!                       (CPU gateway only; YOSO_TRACE=1 equivalent)
//!
//! Config: defaults < --config file.json < CLI flags (see config module).

use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};
use yoso::cli::Args;
use yoso::config::RunConfig;
use yoso::data::corpus::{CorpusConfig, CorpusGenerator};
use yoso::data::glue_synth::{GlueGenerator, GlueTask};
use yoso::data::lra::{LraGenerator, LraTask};
use yoso::data::mlm::{MlmConfig, PretrainStream};
use yoso::data::tokenizer::WordTokenizer;
use yoso::info;
use yoso::metrics::Recorder;
use yoso::runtime::Runtime;
use yoso::serve::{BatchPolicy, ServerHandle};
use yoso::train::{ClsSource, PretrainSource, Trainer};

fn main() -> Result<()> {
    yoso::util::log::init_from_env();
    let args = Args::from_env();
    let cfg = RunConfig::resolve(&args)?;
    match args.positional.first().map(String::as_str) {
        Some("info") => cmd_info(&cfg),
        Some("train") => cmd_train(&args, &cfg),
        Some("finetune") => cmd_finetune(&args, &cfg),
        Some("lra") => cmd_lra(&args, &cfg),
        Some("serve") => cmd_serve(&args, &cfg),
        other => {
            eprintln!(
                "usage: yoso <info|train|finetune|lra|serve> [flags]\n\
                 got: {other:?}\nsee rust/src/main.rs header for flags"
            );
            bail!("unknown subcommand");
        }
    }
}

fn pretrain_source(seed: u64) -> PretrainSource {
    PretrainSource {
        stream: PretrainStream::new(
            CorpusGenerator::new(CorpusConfig::default()),
            WordTokenizer { n_words: 2000 },
            MlmConfig::default(),
            seed,
        ),
    }
}

fn cmd_info(cfg: &RunConfig) -> Result<()> {
    let rt = Runtime::open(Path::new(&cfg.artifacts_dir))?;
    println!("{:<34} {:>10} {:>8} {:>8}  attention", "artifact", "kind", "inputs",
             "outputs");
    for (name, spec) in &rt.manifest.artifacts {
        println!(
            "{:<34} {:>10} {:>8} {:>8}  {}",
            name,
            spec.kind,
            spec.inputs.len(),
            spec.outputs.len(),
            spec.attention
        );
    }
    Ok(())
}

fn cmd_train(args: &Args, cfg: &RunConfig) -> Result<()> {
    let family = args.get_or("family", "pretrain").to_string();
    let variant = &cfg.train.variant;
    let rt = Runtime::open(Path::new(&cfg.artifacts_dir))?;
    let train_name = format!("train_{family}_{variant}");
    let eval_name = format!("eval_{family}_{variant}");
    let eval = rt.manifest.get(&eval_name).ok().map(|_| eval_name.as_str());

    let mut trainer = Trainer::new(&rt, &train_name, eval, cfg.seed, None)?;
    info!(
        "training {train_name}: {} params ({} tensors)",
        trainer.param_template.total_elements(),
        trainer.param_template.len()
    );
    let source = pretrain_source(cfg.seed);
    let mut rec = Recorder::new();
    trainer.run(
        &source,
        cfg.train.steps,
        cfg.train.lr,
        cfg.train.eval_every,
        cfg.train.eval_batches,
        cfg.train.log_every,
        &mut rec,
    )?;
    let results = PathBuf::from(&cfg.results_dir);
    rec.write_csv(&results.join(format!("train_{family}_{variant}.csv")))?;
    let ckpt = PathBuf::from(&cfg.checkpoint_dir)
        .join(format!("{family}_{variant}.ckpt"));
    trainer.save_checkpoint(&ckpt)?;
    info!("checkpoint -> {ckpt:?}");
    Ok(())
}

fn cmd_finetune(args: &Args, cfg: &RunConfig) -> Result<()> {
    let task_name = args.get_or("task", "mrpc");
    let task = GlueTask::all()
        .into_iter()
        .find(|t| t.name() == task_name)
        .with_context(|| format!("unknown GLUE task {task_name}"))?;
    let variant = &cfg.train.variant;
    let rt = Runtime::open(Path::new(&cfg.artifacts_dir))?;

    let init = match args.get("checkpoint") {
        Some(p) => Some(yoso::train::checkpoint::load(Path::new(p))?),
        None => None,
    };
    let train_name = format!("train_glue_{variant}");
    let eval_name = format!("eval_glue_{variant}");
    let mut trainer =
        Trainer::new(&rt, &train_name, Some(&eval_name), cfg.seed, init)?;
    let source = ClsSource::Glue(GlueGenerator::new(task, 128, cfg.seed));
    let mut rec = Recorder::new();
    trainer.run(
        &source,
        cfg.train.steps,
        cfg.train.lr,
        cfg.train.eval_every,
        cfg.train.eval_batches,
        cfg.train.log_every,
        &mut rec,
    )?;
    let eval = trainer.evaluate(&source, cfg.train.eval_batches)?;
    println!(
        "finetune {task_name} {variant}: acc {:.4} (metric: {})",
        eval.accuracy,
        task.metric()
    );
    rec.write_csv(
        &PathBuf::from(&cfg.results_dir)
            .join(format!("glue_{task_name}_{variant}.csv")),
    )?;
    Ok(())
}

fn cmd_lra(args: &Args, cfg: &RunConfig) -> Result<()> {
    let task_name = args.get_or("task", "listops");
    let task = LraTask::all()
        .into_iter()
        .find(|t| t.name() == task_name)
        .with_context(|| format!("unknown LRA task {task_name}"))?;
    let variant = &cfg.train.variant;
    let rt = Runtime::open(Path::new(&cfg.artifacts_dir))?;
    let mut trainer = Trainer::new(
        &rt,
        &format!("train_lra_{variant}"),
        Some(&format!("eval_lra_{variant}")),
        cfg.seed,
        None,
    )?;
    let source = ClsSource::Lra(LraGenerator::new(task, 256, cfg.seed));
    let mut rec = Recorder::new();
    trainer.run(
        &source,
        cfg.train.steps,
        cfg.train.lr,
        cfg.train.eval_every,
        cfg.train.eval_batches,
        cfg.train.log_every,
        &mut rec,
    )?;
    let eval = trainer.evaluate(&source, cfg.train.eval_batches)?;
    println!("lra {task_name} {variant}: accuracy {:.4}", eval.accuracy);
    rec.write_csv(
        &PathBuf::from(&cfg.results_dir)
            .join(format!("lra_{task_name}_{variant}.csv")),
    )?;
    Ok(())
}

/// `--trace` flag (or `YOSO_TRACE=1`): flight-recorder tracing on.
fn trace_requested(args: &Args) -> bool {
    args.has_flag("trace")
        || args.get("trace").is_some_and(|v| yoso::obs::trace_setting(Some(v)))
        || yoso::obs::trace_enabled()
}

fn cmd_serve(args: &Args, cfg: &RunConfig) -> Result<()> {
    if args.has_flag("cpu") || args.get("cpu").is_some() {
        return cmd_serve_cpu(args, cfg);
    }
    if trace_requested(args) {
        info!(
            "--trace: the artifact executor has no flight recorder \
             (request lifecycle + kernel phases are CPU-gateway \
             instruments) — use `serve --cpu --trace`"
        );
    }
    let variant = &cfg.train.variant;
    let n_requests = args.get_usize("requests", 256);
    let artifact = format!("fwd_glue_{variant}");
    let handle = ServerHandle::spawn(
        PathBuf::from(&cfg.artifacts_dir),
        artifact.clone(),
        BatchPolicy {
            max_batch: cfg.serve.max_batch,
            max_wait: std::time::Duration::from_millis(cfg.serve.max_wait_ms),
        },
        cfg.seed,
        args.get("checkpoint").map(PathBuf::from),
    );

    // drive a synthetic open-loop workload
    let gen = GlueGenerator::new(GlueTask::Qnli, 128, cfg.seed + 1);
    let mut receivers = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let ex = gen.example(i as u64);
        receivers.push(handle.submit(ex.input_ids, ex.segment_ids));
        if i % 8 == 7 {
            std::thread::sleep(std::time::Duration::from_micros(500));
        }
    }
    let mut got = 0usize;
    for rx in receivers {
        if rx.recv().is_ok() {
            got += 1;
        }
    }
    let stats = handle.shutdown()?;
    println!("served {got}/{n_requests} (artifact {artifact}) | {stats}");
    Ok(())
}

/// `serve --cpu`: the artifact-free multi-replica gateway (pure-Rust
/// encoder + attention zoo). With `--trace` (or `YOSO_TRACE=1`) the
/// run's flight-recorder events and kernel phase spans are written as a
/// Chrome `trace_event` timeline to `results/trace_serve.json` and a
/// Prometheus-style snapshot is printed.
fn cmd_serve_cpu(args: &Args, cfg: &RunConfig) -> Result<()> {
    use yoso::serve::{CpuServeConfig, Gateway, GatewayConfig};

    let trace = trace_requested(args);
    if trace {
        // flip the process gate too, so the fused kernel's phase probes
        // record alongside the gateway's lifecycle events
        yoso::obs::set_trace_enabled(true);
    }
    let n_requests = args.get_usize("requests", 256);
    let mut gcfg = GatewayConfig::new(CpuServeConfig {
        attention: cfg.train.variant.clone(),
        seed: cfg.seed,
        threads: 1,
        ..CpuServeConfig::default()
    });
    gcfg.replicas = cfg.serve.workers.max(1);
    gcfg.trace = trace;
    let gw = Gateway::spawn(gcfg);
    let submitter = gw.submitter();

    let gen = GlueGenerator::new(GlueTask::Qnli, 128, cfg.seed + 1);
    let mut receivers = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let ex = gen.example(i as u64);
        if let Ok(rx) = submitter.submit(ex.input_ids, ex.segment_ids) {
            receivers.push(rx);
        }
        if i % 8 == 7 {
            std::thread::sleep(std::time::Duration::from_micros(500));
        }
    }
    let mut got = 0usize;
    for rx in receivers {
        if matches!(rx.recv(), Ok(Ok(_))) {
            got += 1;
        }
    }
    let sink = gw.trace_sink();
    let stats = gw.shutdown();
    println!("served {got}/{n_requests} (cpu gateway) | {stats}");
    if let Some(sink) = sink {
        let log = sink.drain();
        let kernel = yoso::obs::kernel_snapshot();
        let path = PathBuf::from(&cfg.results_dir).join("trace_serve.json");
        yoso::obs::write_chrome_trace(&path, &log, &kernel)?;
        println!(
            "trace: {} events, {} kernel spans -> {}",
            log.events.len(),
            kernel.spans.len(),
            path.display()
        );
        print!("{}", yoso::obs::prometheus_text(&log, &kernel));
    }
    Ok(())
}
