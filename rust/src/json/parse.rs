//! Recursive-descent JSON parser.

use super::Value;
use std::collections::BTreeMap;

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("bad low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // reassemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.pos - 1;
                        for _ in 1..len {
                            self.bump();
                        }
                        let s = std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("eof in \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numbers() {
        for (s, v) in [("0", 0.0), ("-1", -1.0), ("2.5", 2.5), ("1e3", 1000.0),
                       ("-1.5e-2", -0.015)] {
            assert_eq!(parse(s).unwrap(), Value::Number(v), "{s}");
        }
    }

    #[test]
    fn strings_escapes() {
        assert_eq!(parse(r#""a\nb\t\"c\"""#).unwrap(),
                   Value::String("a\nb\t\"c\"".into()));
        assert_eq!(parse(r#""A""#).unwrap(), Value::String("A".into()));
        assert_eq!(parse(r#""😀""#).unwrap(),
                   Value::String("😀".into()));
    }

    #[test]
    fn unicode_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Value::String("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["", "{", "[1,]", "{\"a\":}", "tru", "1 2", "\"\\x\""] {
            assert!(parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn nested() {
        let v = parse(r#"[[1,[2,[3]]],{"a":[{"b":null}]}]"#).unwrap();
        assert_eq!(v.as_array().unwrap().len(), 2);
    }
}
