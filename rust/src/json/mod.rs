//! Minimal JSON substrate (the offline registry has no serde).
//!
//! Full RFC 8259 parser + emitter over an owned [`Value`] tree. Used for
//! the artifact manifest, config files, and results emission. Not
//! performance-critical: the largest document is the ~100 KB manifest.

mod emit;
mod parse;

pub use emit::to_string_pretty;
pub use parse::{parse, ParseError};

use std::collections::BTreeMap;

/// Owned JSON value. Object keys are sorted (BTreeMap) so emission is
/// deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; None for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Chained path access: `v.path(&["config", "batch"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn array_of_f64(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|x| Value::Number(*x)).collect())
    }

    pub fn str(s: &str) -> Value {
        Value::String(s.to_string())
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Value {
        Value::Number(v)
    }
}

impl From<usize> for Value {
    fn from(v: usize) -> Value {
        Value::Number(v as f64)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Value {
        Value::String(v.to_string())
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Value {
        Value::Bool(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\n", "d": true}, "e": null}"#;
        let v = parse(src).unwrap();
        let emitted = to_string_pretty(&v);
        let v2 = parse(&emitted).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn path_access() {
        let v = parse(r#"{"x": {"y": {"z": 7}}}"#).unwrap();
        assert_eq!(v.path(&["x", "y", "z"]).unwrap().as_i64(), Some(7));
        assert!(v.path(&["x", "nope"]).is_none());
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "t", "b": false, "a": [1]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("t"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("a").unwrap().as_array().unwrap().len(), 1);
        assert!(v.get("n").unwrap().as_str().is_none());
    }
}
