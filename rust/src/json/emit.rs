//! JSON emission (pretty, deterministic key order).

use super::Value;

pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    emit(v, 0, &mut out);
    out
}

fn emit(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Number(n) => emit_number(*n, out),
        Value::String(s) => emit_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                pad(indent + 1, out);
                emit(item, indent + 1, out);
            }
            out.push('\n');
            pad(indent, out);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                pad(indent + 1, out);
                emit_string(k, out);
                out.push_str(": ");
                emit(val, indent + 1, out);
            }
            out.push('\n');
            pad(indent, out);
            out.push('}');
        }
    }
}

fn pad(indent: usize, out: &mut String) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn emit_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; emit null like serde_json's lossy mode.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        out.push_str(&(n as i64).to_string());
    } else {
        out.push_str(&n.to_string());
    }
}

fn emit_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;

    #[test]
    fn integers_stay_integers() {
        assert_eq!(to_string_pretty(&Value::Number(42.0)), "42");
        assert_eq!(to_string_pretty(&Value::Number(-0.5)), "-0.5");
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::String("a\"b\\c\nd\u{0001}".into());
        let s = to_string_pretty(&v);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(to_string_pretty(&Value::Number(f64::NAN)), "null");
    }
}
