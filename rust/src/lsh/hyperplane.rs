//! Gaussian hyperplane LSH (SimHash, Charikar 2002).
//!
//! For unit vectors u, v: P[sign(r.u) = sign(r.v)] = 1 - theta(u,v)/pi
//! per hyperplane; concatenating tau hyperplanes gives the paper's
//! collision probability (1 - theta/pi)^tau.

use super::Hasher;
use crate::tensor::{linalg, Mat};
use crate::util::Rng;

/// m independent hashes, each the concatenation of tau Gaussian
/// hyperplanes. Rotations stored as (m*tau, d) rows for cache-friendly
/// projection.
pub struct HyperplaneHasher {
    pub tau: usize,
    pub m: usize,
    pub d: usize,
    planes: Mat, // (m * tau, d)
}

impl HyperplaneHasher {
    pub fn new(rng: &mut Rng, m: usize, d: usize, tau: usize) -> Self {
        assert!(tau <= 24, "packed codes use u32; tau too large");
        HyperplaneHasher { tau, m, d, planes: Mat::randn(m * tau, d, 1.0, rng) }
    }

    /// Hash one vector for hash function `h`.
    pub fn hash_one(&self, x: &[f32], h: usize) -> u32 {
        let mut code = 0u32;
        for t in 0..self.tau {
            let plane = self.planes.row(h * self.tau + t);
            if linalg::dot(plane, x) >= 0.0 {
                code |= 1 << t;
            }
        }
        code
    }
}

impl Hasher for HyperplaneHasher {
    fn tau(&self) -> usize {
        self.tau
    }

    fn n_hashes(&self) -> usize {
        self.m
    }

    fn hash_all(&self, x: &Mat) -> Vec<u32> {
        assert_eq!(x.cols, self.d);
        let n = x.rows;
        let mut codes = vec![0u32; self.m * n];
        for i in 0..n {
            let row = x.row(i);
            for h in 0..self.m {
                codes[h * n + i] = self.hash_one(row, h);
            }
        }
        codes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::collision::collision_probability;

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(0);
        let hasher = HyperplaneHasher::new(&mut rng, 4, 16, 6);
        let x = Mat::randn(32, 16, 1.0, &mut rng).unit_rows();
        let codes = hasher.hash_all(&x);
        assert_eq!(codes.len(), 4 * 32);
        assert!(codes.iter().all(|&c| c < 64));
    }

    #[test]
    fn identical_vectors_always_collide() {
        let mut rng = Rng::new(1);
        let hasher = HyperplaneHasher::new(&mut rng, 8, 16, 8);
        let x = Mat::randn(1, 16, 1.0, &mut rng).unit_rows();
        let a = hasher.hash_all(&x);
        let b = hasher.hash_all(&x);
        assert_eq!(a, b);
    }

    #[test]
    fn empirical_collision_rate_matches_theory() {
        // Monte-Carlo over many hashes: the empirical collision frequency
        // of a fixed pair must approach (1 - theta/pi)^tau.
        let mut rng = Rng::new(2);
        let d = 24;
        let tau = 4;
        let m = 4000;
        let hasher = HyperplaneHasher::new(&mut rng, m, d, tau);
        // build a pair at a known angle
        let mut x = Mat::zeros(2, d);
        x.set(0, 0, 1.0);
        let angle = 0.9f32; // radians
        x.set(1, 0, angle.cos());
        x.set(1, 1, angle.sin());
        let codes = hasher.hash_all(&x);
        let n = 2;
        let mut hits = 0usize;
        for h in 0..m {
            if codes[h * n] == codes[h * n + 1] {
                hits += 1;
            }
        }
        let emp = hits as f64 / m as f64;
        let theory = collision_probability(angle.cos() as f64, tau as u32);
        assert!(
            (emp - theory).abs() < 0.03,
            "empirical {emp:.4} vs theory {theory:.4}"
        );
    }
}
