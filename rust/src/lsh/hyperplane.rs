//! Gaussian hyperplane LSH (SimHash, Charikar 2002).
//!
//! For unit vectors u, v: P[sign(r.u) = sign(r.v)] = 1 - theta(u,v)/pi
//! per hyperplane; concatenating tau hyperplanes gives the paper's
//! collision probability (1 - theta/pi)^tau.
//!
//! Two hashing paths share the same planes and produce bit-identical
//! codes (every projection is exactly `linalg::dot`, and f32 multiply
//! commutes bitwise):
//!
//! * `hash_all` — one blocked matmul_t of the input against the whole
//!   (m·tau, d) plane matrix, then sign extraction. The fast default.
//! * `hash_one` / `hash_all_seed` — the seed repo's per-token projection
//!   loop, kept verbatim as the `KernelVariant::Seed` A/B baseline.
//!
//! `hash_block_into` is the fused kernel's zero-allocation entry: codes
//! of one hash for every row, written into caller (arena) buffers.

use super::Hasher;
use crate::tensor::{linalg, Mat};
use crate::util::Rng;

/// m independent hashes, each the concatenation of tau Gaussian
/// hyperplanes. Rotations stored as (m*tau, d) rows for cache-friendly
/// projection.
pub struct HyperplaneHasher {
    pub tau: usize,
    pub m: usize,
    pub d: usize,
    planes: Mat, // (m * tau, d)
}

impl HyperplaneHasher {
    pub fn new(rng: &mut Rng, m: usize, d: usize, tau: usize) -> Self {
        assert!(tau <= 24, "packed codes use u32; tau too large");
        HyperplaneHasher { tau, m, d, planes: Mat::randn(m * tau, d, 1.0, rng) }
    }

    /// Redraw the planes in place, consuming the exact RNG sequence
    /// `new` would: an arena-held hasher refilled this way is
    /// bit-identical to a freshly constructed one, minus the allocation.
    pub fn refill(&mut self, rng: &mut Rng) {
        for p in self.planes.data.iter_mut() {
            *p = rng.normal();
        }
    }

    /// Hash one vector for hash function `h`.
    pub fn hash_one(&self, x: &[f32], h: usize) -> u32 {
        let mut code = 0u32;
        for t in 0..self.tau {
            let plane = self.planes.row(h * self.tau + t);
            if linalg::dot(plane, x) >= 0.0 {
                code |= 1 << t;
            }
        }
        code
    }

    /// The seed repo's `hash_all`: per-token, per-hash `hash_one` loop.
    /// Kept verbatim as the kernel A/B baseline (`KernelVariant::Seed`);
    /// codes are bit-identical to the matmul-backed `hash_all`.
    pub fn hash_all_seed(&self, x: &Mat) -> Vec<u32> {
        assert_eq!(x.cols, self.d);
        let n = x.rows;
        let mut codes = vec![0u32; self.m * n];
        for i in 0..n {
            let row = x.row(i);
            for h in 0..self.m {
                codes[h * n + i] = self.hash_one(row, h);
            }
        }
        codes
    }

    /// Codes of hash `h` for every row of `x`, matmul-backed and
    /// allocation-free: projections land in `proj` (>= n·tau floats, an
    /// (n, tau) block), sign bits in `codes` (>= n slots). Rows are
    /// tiled 8 at a time so each plane row streams from cache once per
    /// tile instead of once per token; every projection is still exactly
    /// `linalg::dot`, so codes match `hash_one` bit-for-bit.
    pub fn hash_block_into(
        &self,
        x: &Mat,
        h: usize,
        proj: &mut [f32],
        codes: &mut [u32],
    ) {
        assert_eq!(x.cols, self.d);
        assert!(h < self.m);
        let n = x.rows;
        let tau = self.tau;
        let proj = &mut proj[..n * tau];
        let codes = &mut codes[..n];
        let row0 = h * tau;
        let mut i0 = 0;
        while i0 < n {
            let i1 = (i0 + 8).min(n);
            for t in 0..tau {
                let plane = self.planes.row(row0 + t);
                for i in i0..i1 {
                    proj[i * tau + t] = linalg::dot(x.row(i), plane);
                }
            }
            i0 = i1;
        }
        for (i, code) in codes.iter_mut().enumerate() {
            let mut c = 0u32;
            for (t, &p) in proj[i * tau..(i + 1) * tau].iter().enumerate() {
                if p >= 0.0 {
                    c |= 1 << t;
                }
            }
            *code = c;
        }
    }
}

impl Hasher for HyperplaneHasher {
    fn tau(&self) -> usize {
        self.tau
    }

    fn n_hashes(&self) -> usize {
        self.m
    }

    fn hash_all(&self, x: &Mat) -> Vec<u32> {
        assert_eq!(x.cols, self.d);
        let n = x.rows;
        // One blocked matmul against the whole (m·tau, d) plane matrix —
        // the tiling in `matmul_nt_into` streams the planes once per
        // 8-token tile instead of once per token — then sign extraction.
        // Each element is exactly `dot`, so codes equal `hash_one`'s.
        let mut proj = Mat::zeros(n, self.m * self.tau);
        linalg::matmul_nt_into(x, &self.planes, &mut proj);
        let mut codes = vec![0u32; self.m * n];
        for i in 0..n {
            let prow = proj.row(i);
            for h in 0..self.m {
                let mut code = 0u32;
                for t in 0..self.tau {
                    if prow[h * self.tau + t] >= 0.0 {
                        code |= 1 << t;
                    }
                }
                codes[h * n + i] = code;
            }
        }
        codes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lsh::collision::collision_probability;

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(0);
        let hasher = HyperplaneHasher::new(&mut rng, 4, 16, 6);
        let x = Mat::randn(32, 16, 1.0, &mut rng).unit_rows();
        let codes = hasher.hash_all(&x);
        assert_eq!(codes.len(), 4 * 32);
        assert!(codes.iter().all(|&c| c < 64));
    }

    #[test]
    fn identical_vectors_always_collide() {
        let mut rng = Rng::new(1);
        let hasher = HyperplaneHasher::new(&mut rng, 8, 16, 8);
        let x = Mat::randn(1, 16, 1.0, &mut rng).unit_rows();
        let a = hasher.hash_all(&x);
        let b = hasher.hash_all(&x);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "tau too large")]
    fn tau_beyond_code_width_panics() {
        // packed codes are u32 with a sign bit per tau: the ctor must
        // reject widths the code type cannot hold (satellite hardening)
        let mut rng = Rng::new(2);
        let _ = HyperplaneHasher::new(&mut rng, 1, 16, 25);
    }

    #[test]
    fn matmul_hash_matches_seed_loop_and_hash_one() {
        // the three hashing paths (blocked matmul, per-hash block into
        // caller buffers, per-token seed loop) must agree exactly
        let mut rng = Rng::new(3);
        let hasher = HyperplaneHasher::new(&mut rng, 5, 24, 7);
        let x = Mat::randn(37, 24, 1.0, &mut rng).unit_rows();
        let fast = hasher.hash_all(&x);
        let seed = hasher.hash_all_seed(&x);
        assert_eq!(fast, seed);
        let n = x.rows;
        let mut proj = vec![0.0f32; n * hasher.tau];
        let mut codes = vec![0u32; n];
        for h in 0..hasher.m {
            hasher.hash_block_into(&x, h, &mut proj, &mut codes);
            assert_eq!(&codes[..], &fast[h * n..(h + 1) * n], "hash {h}");
            for i in 0..n {
                assert_eq!(codes[i], hasher.hash_one(x.row(i), h));
            }
        }
    }

    #[test]
    fn refill_matches_fresh_construction() {
        let mut r1 = Rng::new(9);
        let fresh = HyperplaneHasher::new(&mut r1, 3, 16, 5);
        // build with one seed, refill with another: must equal `fresh`
        let mut r0 = Rng::new(1234);
        let mut reused = HyperplaneHasher::new(&mut r0, 3, 16, 5);
        let mut r2 = Rng::new(9);
        reused.refill(&mut r2);
        let mut rx = Rng::new(77);
        let x = Mat::randn(12, 16, 1.0, &mut rx).unit_rows();
        assert_eq!(fresh.hash_all(&x), reused.hash_all(&x));
    }

    #[test]
    fn empirical_collision_rate_matches_theory() {
        // Monte-Carlo over many hashes: the empirical collision frequency
        // of a fixed pair must approach (1 - theta/pi)^tau.
        let mut rng = Rng::new(2);
        let d = 24;
        let tau = 4;
        let m = 4000;
        let hasher = HyperplaneHasher::new(&mut rng, m, d, tau);
        // build a pair at a known angle
        let mut x = Mat::zeros(2, d);
        x.set(0, 0, 1.0);
        let angle = 0.9f32; // radians
        x.set(1, 0, angle.cos());
        x.set(1, 1, angle.sin());
        let codes = hasher.hash_all(&x);
        let n = 2;
        let mut hits = 0usize;
        for h in 0..m {
            if codes[h * n] == codes[h * n + 1] {
                hits += 1;
            }
        }
        let emp = hits as f64 / m as f64;
        let theory = collision_probability(angle.cos() as f64, tau as u32);
        assert!(
            (emp - theory).abs() < 0.03,
            "empirical {emp:.4} vs theory {theory:.4}"
        );
    }
}
