//! LSH substrate: hyperplane (SimHash) hashing, the fast-Hadamard
//! approximated random projection (Andoni et al., 2015), and the
//! collision-probability math of the paper (Figure 2).

pub mod collision;
pub mod hadamard;
pub mod hyperplane;

pub use collision::{collision_probability, collision_probability_grad,
                    collision_probability_grad_lower_bound};
pub use hadamard::HadamardHasher;
pub use hyperplane::HyperplaneHasher;

/// Common interface: map each row of `x` (n, d) to a packed code in
/// [0, 2^tau) for each of `m` independent hashes. Output layout: (m, n).
pub trait Hasher {
    fn tau(&self) -> usize;
    fn n_hashes(&self) -> usize;
    /// codes[h * n + i] = f_h(x_i)
    fn hash_all(&self, x: &crate::tensor::Mat) -> Vec<u32>;
}
