//! Collision-probability math (paper Figure 2 and Eqs. 3-4).

use std::f64::consts::PI;

const SIM_EPS: f64 = 1e-9;

/// E[B]_ij = (1 - arccos(sim)/pi)^tau.
pub fn collision_probability(sim: f64, tau: u32) -> f64 {
    let sim = sim.clamp(-1.0 + SIM_EPS, 1.0 - SIM_EPS);
    (1.0 - sim.acos() / PI).powi(tau as i32)
}

/// True derivative d/dsim (Eq. 3 weight). Diverges at |sim| -> 1.
pub fn collision_probability_grad(sim: f64, tau: u32) -> f64 {
    let sim = sim.clamp(-1.0 + SIM_EPS, 1.0 - SIM_EPS);
    let base = 1.0 - sim.acos() / PI;
    tau as f64 * base.powi(tau as i32 - 1) / (PI * (1.0 - sim * sim).sqrt())
}

/// The paper's numerically-safe lower bound (tau/2) * E[B] (Eq. 4).
pub fn collision_probability_grad_lower_bound(sim: f64, tau: u32) -> f64 {
    0.5 * tau as f64 * collision_probability(sim, tau)
}

/// Softmax-style attention weight exp(tau * (sim - 1)) — the curve the
/// paper compares against in Figure 2.
pub fn exp_weight(sim: f64, tau: u32) -> f64 {
    (tau as f64 * (sim - 1.0)).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints() {
        // sim is clamped away from the poles, so "1.0" lands at 1 - eps.
        assert!((collision_probability(1.0, 8) - 1.0).abs() < 1e-3);
        assert!(collision_probability(-1.0, 8) < 1e-6);
    }

    #[test]
    fn monotonic_increasing() {
        let mut prev = 0.0;
        for i in 0..=100 {
            let sim = -1.0 + 2.0 * i as f64 / 100.0;
            let p = collision_probability(sim, 4);
            assert!(p >= prev);
            prev = p;
        }
    }

    #[test]
    fn lower_bound_holds_everywhere() {
        for tau in [1u32, 2, 4, 8, 12] {
            for i in 0..200 {
                let sim = -0.999 + 1.998 * i as f64 / 199.0;
                let lb = collision_probability_grad_lower_bound(sim, tau);
                let g = collision_probability_grad(sim, tau);
                assert!(lb <= g + 1e-9, "tau={tau} sim={sim} lb={lb} g={g}");
            }
        }
    }

    #[test]
    fn grad_is_derivative() {
        // finite differences
        let tau = 6;
        for sim in [-0.8, -0.2, 0.0, 0.4, 0.9] {
            let h = 1e-6;
            let fd = (collision_probability(sim + h, tau)
                - collision_probability(sim - h, tau))
                / (2.0 * h);
            let an = collision_probability_grad(sim, tau);
            assert!(
                (fd - an).abs() / an.max(1e-9) < 1e-3,
                "sim={sim}: fd={fd} an={an}"
            );
        }
    }

    #[test]
    fn variance_bounded_by_mean() {
        for i in 0..100 {
            let sim = -0.99 + 1.98 * i as f64 / 99.0;
            let p = collision_probability(sim, 8);
            assert!(p * (1.0 - p) <= p + 1e-12);
        }
    }
}
