//! Fast Hadamard-transform LSH (Andoni et al., 2015 "HD3" construction).
//!
//! Replaces the dense (d x tau) Gaussian projection with three rounds of
//! (random sign diagonal, Walsh–Hadamard transform), taking the first tau
//! coordinates' signs: O(tau + d log d) per token instead of O(tau * d).
//! This is the "Speed-up" paragraph of paper §3.2.
//!
//! Honest CPU caveat (EXPERIMENTS.md §Perf): at the paper's tau <= 8 the
//! construction costs 3 d log2 d > tau d raw ops, so on this substrate the
//! vectorized dense projection is faster; the trick pays off when tau
//! approaches d (or on hardware where the dense projection is
//! memory-bound). Both hashers are provided and statistically equivalent
//! (tests below).

use super::Hasher;
use crate::tensor::Mat;
use crate::util::Rng;

/// HD3 rounds of (random sign diagonal, Walsh–Hadamard transform).
pub const ROUNDS: usize = 3;

pub struct HadamardHasher {
    pub tau: usize,
    pub m: usize,
    pub d: usize,
    /// (m, ROUNDS, d) sign diagonals, flattened.
    signs: Vec<f32>,
}

/// In-place unnormalized Walsh–Hadamard transform; `x.len()` must be a
/// power of two.
pub fn fwht(x: &mut [f32]) {
    let d = x.len();
    debug_assert!(d.is_power_of_two());
    let mut h = 1;
    while h < d {
        let mut i = 0;
        while i < d {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += 2 * h;
        }
        h *= 2;
    }
}

impl HadamardHasher {
    pub fn new(rng: &mut Rng, m: usize, d: usize, tau: usize) -> Self {
        assert!(d.is_power_of_two(), "Hadamard needs power-of-two dim");
        assert!(tau <= d && tau <= 24, "tau too large");
        let signs = (0..m * ROUNDS * d).map(|_| rng.sign()).collect();
        HadamardHasher { tau, m, d, signs }
    }

    /// Redraw the sign diagonals in place, consuming the exact RNG
    /// sequence `new` would: an arena-held hasher refilled this way is
    /// bit-identical to a freshly constructed one, minus the allocation.
    pub fn refill(&mut self, rng: &mut Rng) {
        for s in self.signs.iter_mut() {
            *s = rng.sign();
        }
    }

    /// Codes of hash `h` for every row of `x`, written into caller
    /// buffers: `buf` is the (n, d) transform scratch (>= n·d floats —
    /// the fused kernel hands its arena's slot here, so steady-state
    /// hashing allocates nothing), `codes` gets one slot per row. The
    /// batch-matrix transform structure (rounds applied matrix-at-a-time
    /// for sign-diagonal cache reuse and long vectorizable loops; see
    /// EXPERIMENTS.md §Perf) is unchanged from `hash_all`, so codes are
    /// identical.
    pub fn hash_block_into(
        &self,
        x: &Mat,
        h: usize,
        buf: &mut [f32],
        codes: &mut [u32],
    ) {
        assert_eq!(x.cols, self.d);
        assert!(h < self.m);
        let n = x.rows;
        let d = self.d;
        let buf = &mut buf[..n * d];
        let codes = &mut codes[..n];
        buf.copy_from_slice(&x.data);
        for r in 0..ROUNDS {
            let base = (h * ROUNDS + r) * d;
            let signs = &self.signs[base..base + d];
            for row in buf.chunks_exact_mut(d) {
                for (v, s) in row.iter_mut().zip(signs) {
                    *v *= s;
                }
                fwht(row);
            }
        }
        for (i, row) in buf.chunks_exact(d).enumerate() {
            let mut code = 0u32;
            for t in 0..self.tau {
                if row[t] >= 0.0 {
                    code |= 1 << t;
                }
            }
            codes[i] = code;
        }
    }
}

impl Hasher for HadamardHasher {
    fn tau(&self) -> usize {
        self.tau
    }

    fn n_hashes(&self) -> usize {
        self.m
    }

    fn hash_all(&self, x: &Mat) -> Vec<u32> {
        assert_eq!(x.cols, self.d);
        let n = x.rows;
        let mut codes = vec![0u32; self.m * n];
        let mut buf = vec![0.0f32; n * self.d];
        for h in 0..self.m {
            self.hash_block_into(x, h, &mut buf, &mut codes[h * n..(h + 1) * n]);
        }
        codes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_involution() {
        let mut rng = Rng::new(0);
        let orig: Vec<f32> = (0..32).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        fwht(&mut x);
        fwht(&mut x);
        for (a, b) in x.iter().zip(&orig) {
            assert!((a / 32.0 - b).abs() < 1e-4);
        }
    }

    #[test]
    fn fwht_d2_matches_hand() {
        let mut x = vec![1.0f32, 2.0];
        fwht(&mut x);
        assert_eq!(x, vec![3.0, -1.0]);
    }

    #[test]
    #[should_panic(expected = "tau too large")]
    fn tau_beyond_code_width_panics() {
        let mut rng = Rng::new(7);
        let _ = HadamardHasher::new(&mut rng, 1, 32, 25);
    }

    #[test]
    fn block_into_matches_hash_all_and_refill_matches_new() {
        let mut rng = Rng::new(5);
        let fresh = HadamardHasher::new(&mut rng, 4, 32, 6);
        let x = Mat::randn(19, 32, 1.0, &mut rng).unit_rows();
        let all = fresh.hash_all(&x);
        let mut buf = vec![0.0f32; x.rows * 32];
        let mut codes = vec![0u32; x.rows];
        for h in 0..fresh.m {
            fresh.hash_block_into(&x, h, &mut buf, &mut codes);
            assert_eq!(&codes[..], &all[h * x.rows..(h + 1) * x.rows], "hash {h}");
        }
        // arena-style reuse: refill must reproduce a fresh construction
        let mut r0 = Rng::new(999);
        let mut reused = HadamardHasher::new(&mut r0, 4, 32, 6);
        let mut r1 = Rng::new(5);
        reused.refill(&mut r1);
        assert_eq!(reused.hash_all(&x), all);
    }

    #[test]
    fn deterministic_and_in_range() {
        let mut rng = Rng::new(1);
        let hasher = HadamardHasher::new(&mut rng, 3, 32, 5);
        let x = Mat::randn(16, 32, 1.0, &mut rng).unit_rows();
        let a = hasher.hash_all(&x);
        let b = hasher.hash_all(&x);
        assert_eq!(a, b);
        assert!(a.iter().all(|&c| c < 32));
    }

    #[test]
    fn approximate_angle_preservation() {
        // HD3 hashing must give collision statistics close to the exact
        // hyperplane hasher for the same pair of vectors.
        use crate::lsh::collision::collision_probability;
        let mut rng = Rng::new(2);
        let d = 64;
        let tau = 3;
        let m = 4000;
        let hasher = HadamardHasher::new(&mut rng, m, d, tau);
        let mut x = Mat::zeros(2, d);
        x.set(0, 0, 1.0);
        let angle = 0.7f32;
        x.set(1, 0, angle.cos());
        x.set(1, 1, angle.sin());
        let codes = hasher.hash_all(&x);
        let hits = (0..m).filter(|h| codes[h * 2] == codes[h * 2 + 1]).count();
        let emp = hits as f64 / m as f64;
        let theory = collision_probability(angle.cos() as f64, tau as u32);
        assert!(
            (emp - theory).abs() < 0.05,
            "empirical {emp:.4} vs theory {theory:.4}"
        );
    }
}
