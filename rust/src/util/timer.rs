//! Wall-clock timing helpers for the bench harness and metrics.

use std::time::Instant;

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }
}

/// Run `f` `iters` times, returning per-iteration seconds.
pub fn time_iters<F: FnMut()>(iters: usize, mut f: F) -> Vec<f64> {
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        out.push(t.elapsed_secs());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::hint::black_box((0..1000).sum::<u64>());
        assert!(t.elapsed_secs() >= 0.0);
    }

    #[test]
    fn time_iters_count() {
        assert_eq!(time_iters(5, || {}).len(), 5);
    }
}
