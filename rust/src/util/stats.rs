//! Streaming and batch statistics used by benches and metrics.

/// Batch summary of a sample: mean/std/min/max/percentiles.
///
/// The percentile fields are **exact order statistics** (sorted-select,
/// see [`quantile_exact`]): each is a value that actually occurred in the
/// sample, which is what latency SLO reporting wants — an interpolated
/// p99 can name a latency no request ever saw. The interpolating
/// [`percentile`] stays available for plotting-style callers.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2) as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: quantile_exact(&sorted, 0.50),
            p90: quantile_exact(&sorted, 0.90),
            p95: quantile_exact(&sorted, 0.95),
            p99: quantile_exact(&sorted, 0.99),
        }
    }
}

/// Exact nearest-rank quantile of a pre-sorted slice ("sorted-select"):
/// the smallest sample value with at least `ceil(q * n)` observations at
/// or below it. Always returns an element of the sample.
pub fn quantile_exact(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let n = sorted.len();
    let rank = (q.clamp(0.0, 1.0) * n as f64).ceil() as usize;
    sorted[rank.clamp(1, n) - 1]
}

/// Linear-interpolated percentile of a pre-sorted slice.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Combine two accumulators (Chan et al. parallel variance): the
    /// result is as if every sample of `other` had been pushed here.
    /// Lets per-thread accumulators (histograms, per-replica stats)
    /// merge without replaying samples.
    pub fn merge(&mut self, other: &Welford) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.mean += d * other.n as f64 / n as f64;
        self.n = n;
    }
}

/// Angle (radians) between two vectors — the Figure 8 error metric.
pub fn radians_between(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let dot: f64 = a.iter().zip(b).map(|(x, y)| (*x as f64) * (*y as f64)).sum();
    let na: f64 = a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    if na == 0.0 || nb == 0.0 {
        return std::f64::consts::FRAC_PI_2;
    }
    (dot / (na * nb)).clamp(-1.0, 1.0).acos()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_constant() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
    }

    #[test]
    fn summary_percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p90, 90.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.min, 0.0);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn quantile_exact_is_an_order_statistic() {
        // nearest-rank must return an element of the sample, never an
        // interpolated midpoint, and must hit the exact edge ranks
        let sorted = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile_exact(&sorted, 0.0), 1.0);
        assert_eq!(quantile_exact(&sorted, 0.25), 1.0); // ceil(1.0) = rank 1
        assert_eq!(quantile_exact(&sorted, 0.5), 2.0);
        assert_eq!(quantile_exact(&sorted, 0.51), 3.0); // ceil(2.04) = rank 3
        assert_eq!(quantile_exact(&sorted, 1.0), 4.0);
        for q in [0.1, 0.37, 0.5, 0.9, 0.95, 0.99] {
            assert!(sorted.contains(&quantile_exact(&sorted, q)));
        }
        // single element: every quantile is that element
        assert_eq!(quantile_exact(&[7.5], 0.99), 7.5);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 4.0, 9.0, 16.0, 25.0];
        let mut w = Welford::default();
        for x in xs {
            w.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        assert!((w.mean() - mean).abs() < 1e-12);
    }

    #[test]
    fn welford_merge_matches_single_stream() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut whole = Welford::default();
        for &x in &xs {
            whole.push(x);
        }
        let (mut a, mut b, mut empty) =
            (Welford::default(), Welford::default(), Welford::default());
        for (i, &x) in xs.iter().enumerate() {
            if i < 37 {
                a.push(x)
            } else {
                b.push(x)
            }
        }
        a.merge(&b);
        a.merge(&empty); // merging an empty accumulator is a no-op
        empty.merge(&a); // merging INTO an empty one adopts the other side
        for w in [&a, &empty] {
            assert_eq!(w.count(), whole.count());
            assert!((w.mean() - whole.mean()).abs() < 1e-9);
            assert!((w.variance() - whole.variance()).abs() < 1e-9);
        }
    }

    #[test]
    fn radians_orthogonal_and_parallel() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        assert!((radians_between(&a, &b) - std::f64::consts::FRAC_PI_2).abs() < 1e-6);
        assert!(radians_between(&a, &a) < 1e-6);
    }
}
