//! Deterministic xoshiro256++ PRNG.
//!
//! The offline crate registry has no `rand`; this is the standard
//! xoshiro256++ generator (Blackman & Vigna) plus the distribution
//! helpers the rest of the crate needs (uniform, normal, zipf,
//! permutation). Deterministic seeding keeps every experiment
//! reproducible from a single u64.

/// xoshiro256++ generator state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (analogous to jax.random.fold_in).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mut h = 0xcbf29ce484222325u64; // FNV offset
        for b in self.s.iter().flat_map(|w| w.to_le_bytes()) {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        for b in data.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        Rng::new(h)
    }

    /// `fold_in` over arbitrary i32 content (e.g. a request's token ids):
    /// identical data always derives the identical stream.
    pub fn fold_in_i32s(&self, data: &[i32]) -> Rng {
        let mut h = 0xcbf29ce484222325u64; // FNV offset
        for &t in data {
            h = (h ^ (t as u32 as u64)).wrapping_mul(0x100000001b3);
        }
        self.fold_in(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine for
        // our non-cryptographic use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                let r = (-2.0 * (u1 as f64).ln()).sqrt() as f32;
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.uniform_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(s) sampler over {0, .., n-1} with precomputed CDF — the unigram
/// distribution of the synthetic corpus.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform_f64();
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let xs: Vec<f32> = (0..200_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let z = Zipf::new(1000, 1.2);
        let mut r = Rng::new(13);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10] && counts[10] > counts[500]);
    }

    #[test]
    fn fold_in_streams_independent() {
        let base = Rng::new(42);
        let mut a = base.fold_in(0);
        let mut b = base.fold_in(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fold_in_i32s_content_addressed() {
        let base = Rng::new(42);
        let mut a = base.fold_in_i32s(&[1, 2, 3]);
        let mut b = base.fold_in_i32s(&[1, 2, 3]);
        let mut c = base.fold_in_i32s(&[1, 2, 4]);
        let x = a.next_u64();
        assert_eq!(x, b.next_u64());
        assert_ne!(x, c.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
