//! Fixed-size thread pool over std channels (no tokio in the offline
//! registry). Powers the data pipeline, the parallel attention engine,
//! and the serving worker pool.
//!
//! Panic safety: a panicking job is caught on the worker, the pending
//! count still drops (so `join` never deadlocks), and the panic is
//! re-raised on the caller at the next `map` — a poisoned pool fails
//! loudly instead of hanging.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Work-queue thread pool. Jobs are closures; `join` blocks until all
/// submitted jobs have completed.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
    panicked: Arc<AtomicBool>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> ThreadPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::with_capacity(n_threads);
        for _ in 0..n_threads.max(1) {
            let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            let panicked = Arc::clone(&panicked);
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        let result = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(job),
                        );
                        if result.is_err() {
                            panicked.store(true, Ordering::SeqCst);
                        }
                        let (lock, cvar) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        if *p == 0 {
                            cvar.notify_all();
                        }
                    }
                    Err(_) => break,
                }
            }));
        }
        ThreadPool { tx: Some(tx), handles, pending, panicked }
    }

    /// True once any job has panicked (sticky).
    pub fn panicked(&self) -> bool {
        self.panicked.load(Ordering::SeqCst)
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker thread died");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }

    /// Map `f` over `items` in parallel, preserving order. Panics if any
    /// job (this batch or an earlier one on this pool) panicked.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> = Arc::new(Mutex::new(
            items.iter().map(|_| None).collect(),
        ));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.join();
        if self.panicked() {
            panic!("thread pool job panicked");
        }
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("map results still shared"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.unwrap())
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn join_idempotent() {
        let pool = ThreadPool::new(2);
        pool.join();
        pool.execute(|| {});
        pool.join();
        pool.join();
    }

    #[test]
    fn panicking_job_does_not_deadlock_join() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        for _ in 0..10 {
            pool.execute(|| {});
        }
        pool.join(); // must return, not hang
        assert!(pool.panicked());
    }

    #[test]
    #[should_panic(expected = "thread pool job panicked")]
    fn map_propagates_job_panic() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1usize, 2, 3], |x| {
            if x == 2 {
                panic!("bad item");
            }
            x
        });
    }
}
