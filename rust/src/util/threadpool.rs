//! Work-stealing thread pool (no external deps; the offline registry
//! has no crossbeam/rayon). Powers the data pipeline, the parallel
//! attention engine, and the serving worker pool.
//!
//! # Scheduler
//!
//! `ThreadPool` replaces the original channel-per-job design with a
//! work-stealing deque scheduler:
//!
//! * each worker owns a local deque; batch submissions (`run_batch`,
//!   `scope`, `map`) pre-distribute jobs round-robin across the local
//!   deques in one placement pass — one pending-count update and one
//!   wake-up for the whole batch instead of a channel send per task;
//! * single `execute` calls land on a shared injector queue;
//! * an idle worker pops its own deque front first, then the injector,
//!   then steals from the *back* of a victim deque starting at a
//!   pseudo-random position (xorshift per worker), so imbalanced batches
//!   rebalance without a central lock on the hot path.
//!
//! The original channel scheduler survives as [`ChannelPool`] behind the
//! same `execute`/`map`/`join`/`panicked` API: it is the baseline the
//! fig7 bench measures the stealing scheduler against, and a fallback
//! reference for debugging scheduler issues.
//!
//! # Determinism contract
//!
//! The pool never influences *what* is computed, only *when*: `map` and
//! `run_batch` assign results positionally, so callers that derive each
//! task's randomness from its index (`attention::engine`) get identical
//! bytes at every thread count and under either scheduler.
//!
//! # Panic safety
//!
//! A panicking job is caught on the worker, every pending/batch count
//! still drops (so `join` and `run_batch` never deadlock), and the panic
//! is re-raised on the caller at the next `map` — a poisoned pool fails
//! loudly instead of hanging.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of pool work.
pub type Job = Box<dyn FnOnce() + Send + 'static>;

struct IdleState {
    shutdown: bool,
}

/// State shared between the handle and the workers.
struct Shared {
    /// Per-worker local deques; batch submission round-robins here.
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// Global queue for single `execute` submissions.
    injector: Mutex<VecDeque<Job>>,
    /// Jobs queued but not yet popped — the workers' sleep fast-path.
    queued: AtomicUsize,
    /// Round-robin placement cursor for batch submission.
    cursor: AtomicUsize,
    /// Jobs taken off another worker's deque (scheduler telemetry).
    steals: AtomicUsize,
    /// Sleep/shutdown coordination; workers wait on `work_cv`.
    idle: Mutex<IdleState>,
    work_cv: Condvar,
    /// Jobs submitted and not yet finished; `join` waits on `done_cv`.
    pending: Mutex<usize>,
    done_cv: Condvar,
    panicked: AtomicBool,
}

impl Shared {
    /// Pop work for worker `me`: own deque front, then injector, then
    /// steal from a random victim's back.
    fn find_job(&self, me: usize, steal_seed: &mut u64) -> Option<Job> {
        if let Some(job) = self.queues[me].lock().unwrap().pop_front() {
            self.queued.fetch_sub(1, Ordering::SeqCst);
            return Some(job);
        }
        if let Some(job) = self.injector.lock().unwrap().pop_front() {
            self.queued.fetch_sub(1, Ordering::SeqCst);
            return Some(job);
        }
        let n = self.queues.len();
        // xorshift64* — cheap per-worker randomized victim order
        *steal_seed ^= *steal_seed << 13;
        *steal_seed ^= *steal_seed >> 7;
        *steal_seed ^= *steal_seed << 17;
        let start = (*steal_seed as usize) % n;
        for off in 0..n {
            let victim = (start + off) % n;
            if victim == me {
                continue;
            }
            if let Some(job) = self.queues[victim].lock().unwrap().pop_back() {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                self.steals.fetch_add(1, Ordering::Relaxed);
                return Some(job);
            }
        }
        None
    }

    /// Run one job with panic containment and pending-count bookkeeping.
    fn run_job(&self, job: Job) {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        if result.is_err() {
            self.panicked.store(true, Ordering::SeqCst);
        }
        let mut p = self.pending.lock().unwrap();
        *p -= 1;
        if *p == 0 {
            self.done_cv.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, me: usize) {
    // fixed per-worker seed: victim order is pseudo-random but does not
    // depend on wall clock, so runs are reproducible under rr/debuggers
    let mut steal_seed =
        0x9E37_79B9_7F4A_7C15u64 ^ (me as u64 + 1).wrapping_mul(0xA24B_AED4_963E_E407);
    loop {
        if let Some(job) = shared.find_job(me, &mut steal_seed) {
            shared.run_job(job);
            continue;
        }
        let guard = shared.idle.lock().unwrap();
        // re-check under the lock: a submitter bumps `queued` before it
        // notifies under this same lock, so either we see the count or
        // we are parked before the notify — no lost wake-ups
        if shared.queued.load(Ordering::SeqCst) > 0 {
            continue;
        }
        if guard.shutdown {
            break;
        }
        let _guard = shared.work_cv.wait(guard).unwrap();
    }
}

/// Work-stealing thread pool. Jobs are closures; `join` blocks until all
/// submitted jobs have completed; `run_batch`/`scope`/`map` submit in
/// bulk and wait for exactly their own batch.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> ThreadPool {
        let n = n_threads.max(1);
        let shared = Arc::new(Shared {
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            injector: Mutex::new(VecDeque::new()),
            queued: AtomicUsize::new(0),
            cursor: AtomicUsize::new(0),
            steals: AtomicUsize::new(0),
            idle: Mutex::new(IdleState { shutdown: false }),
            work_cv: Condvar::new(),
            pending: Mutex::new(0),
            done_cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let handles = (0..n)
            .map(|me| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(shared, me))
            })
            .collect();
        ThreadPool { shared, handles }
    }

    pub fn threads(&self) -> usize {
        self.shared.queues.len()
    }

    /// True once any job has panicked (sticky).
    pub fn panicked(&self) -> bool {
        self.shared.panicked.load(Ordering::SeqCst)
    }

    /// Cumulative count of jobs executed off their placement deque —
    /// scheduler telemetry (and the structural stealing assertion in
    /// tests, which beats flaky wall-clock bounds).
    pub fn steals(&self) -> usize {
        self.shared.steals.load(Ordering::Relaxed)
    }

    /// Submit a single job (injector queue; one wake-up).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        // pending/queued go up BEFORE the push: a worker may pop and
        // finish the job before we return, and both counters are
        // decremented on that path
        {
            let mut p = self.shared.pending.lock().unwrap();
            *p += 1;
        }
        self.shared.queued.fetch_add(1, Ordering::SeqCst);
        self.shared.injector.lock().unwrap().push_back(Box::new(f));
        let _guard = self.shared.idle.lock().unwrap();
        self.shared.work_cv.notify_one();
    }

    /// Bulk-submit: place `jobs` round-robin across the worker deques in
    /// one pass (single pending update, single wake-up) and block until
    /// exactly this batch has finished. Panicking jobs still complete the
    /// batch (see module docs); check `panicked` afterwards.
    ///
    /// Deadlock rule: like `map`/`join`, never call from a job running on
    /// this same pool.
    pub fn run_batch(&self, jobs: Vec<Job>) {
        let n_jobs = jobs.len();
        if n_jobs == 0 {
            return;
        }
        let batch = Arc::new((Mutex::new(n_jobs), Condvar::new()));
        let wrapped: Vec<Job> = jobs
            .into_iter()
            .map(|job| {
                let batch = Arc::clone(&batch);
                let shared = Arc::clone(&self.shared);
                let wrapper = move || {
                    // contain the user panic so the batch count always
                    // drops; the sticky flag must be set BEFORE the
                    // caller is woken — a `map` checking `panicked()`
                    // right after its batch completes must observe it
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                    if r.is_err() {
                        shared.panicked.store(true, Ordering::SeqCst);
                    }
                    let (left, cv) = &*batch;
                    let mut l = left.lock().unwrap();
                    *l -= 1;
                    if *l == 0 {
                        cv.notify_all();
                    }
                    drop(l);
                    if let Err(payload) = r {
                        // re-raise so the worker's bookkeeping sees it too
                        std::panic::resume_unwind(payload);
                    }
                };
                Box::new(wrapper) as Job
            })
            .collect();
        self.inject_batch(wrapped);
        let (left, cv) = &*batch;
        let mut l = left.lock().unwrap();
        while *l > 0 {
            l = cv.wait(l).unwrap();
        }
    }

    /// Collect jobs through a [`Scope`], then `run_batch` them — the
    /// bulk-submit ergonomics for callers that build jobs imperatively.
    pub fn scope<F: FnOnce(&mut Scope)>(&self, f: F) {
        let mut scope = Scope { jobs: Vec::new() };
        f(&mut scope);
        self.run_batch(scope.jobs);
    }

    /// One placement pass for a pre-wrapped batch.
    fn inject_batch(&self, jobs: Vec<Job>) {
        let n_jobs = jobs.len();
        let n_queues = self.shared.queues.len();
        {
            let mut p = self.shared.pending.lock().unwrap();
            *p += n_jobs;
        }
        self.shared.queued.fetch_add(n_jobs, Ordering::SeqCst);
        // rotate the starting queue so back-to-back small batches do not
        // all pile onto worker 0
        let start = self.shared.cursor.fetch_add(n_jobs, Ordering::Relaxed);
        let mut per_queue: Vec<Vec<Job>> = (0..n_queues).map(|_| Vec::new()).collect();
        for (i, job) in jobs.into_iter().enumerate() {
            per_queue[(start + i) % n_queues].push(job);
        }
        for (qi, group) in per_queue.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            self.shared.queues[qi].lock().unwrap().extend(group);
        }
        let _guard = self.shared.idle.lock().unwrap();
        self.shared.work_cv.notify_all();
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let mut p = self.shared.pending.lock().unwrap();
        while *p > 0 {
            p = self.shared.done_cv.wait(p).unwrap();
        }
    }

    /// Map `f` over `items` in parallel, preserving order, via the
    /// bulk-submit path. Panics if any job (this batch or an earlier one
    /// on this pool) panicked.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new(items.iter().map(|_| None).collect()));
        let jobs: Vec<Job> = items
            .into_iter()
            .enumerate()
            .map(|(i, item)| {
                let f = Arc::clone(&f);
                let results = Arc::clone(&results);
                let job = move || {
                    let r = f(item);
                    results.lock().unwrap()[i] = Some(r);
                };
                Box::new(job) as Job
            })
            .collect();
        self.run_batch(jobs);
        if self.panicked() {
            panic!("thread pool job panicked");
        }
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("map results still shared"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.unwrap())
            .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut idle = self.shared.idle.lock().unwrap();
            idle.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Job collector handed to [`ThreadPool::scope`] closures.
pub struct Scope {
    jobs: Vec<Job>,
}

impl Scope {
    /// Queue a job for the batch; it runs when the scope closure returns.
    pub fn spawn<F: FnOnce() + Send + 'static>(&mut self, f: F) {
        self.jobs.push(Box::new(f));
    }

    /// Number of jobs queued so far.
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

/// The original channel-per-job scheduler: one `mpsc` send per task and
/// a single receiver behind a mutex. Kept (not as the default) so the
/// fig7 bench can measure the work-stealing scheduler against it, and as
/// a structurally-simple reference when debugging scheduler issues.
pub struct ChannelPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    panicked: Arc<AtomicBool>,
}

impl ChannelPool {
    pub fn new(n_threads: usize) -> ChannelPool {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), Condvar::new()));
        let panicked = Arc::new(AtomicBool::new(false));
        let mut handles = Vec::with_capacity(n_threads);
        for _ in 0..n_threads.max(1) {
            let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            let panicked = Arc::clone(&panicked);
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        let result =
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                        if result.is_err() {
                            panicked.store(true, Ordering::SeqCst);
                        }
                        let (lock, cvar) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        if *p == 0 {
                            cvar.notify_all();
                        }
                    }
                    Err(_) => break,
                }
            }));
        }
        ChannelPool { tx: Some(tx), handles, pending, panicked }
    }

    /// True once any job has panicked (sticky).
    pub fn panicked(&self) -> bool {
        self.panicked.load(Ordering::SeqCst)
    }

    /// Submit a job (one channel send — the measured overhead).
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker thread died");
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }

    /// Map `f` over `items` in parallel, preserving order — the legacy
    /// channel-send-per-item path. Panics if any job panicked.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new(items.iter().map(|_| None).collect()));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.execute(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.join();
        if self.panicked() {
            panic!("thread pool job panicked");
        }
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("map results still shared"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.unwrap())
            .collect()
    }
}

impl Drop for ChannelPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::test_threads;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(test_threads(4));
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(test_threads(3));
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn join_idempotent() {
        let pool = ThreadPool::new(test_threads(2));
        pool.join();
        pool.execute(|| {});
        pool.join();
        pool.join();
    }

    #[test]
    fn panicking_job_does_not_deadlock_join() {
        let pool = ThreadPool::new(test_threads(2));
        pool.execute(|| panic!("boom"));
        for _ in 0..10 {
            pool.execute(|| {});
        }
        pool.join(); // must return, not hang
        assert!(pool.panicked());
    }

    #[test]
    #[should_panic(expected = "thread pool job panicked")]
    fn map_propagates_job_panic() {
        let pool = ThreadPool::new(test_threads(2));
        let _ = pool.map(vec![1usize, 2, 3], |x| {
            if x == 2 {
                panic!("bad item");
            }
            x
        });
    }

    #[test]
    fn map_panic_poisons_pool_without_deadlocking_join() {
        // the satellite regression: a panicking job on the *bulk-submit*
        // path must poison `panicked()` while `join` still returns
        let pool = ThreadPool::new(test_threads(3));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map((0..64).collect::<Vec<usize>>(), |x| {
                if x == 13 {
                    panic!("boom at 13");
                }
                x
            })
        }));
        assert!(r.is_err(), "map must re-raise the job panic");
        assert!(pool.panicked());
        pool.join(); // poisoned pool must still not hang
        pool.execute(|| {});
        pool.join(); // and must still run later work
    }

    #[test]
    fn run_batch_waits_for_exactly_its_batch() {
        let pool = ThreadPool::new(test_threads(4));
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs: Vec<Job> = (0..200)
            .map(|_| {
                let c = Arc::clone(&counter);
                Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Job
            })
            .collect();
        pool.run_batch(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 200);
        pool.run_batch(Vec::new()); // empty batch is a no-op
    }

    #[test]
    fn scope_collects_and_runs() {
        let pool = ThreadPool::new(test_threads(3));
        let counter = Arc::new(AtomicUsize::new(0));
        pool.scope(|s| {
            assert!(s.is_empty());
            for i in 0..32 {
                let c = Arc::clone(&counter);
                s.spawn(move || {
                    c.fetch_add(i, Ordering::SeqCst);
                });
            }
            assert_eq!(s.len(), 32);
        });
        assert_eq!(counter.load(Ordering::SeqCst), (0..32).sum::<usize>());
    }

    #[test]
    fn unbalanced_batch_is_stolen() {
        // batch placement strides round-robin over the local deques, so
        // with a 4-wide pool, jobs i and i+4 land on the SAME deque: the
        // 8 sleep jobs below (i % 4 == 0, i < 32) all queue behind one
        // worker. Without stealing that worker runs them serially
        // (8 x 30 ms = 240 ms); with stealing the other three workers
        // drain that deque's back and the batch finishes in ~2 rounds.
        // Width is pinned at 4 (not test_threads) — this asserts the
        // stealing property itself, which needs idle peers.
        let pool = ThreadPool::new(4);
        let items: Vec<usize> = (0..32).collect();
        let out = pool.map(items, |i| {
            if i % 4 == 0 {
                std::thread::sleep(std::time::Duration::from_millis(30));
            }
            i * 2
        });
        assert_eq!(out, (0..32).map(|i| i * 2).collect::<Vec<_>>());
        // structural assertion (no flaky wall-clock bound): the three
        // workers that drained their instant jobs must have pulled
        // sleepers off the hot deque
        assert!(
            pool.steals() > 0,
            "no stealing happened — sleepers ran serially on one worker"
        );
    }

    #[test]
    fn concurrent_submitters() {
        let pool = Arc::new(ThreadPool::new(test_threads(4)));
        let counter = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..4 {
            let pool = Arc::clone(&pool);
            let counter = Arc::clone(&counter);
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let c = Arc::clone(&counter);
                    pool.execute(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 200);
    }

    #[test]
    fn channel_pool_still_works() {
        // the legacy scheduler stays correct — it is the bench baseline
        let pool = ChannelPool::new(test_threads(3));
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x + 1);
        assert_eq!(out, (1..51).collect::<Vec<_>>());
        pool.execute(|| panic!("boom"));
        pool.join();
        assert!(pool.panicked());
    }
}
