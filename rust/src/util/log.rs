//! Minimal leveled logger (stderr) controlled by `YOSO_LOG`.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);

/// Initialize the log level from the `YOSO_LOG` env var (error|warn|info|debug).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("YOSO_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, args: std::fmt::Arguments) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($t)*)) }
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($t)*)) }
}

#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($t)*)) }
}

#[macro_export]
macro_rules! error {
    ($($t:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, format_args!($($t)*)) }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
