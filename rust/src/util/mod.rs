//! Infrastructure substrates: RNG, statistics, timing, thread pool, logging.

pub mod log;
pub mod rng;
pub mod stats;
pub mod threadpool;
pub mod timer;

pub use rng::Rng;
pub use stats::Summary;
pub use threadpool::{ChannelPool, ThreadPool};
pub use timer::Timer;
