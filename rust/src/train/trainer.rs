//! The training loop: Rust owns the loop, data, metrics, checkpoints;
//! XLA owns the math (one fused HLO train step per variant).

use super::checkpoint;
use super::source::{BatchSource, EVAL_INDEX_BASE};
use crate::metrics::Recorder;
use crate::model::ParamSet;
use crate::runtime::literal::{f32_literal, i32_literal, to_f32_vec};
use crate::runtime::{Artifact, Runtime};
use crate::util::Timer;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;
use xla::Literal;

/// Example-index stride between training batches: must exceed any batch
/// size so step s and step s+1 draw disjoint examples.
pub const BATCH_INDEX_STRIDE: u64 = 4096;

/// Metrics vector layout (see model.py pretrain_losses / cls_losses).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepMetrics {
    pub loss: f64,
    pub mlm_loss: f64,
    pub sop_loss: f64,
    pub correct: f64,
    pub denom: f64,
    pub sop_correct: f64,
    pub batch: f64,
}

impl StepMetrics {
    pub fn from_vec(v: &[f32]) -> StepMetrics {
        StepMetrics {
            loss: v[0] as f64,
            mlm_loss: v[1] as f64,
            sop_loss: v[2] as f64,
            correct: v[3] as f64,
            denom: v[4] as f64,
            sop_correct: v[5] as f64,
            batch: v[6] as f64,
        }
    }

    pub fn mlm_accuracy(&self) -> f64 {
        self.correct / self.denom.max(1.0)
    }

    pub fn sop_accuracy(&self) -> f64 {
        self.sop_correct / self.batch.max(1.0)
    }

    /// exp(mlm_loss): the Table-2 perplexity metric.
    pub fn mlm_perplexity(&self) -> f64 {
        self.mlm_loss.exp()
    }
}

#[derive(Clone, Debug, Default)]
pub struct EvalResult {
    pub loss: f64,
    pub mlm_perplexity: f64,
    pub accuracy: f64,
    pub sop_accuracy: f64,
}

/// Evaluate an arbitrary eval artifact with explicit parameter literals —
/// used when sweeping inference-time settings (e.g. Figure 5's hash
/// counts) over one trained parameter set.
pub fn eval_artifact(
    art: &Artifact,
    params: &[Literal],
    source: &dyn BatchSource,
    n_batches: usize,
) -> Result<EvalResult> {
    let spec = &art.spec;
    ensure!(spec.n_params() == params.len(), "param count mismatch");
    let mut loss_sum = 0.0;
    let mut agg = StepMetrics::default();
    for b in 0..n_batches {
        let batch = source.batch_literals(EVAL_INDEX_BASE + (b as u64) * 1024, spec)?;
        let mut inputs: Vec<Literal> = params.to_vec();
        inputs.extend(batch);
        inputs.push(i32_literal(&[b as i32], &[])?);
        let outputs = art.execute(&inputs)?;
        let m = StepMetrics::from_vec(&to_f32_vec(&outputs[0])?);
        loss_sum += m.loss;
        agg.mlm_loss += m.mlm_loss;
        agg.correct += m.correct;
        agg.denom += m.denom;
        agg.sop_correct += m.sop_correct;
        agg.batch += m.batch;
    }
    let nb = n_batches.max(1) as f64;
    Ok(EvalResult {
        loss: loss_sum / nb,
        mlm_perplexity: (agg.mlm_loss / nb).exp(),
        accuracy: agg.correct / agg.denom.max(1.0),
        sop_accuracy: agg.sop_correct / agg.batch.max(1.0),
    })
}

pub struct Trainer {
    train_art: Arc<Artifact>,
    eval_art: Option<Arc<Artifact>>,
    /// current parameters (host-side, ABI order)
    pub params: Vec<Literal>,
    adam_m: Vec<Literal>,
    adam_v: Vec<Literal>,
    pub step: usize,
    n_params: usize,
    pub param_template: ParamSet,
}

impl Trainer {
    /// Create a trainer for the named train-step artifact, initializing
    /// parameters in Rust (or from `init` when resuming/fine-tuning).
    pub fn new(
        runtime: &Runtime,
        train_artifact: &str,
        eval_artifact: Option<&str>,
        seed: u64,
        init: Option<ParamSet>,
    ) -> Result<Trainer> {
        let train_art = runtime.artifact(train_artifact)?;
        let eval_art = match eval_artifact {
            Some(name) => Some(runtime.artifact(name)?),
            None => None,
        };
        let spec = &train_art.spec;
        let n_params = spec.n_params();
        ensure!(n_params > 0, "{train_artifact} has no param inputs");

        let mut template = ParamSet::init_for(spec, seed);
        if let Some(init) = init {
            // fine-tuning: copy matching tensors (head params may differ)
            let by_name: std::collections::BTreeMap<_, _> = init
                .names
                .iter()
                .zip(init.values.iter())
                .map(|(n, v)| (n.clone(), v))
                .collect();
            let mut copied = 0;
            for i in 0..template.len() {
                if let Some(v) = by_name.get(&template.names[i]) {
                    if v.len() == template.values[i].len() {
                        template.values[i] = (*v).clone();
                        copied += 1;
                    }
                }
            }
            crate::info!("fine-tune init: {copied}/{} tensors from checkpoint",
                         template.len());
        }

        let params = Self::to_literals(&template)?;
        let zeros = template.zeros_like();
        let adam_m = Self::to_literals(&zeros)?;
        let adam_v = Self::to_literals(&zeros)?;
        Ok(Trainer {
            train_art,
            eval_art,
            params,
            adam_m,
            adam_v,
            step: 0,
            n_params,
            param_template: template,
        })
    }

    fn to_literals(set: &ParamSet) -> Result<Vec<Literal>> {
        set.values
            .iter()
            .zip(&set.shapes)
            .map(|(v, s)| f32_literal(v, s))
            .collect()
    }

    /// Current parameters as a host ParamSet (for checkpointing).
    pub fn snapshot(&self) -> Result<ParamSet> {
        let mut set = self.param_template.clone();
        for (i, lit) in self.params.iter().enumerate() {
            set.values[i] = to_f32_vec(lit)?;
        }
        Ok(set)
    }

    pub fn save_checkpoint(&self, path: &Path) -> Result<()> {
        checkpoint::save(&self.snapshot()?, path)
    }

    /// One optimizer step on the batch at `index`; returns its metrics.
    pub fn train_step(
        &mut self,
        source: &dyn BatchSource,
        index: u64,
        lr: f64,
    ) -> Result<StepMetrics> {
        let spec = &self.train_art.spec;
        let batch = source.batch_literals(index, spec)?;
        let mut inputs: Vec<Literal> = Vec::with_capacity(spec.inputs.len());
        // ABI: params, adam_m, adam_v, batch..., step, seed, lr
        inputs.extend(self.params.drain(..));
        inputs.extend(self.adam_m.drain(..));
        inputs.extend(self.adam_v.drain(..));
        inputs.extend(batch);
        inputs.push(i32_literal(&[self.step as i32], &[])?);
        inputs.push(i32_literal(&[(index & 0x7FFF_FFFF) as i32], &[])?);
        inputs.push(f32_literal(&[lr as f32], &[])?);

        let mut outputs = self.train_art.execute(&inputs)?;
        ensure!(outputs.len() == 3 * self.n_params + 1, "train step ABI");
        let metrics_lit = outputs.pop().unwrap();
        self.adam_v = outputs.split_off(2 * self.n_params);
        self.adam_m = outputs.split_off(self.n_params);
        self.params = outputs;
        self.step += 1;
        let m = to_f32_vec(&metrics_lit)?;
        Ok(StepMetrics::from_vec(&m))
    }

    /// Evaluate over `n_batches` held-out batches.
    pub fn evaluate(&self, source: &dyn BatchSource, n_batches: usize) -> Result<EvalResult> {
        let art = self
            .eval_art
            .as_ref()
            .context("no eval artifact configured")?;
        let spec = &art.spec;
        let mut agg = StepMetrics::default();
        let mut loss_sum = 0.0;
        for b in 0..n_batches {
            let batch = source.batch_literals(
                EVAL_INDEX_BASE + (b as u64) * 1024,
                spec,
            )?;
            let mut inputs: Vec<Literal> = Vec::with_capacity(spec.inputs.len());
            for lit in &self.params {
                inputs.push(lit.clone());
            }
            inputs.extend(batch);
            inputs.push(i32_literal(&[b as i32], &[])?);
            let outputs = art.execute(&inputs)?;
            let m = StepMetrics::from_vec(&to_f32_vec(&outputs[0])?);
            loss_sum += m.loss;
            agg.mlm_loss += m.mlm_loss;
            agg.correct += m.correct;
            agg.denom += m.denom;
            agg.sop_correct += m.sop_correct;
            agg.batch += m.batch;
        }
        let nb = n_batches.max(1) as f64;
        Ok(EvalResult {
            loss: loss_sum / nb,
            mlm_perplexity: (agg.mlm_loss / nb).exp(),
            accuracy: agg.correct / agg.denom.max(1.0),
            sop_accuracy: agg.sop_correct / agg.batch.max(1.0),
        })
    }

    /// Full training run with logging + periodic eval into a Recorder.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        source: &dyn BatchSource,
        steps: usize,
        lr: f64,
        eval_every: usize,
        eval_batches: usize,
        log_every: usize,
        rec: &mut Recorder,
    ) -> Result<()> {
        let timer = Timer::start();
        for s in 0..steps {
            // stride the example-index space so consecutive batches are
            // disjoint (sources hand out examples [index, index + batch))
            let m = self.train_step(source, (s as u64) * BATCH_INDEX_STRIDE, lr)?;
            rec.push("train_loss", self.step as f64, m.loss);
            rec.push("train_mlm_ppl", self.step as f64, m.mlm_perplexity());
            if log_every > 0 && s % log_every == 0 {
                crate::info!(
                    "step {:>5}  loss {:.4}  mlm_ppl {:.2}  acc {:.3}  ({:.2} s/step)",
                    self.step,
                    m.loss,
                    m.mlm_perplexity(),
                    m.mlm_accuracy(),
                    timer.elapsed_secs() / (s + 1) as f64,
                );
            }
            if eval_every > 0 && (s + 1) % eval_every == 0 && self.eval_art.is_some() {
                let e = self.evaluate(source, eval_batches)?;
                rec.push("eval_loss", self.step as f64, e.loss);
                rec.push("eval_mlm_ppl", self.step as f64, e.mlm_perplexity);
                rec.push("eval_acc", self.step as f64, e.accuracy);
                rec.push("eval_sop_acc", self.step as f64, e.sop_accuracy);
                crate::info!(
                    "  eval @ {:>5}: loss {:.4} ppl {:.2} acc {:.3} sop {:.3}",
                    self.step, e.loss, e.mlm_perplexity, e.accuracy, e.sop_accuracy
                );
            }
        }
        Ok(())
    }
}
