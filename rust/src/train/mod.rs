//! Training orchestrator: drives the fused train-step artifacts (fwd +
//! bwd + AdamW in one HLO module) from Rust, with data generation,
//! metrics, periodic evaluation, and checkpointing.

pub mod checkpoint;
pub mod source;
pub mod trainer;

pub use source::{BatchSource, ClsSource, PretrainSource};
pub use trainer::{EvalResult, Trainer};
