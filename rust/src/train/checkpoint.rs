//! Checkpoint format: a simple self-describing binary container for named
//! f32 tensors (the offline registry has no serde/npy writer).
//!
//! Layout (little-endian):
//!   magic "YOSOCKPT" | u32 version | u32 tensor count
//!   per tensor: u32 name_len | name bytes | u32 ndim | u64 dims...
//!               | f32 data...

use crate::model::ParamSet;
use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"YOSOCKPT";
const VERSION: u32 = 1;

pub fn save(params: &ParamSet, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for i in 0..params.len() {
        let name = params.names[i].as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(params.shapes[i].len() as u32).to_le_bytes())?;
        for &d in &params.shapes[i] {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        for &x in &params.values[i] {
            f.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: &Path) -> Result<ParamSet> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC, "not a yoso checkpoint");
    let version = read_u32(&mut f)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let count = read_u32(&mut f)? as usize;
    let mut set = ParamSet::default();
    for _ in 0..count {
        let name_len = read_u32(&mut f)? as usize;
        ensure!(name_len < 4096, "absurd name length");
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let ndim = read_u32(&mut f)? as usize;
        ensure!(ndim <= 8, "absurd rank");
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            f.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        let count: usize = shape.iter().product();
        ensure!(count < (1 << 30), "absurd tensor size");
        let mut data = vec![0f32; count];
        let mut buf = vec![0u8; count * 4];
        f.read_exact(&mut buf)?;
        for (x, c) in data.iter_mut().zip(buf.chunks_exact(4)) {
            *x = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
        set.names.push(String::from_utf8(name)?);
        set.shapes.push(shape);
        set.values.push(data);
    }
    Ok(set)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let params = ParamSet {
            names: vec!["a".into(), "layer0.wq".into()],
            shapes: vec![vec![2, 3], vec![4]],
            values: vec![vec![1.0, -2.5, 3.0, 0.0, 7.5, -1.0], vec![0.5; 4]],
        };
        let path = std::env::temp_dir().join(format!("ckpt_{}.bin", std::process::id()));
        save(&params, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.names, params.names);
        assert_eq!(loaded.shapes, params.shapes);
        assert_eq!(loaded.values, params.values);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join(format!("bad_{}.bin", std::process::id()));
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        let _ = std::fs::remove_file(path);
    }
}
