//! Batch sources: map (index, artifact ABI) -> input literals for the
//! `batch:*` slots. Deterministic by index, so runs are reproducible and
//! train/eval splits are disjoint index ranges.

use crate::data::glue_synth::GlueGenerator;
use crate::data::lra::LraGenerator;
use crate::data::mlm::PretrainStream;
use crate::runtime::literal::i32_literal;
use crate::runtime::manifest::ArtifactSpec;
use anyhow::{bail, Result};
use xla::Literal;

/// Index base for evaluation batches — far from any training index.
pub const EVAL_INDEX_BASE: u64 = 1 << 40;

pub trait BatchSource: Send {
    /// Literals for the artifact's `batch:*` slots, in ABI order.
    fn batch_literals(&self, start_index: u64, spec: &ArtifactSpec)
        -> Result<Vec<Literal>>;
}

/// MLM + SOP pretraining batches.
pub struct PretrainSource {
    pub stream: PretrainStream,
}

impl BatchSource for PretrainSource {
    fn batch_literals(&self, start: u64, spec: &ArtifactSpec) -> Result<Vec<Literal>> {
        let slots = spec.inputs_with_prefix("batch:");
        let b = slots
            .first()
            .map(|s| s.shape[0])
            .unwrap_or(0);
        let batch = self.stream.batch(start, b);
        let mut out = Vec::with_capacity(slots.len());
        for slot in slots {
            let lit = match slot.name.as_str() {
                "batch:input_ids" => i32_literal(&batch.input_ids, &slot.shape)?,
                "batch:segment_ids" => i32_literal(&batch.segment_ids, &slot.shape)?,
                "batch:mlm_labels" => i32_literal(&batch.mlm_labels, &slot.shape)?,
                "batch:sop_labels" => i32_literal(&batch.sop_labels, &slot.shape)?,
                other => bail!("unknown pretrain batch slot {other}"),
            };
            out.push(lit);
        }
        Ok(out)
    }
}

/// Classification batches from any deterministic example generator.
pub enum ClsSource {
    Glue(GlueGenerator),
    Lra(LraGenerator),
}

impl ClsSource {
    fn batch(&self, start: u64, b: usize) -> crate::data::ClsBatch {
        match self {
            ClsSource::Glue(g) => g.batch(start, b),
            ClsSource::Lra(g) => g.batch(start, b),
        }
    }
}

impl BatchSource for ClsSource {
    fn batch_literals(&self, start: u64, spec: &ArtifactSpec) -> Result<Vec<Literal>> {
        let slots = spec.inputs_with_prefix("batch:");
        let b = slots.first().map(|s| s.shape[0]).unwrap_or(0);
        let batch = self.batch(start, b);
        let mut out = Vec::with_capacity(slots.len());
        for slot in slots {
            let lit = match slot.name.as_str() {
                "batch:input_ids" => i32_literal(&batch.input_ids, &slot.shape)?,
                "batch:segment_ids" => i32_literal(&batch.segment_ids, &slot.shape)?,
                "batch:labels" => i32_literal(&batch.labels, &slot.shape)?,
                other => bail!("unknown cls batch slot {other}"),
            };
            out.push(lit);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{CorpusConfig, CorpusGenerator};
    use crate::data::mlm::MlmConfig;
    use crate::data::tokenizer::WordTokenizer;
    use crate::runtime::manifest::{Dtype, IoSpec};

    fn pretrain_spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "t".into(),
            file: "/dev/null".into(),
            kind: "train_step".into(),
            family: "pretrain".into(),
            attention: "softmax".into(),
            inputs: vec![
                IoSpec { name: "batch:input_ids".into(), shape: vec![4, 128], dtype: Dtype::I32 },
                IoSpec { name: "batch:segment_ids".into(), shape: vec![4, 128], dtype: Dtype::I32 },
                IoSpec { name: "batch:mlm_labels".into(), shape: vec![4, 128], dtype: Dtype::I32 },
                IoSpec { name: "batch:sop_labels".into(), shape: vec![4], dtype: Dtype::I32 },
            ],
            outputs: vec![],
            config: Default::default(),
        }
    }

    #[test]
    fn pretrain_source_fills_all_slots() {
        let src = PretrainSource {
            stream: PretrainStream::new(
                CorpusGenerator::new(CorpusConfig::default()),
                WordTokenizer { n_words: 2000 },
                MlmConfig::default(),
                3,
            ),
        };
        let lits = src.batch_literals(0, &pretrain_spec()).unwrap();
        assert_eq!(lits.len(), 4);
        assert_eq!(lits[0].element_count(), 4 * 128);
    }
}
