//! Incremental YOSO encoding: the additive-sketch property, made an API.
//!
//! The per-hash bucket table is a *sum* of value rows keyed by key hash
//! (`H[f(K_j)] += V_j`, paper §3 / Alg. 1), so appending a token is an
//! O(m·dv) accumulator update — not a re-encode. [`YosoStream`] owns the
//! per-hash tables for one (head, session) and exposes exactly that:
//! `append` folds new key/value rows into the tables, `finish_into`
//! re-gathers any query block against the current state.
//!
//! **Bit-identity contract** (property-tested in
//! `tests/prop_yoso_stream.rs`): a stream fed the same keys/values in
//! any chunking produces byte-identical output to one batch forward at
//! the same total width. Three invariants make this hold:
//!
//! * the hasher is drawn whole, up front, from the construction RNG —
//!   the exact draw order of both batch kernels;
//! * within each (hash, bucket), value rows are accumulated in
//!   ascending global-`j` order: sequential appends each add their
//!   chunk's rows in ascending local order, and chunk order is session
//!   order — the same floating-point summation order as the fused
//!   kernel's stable `scatter_sorted` and the seed kernel's `j` loop;
//! * row normalization, hashing, and the gather's `+= table / m` are
//!   all row-independent, so per-chunk processing never changes bytes.
//!
//! Because float addition is not invertible, there is no `remove`:
//! a query against a *shorter-than-appended* effective width (e.g. the
//! PAD tail of a bucketed batch) goes through
//! [`YosoStream::finish_with_tail_into`], which overlays the tail rows
//! on a scratch copy of the tables — the live session state is never
//! contaminated. All scratch is grow-only (the `KernelArena` idiom), so
//! steady-state appends and gathers allocate zero heap
//! (`tests/alloc_stream.rs`).
//!
//! # The m'-prefix readout contract (degraded quality)
//!
//! Both gather entry points take an `m_read` argument: a session
//! absorbed at `m` hash rounds can be *read* at any `m' ≤ m` by
//! summing only the first `m'` tables with weight `1/m'`. This is not
//! an approximation of an approximation — it is **bit-identical to a
//! fresh m'-round forward** with the same construction RNG, because
//! both hashers draw their randomness hash-major
//! ([`HyperplaneHasher::new`] draws plane rows `[h·tau, (h+1)·tau)` in
//! hash order; [`HadamardHasher`] draws its sign diagonals
//! `(m, rounds, d)`-flattened), so an m'-round hasher from the same
//! RNG state *is* the first m' rounds of an m-round hasher, scatter
//! into table `h` depends only on hash `h`, and the gather visits
//! `h = 0..m'` in the batch kernels' order. Property-tested across
//! shapes × tau × hashers × kernels in `tests/prop_yoso_stream.rs`
//! (`m_prefix_readout_matches_fresh_m_forward`). This is what lets the
//! serving degradation ladder (`serve::gateway`) trade hash rounds for
//! latency per *readout*, with zero session mutation and no rebuild:
//! degraded service costs O(m'·dv) per query row.

use super::kernel::{
    add_rows_8, axpy_rows_8, copy_unit_rows, grow_f32, grow_u32, prep_hada,
    prep_hyper,
};
use super::yoso::YosoAttention;
use crate::lsh::{hadamard, HadamardHasher, HyperplaneHasher};
use crate::tensor::Mat;
use crate::util::Rng;

/// Incremental per-head YOSO state: `m` bucket tables (each 2^tau × dv)
/// plus the hasher drawn at construction. See the module doc for the
/// bit-identity contract.
pub struct YosoStream {
    tau: usize,
    m: usize,
    fast: bool,
    normalize: bool,
    d: usize,
    dv: usize,
    /// arena-idiom hasher slots: `reset` refills in place, no realloc
    hyper: Option<HyperplaneHasher>,
    hada: Option<HadamardHasher>,
    /// m contiguous tables, hash h at `[h·2^tau·dv ..][.. 2^tau·dv]`
    tables: Vec<f32>,
    n_keys: usize,
    /// grow-only scratch: normalized key/query copies, hasher
    /// projections, per-hash codes, and the tail-overlay table copy
    kn: Mat,
    qn: Mat,
    proj: Vec<f32>,
    codes: Vec<u32>,
    scratch_tables: Vec<f32>,
}

impl YosoStream {
    /// A fresh stream for one head of `att`, drawing the hasher from
    /// `rng` exactly as a batch forward would (same geometry, same draw
    /// order), so streamed and batch outputs share the randomness.
    pub fn new(att: &YosoAttention, d: usize, dv: usize, rng: &mut Rng) -> YosoStream {
        let nb = 1usize << att.tau;
        let mut s = YosoStream {
            tau: att.tau,
            m: att.m,
            fast: att.fast_hash,
            normalize: att.normalize,
            d,
            dv,
            hyper: None,
            hada: None,
            tables: vec![0.0; att.m * nb * dv],
            n_keys: 0,
            kn: Mat::zeros(0, 0),
            qn: Mat::zeros(0, 0),
            proj: Vec::new(),
            codes: Vec::new(),
            scratch_tables: Vec::new(),
        };
        s.reset(rng);
        s
    }

    /// Rewind to an empty session with a freshly drawn hasher, reusing
    /// every buffer (the statelessness surface the property test's
    /// interleaved-session check exercises): a reset stream is
    /// bit-identical to a newly constructed one.
    pub fn reset(&mut self, rng: &mut Rng) {
        if self.fast {
            prep_hada(&mut self.hada, rng, self.m, self.d, self.tau);
        } else {
            prep_hyper(&mut self.hyper, rng, self.m, self.d, self.tau);
        }
        self.tables.fill(0.0);
        self.n_keys = 0;
    }

    /// Keys appended so far (the session length this head has absorbed).
    pub fn n_keys(&self) -> usize {
        self.n_keys
    }

    pub fn is_empty(&self) -> bool {
        self.n_keys == 0
    }

    /// Hash rounds this session was absorbed at — the ceiling for the
    /// `m_read` argument of the gather entry points.
    pub fn m(&self) -> usize {
        self.m
    }

    /// Approximate resident bytes (tables + grow-only scratch + hasher
    /// storage) — the cache's eviction currency.
    pub fn approx_bytes(&self) -> usize {
        let hasher = if self.fast {
            self.m * hadamard::ROUNDS * self.d
        } else {
            self.m * self.tau * self.d
        };
        (self.tables.len()
            + self.scratch_tables.len()
            + self.proj.len()
            + self.kn.data.len()
            + self.qn.data.len()
            + hasher)
            * 4
            + self.codes.len() * 4
    }

    fn grow_scratch(&mut self, n: usize) {
        grow_u32(&mut self.codes, n);
        grow_f32(
            &mut self.proj,
            if self.fast { n * self.d } else { n * self.tau },
        );
    }

    /// Fold `t` new tokens into the session: `tables[h][f_h(K_j)] += V_j`
    /// for each hash, O(m·dv) per token. Rows are accumulated in
    /// ascending order, continuing the session's global-`j` order.
    /// Zero heap allocation once scratch is warm at this chunk size.
    pub fn append(&mut self, k: &Mat, v: &Mat) {
        assert_eq!(k.cols, self.d, "key dim mismatch");
        assert_eq!(v.cols, self.dv, "value dim mismatch");
        assert_eq!(k.rows, v.rows, "key/value row mismatch");
        let t = k.rows;
        if t == 0 {
            return;
        }
        copy_unit_rows(&mut self.kn, k);
        self.grow_scratch(t);
        let YosoStream {
            tau, m, fast, dv, hyper, hada, tables, kn, proj, codes, ..
        } = self;
        scatter_chunk(
            hyper.as_ref(),
            hada.as_ref(),
            *fast,
            *m,
            1usize << *tau,
            *dv,
            kn,
            v,
            proj,
            &mut codes[..t],
            tables,
        );
        self.n_keys += t;
    }

    /// Gather every query row against the first `m_read ≤ m` tables:
    /// `out_i = (1/m') Σ_{h<m'} tables[h][f_h(Q_i)]`, l2-normalized when
    /// the source attention does (N-YOSO). `out` must be (q.rows, dv).
    /// At `m_read == m` this is bit-identical to a batch forward over
    /// all appended keys; at `m_read < m` it is bit-identical to a
    /// fresh `m_read`-round forward (see the module doc's m'-prefix
    /// readout contract).
    pub fn finish_into(&mut self, q: &Mat, m_read: usize, out: &mut Mat) {
        assert!(
            (1..=self.m).contains(&m_read),
            "m_read {m_read} outside [1, {}]",
            self.m
        );
        assert_eq!(q.cols, self.d, "query dim mismatch");
        assert_eq!((out.rows, out.cols), (q.rows, self.dv), "out must be (nq, dv)");
        let nq = q.rows;
        copy_unit_rows(&mut self.qn, q);
        self.grow_scratch(nq);
        let YosoStream {
            tau, fast, dv, normalize, hyper, hada, tables, qn, proj, codes, ..
        } = self;
        gather_block(
            hyper.as_ref(),
            hada.as_ref(),
            *fast,
            m_read,
            1usize << *tau,
            *dv,
            qn,
            proj,
            &mut codes[..nq],
            tables,
            *normalize,
            out,
        );
    }

    /// `finish_into`, but with `tail_k`/`tail_v` rows overlaid *after*
    /// the appended session rows on a scratch copy of the tables — the
    /// bucketed-batch PAD tail, without contaminating session state.
    /// Tail rows sit at global indices past every appended row, so
    /// appending them last preserves the ascending summation order and
    /// the result is bit-identical to one batch forward over
    /// session-keys ++ tail-keys at `m_read` hash rounds. Only the
    /// first `m_read` tables are copied and overlaid, so a degraded
    /// readout pays O(m'·2^tau·dv), not O(m·2^tau·dv).
    pub fn finish_with_tail_into(
        &mut self,
        q: &Mat,
        tail_k: &Mat,
        tail_v: &Mat,
        m_read: usize,
        out: &mut Mat,
    ) {
        let t = tail_k.rows;
        if t == 0 {
            self.finish_into(q, m_read, out);
            return;
        }
        assert!(
            (1..=self.m).contains(&m_read),
            "m_read {m_read} outside [1, {}]",
            self.m
        );
        assert_eq!(tail_k.cols, self.d, "tail key dim mismatch");
        assert_eq!(tail_v.cols, self.dv, "tail value dim mismatch");
        assert_eq!(tail_k.rows, tail_v.rows, "tail key/value row mismatch");
        assert_eq!(q.cols, self.d, "query dim mismatch");
        assert_eq!((out.rows, out.cols), (q.rows, self.dv), "out must be (nq, dv)");
        let nb = 1usize << self.tau;
        let read_len = m_read * nb * self.dv;
        grow_f32(&mut self.scratch_tables, self.tables.len());
        let nq = q.rows;
        // overlay the tail on a copy of the live table prefix
        copy_unit_rows(&mut self.kn, tail_k);
        self.grow_scratch(t.max(nq));
        {
            let YosoStream {
                fast, dv, hyper, hada, tables, scratch_tables, kn, proj,
                codes, ..
            } = self;
            let scratch = &mut scratch_tables[..read_len];
            scratch.copy_from_slice(&tables[..read_len]);
            scatter_chunk(
                hyper.as_ref(),
                hada.as_ref(),
                *fast,
                m_read,
                nb,
                *dv,
                kn,
                tail_v,
                proj,
                &mut codes[..t],
                scratch,
            );
        }
        copy_unit_rows(&mut self.qn, q);
        let YosoStream {
            fast, dv, normalize, hyper, hada, scratch_tables, qn,
            proj, codes, ..
        } = self;
        gather_block(
            hyper.as_ref(),
            hada.as_ref(),
            *fast,
            m_read,
            nb,
            *dv,
            qn,
            proj,
            &mut codes[..nq],
            &scratch_tables[..read_len],
            *normalize,
            out,
        );
    }
}

/// Hash `kn`'s rows per hash and accumulate `v`'s rows into `tables`,
/// ascending local order (helper shared by live appends and the
/// tail overlay).
#[allow(clippy::too_many_arguments)]
fn scatter_chunk(
    hyper: Option<&HyperplaneHasher>,
    hada: Option<&HadamardHasher>,
    fast: bool,
    m: usize,
    nb: usize,
    dv: usize,
    kn: &Mat,
    v: &Mat,
    proj: &mut [f32],
    codes: &mut [u32],
    tables: &mut [f32],
) {
    for h in 0..m {
        if fast {
            hada.unwrap().hash_block_into(kn, h, proj, codes);
        } else {
            hyper.unwrap().hash_block_into(kn, h, proj, codes);
        }
        let table = &mut tables[h * nb * dv..(h + 1) * nb * dv];
        for (j, &c) in codes.iter().enumerate() {
            let b = c as usize;
            add_rows_8(&mut table[b * dv..(b + 1) * dv], v.row(j));
        }
    }
}

/// Hash `qn`'s rows per hash and gather `out_i += tables[h][code] / m`
/// over the first `m` tables of `tables` (the m'-prefix readout when
/// `m` is below the session's absorption rounds), then optionally
/// l2-normalize — the batch kernels' gather order.
#[allow(clippy::too_many_arguments)]
fn gather_block(
    hyper: Option<&HyperplaneHasher>,
    hada: Option<&HadamardHasher>,
    fast: bool,
    m: usize,
    nb: usize,
    dv: usize,
    qn: &Mat,
    proj: &mut [f32],
    codes: &mut [u32],
    tables: &[f32],
    normalize: bool,
    out: &mut Mat,
) {
    out.data.fill(0.0);
    let inv_m = 1.0 / m as f32;
    for h in 0..m {
        if fast {
            hada.unwrap().hash_block_into(qn, h, proj, codes);
        } else {
            hyper.unwrap().hash_block_into(qn, h, proj, codes);
        }
        let table = &tables[h * nb * dv..(h + 1) * nb * dv];
        for (i, &c) in codes.iter().enumerate() {
            let b = c as usize;
            axpy_rows_8(
                inv_m,
                &table[b * dv..(b + 1) * dv],
                &mut out.data[i * dv..(i + 1) * dv],
            );
        }
    }
    if normalize {
        out.l2_normalize_rows();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{Attention, KernelVariant};

    fn setup(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let q = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let v = Mat::randn(n, d, 1.0, &mut rng);
        (q, k, v)
    }

    #[test]
    fn one_shot_append_matches_batch_forward() {
        for fast in [false, true] {
            let att = YosoAttention::new(5, 4, fast)
                .with_kernel(KernelVariant::Fused);
            let (q, k, v) = setup(24, 16, 3);
            let expected = att.forward(&q, &k, &v, &mut Rng::new(11));
            let mut s = YosoStream::new(&att, 16, 16, &mut Rng::new(11));
            s.append(&k, &v);
            let mut out = Mat::zeros(q.rows, v.cols);
            s.finish_into(&q, s.m(), &mut out);
            assert_eq!(s.n_keys(), 24);
            for (a, b) in out.data.iter().zip(&expected.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "fast={fast}");
            }
        }
    }

    #[test]
    fn tail_overlay_leaves_session_state_intact() {
        let att = YosoAttention::new(4, 3, false);
        let (q, k, v) = setup(20, 16, 7);
        let real = 12usize;
        let k_real = Mat::from_fn(real, 16, |i, j| k.at(i, j));
        let v_real = Mat::from_fn(real, 16, |i, j| v.at(i, j));
        let k_tail = Mat::from_fn(20 - real, 16, |i, j| k.at(real + i, j));
        let v_tail = Mat::from_fn(20 - real, 16, |i, j| v.at(real + i, j));
        let expected = att.forward(&q, &k, &v, &mut Rng::new(5));
        let mut s = YosoStream::new(&att, 16, 16, &mut Rng::new(5));
        s.append(&k_real, &v_real);
        let mut out = Mat::zeros(q.rows, v.cols);
        // twice: the overlay must not leak tail rows into the session
        for pass in 0..2 {
            s.finish_with_tail_into(&q, &k_tail, &v_tail, s.m(), &mut out);
            for (a, b) in out.data.iter().zip(&expected.data) {
                assert_eq!(a.to_bits(), b.to_bits(), "pass {pass}");
            }
            assert_eq!(s.n_keys(), real, "tail must not count as appended");
        }
    }

    #[test]
    fn reset_replays_a_fresh_stream() {
        let att = YosoAttention::new(4, 2, true);
        let (q, k, v) = setup(16, 16, 9);
        let mut s = YosoStream::new(&att, 16, 16, &mut Rng::new(1));
        s.append(&k, &v);
        let mut first = Mat::zeros(q.rows, v.cols);
        s.finish_into(&q, s.m(), &mut first);
        // pollute, then reset with the same seed: bytes must replay
        s.append(&q, &v);
        s.reset(&mut Rng::new(1));
        assert!(s.is_empty());
        s.append(&k, &v);
        let mut second = Mat::zeros(q.rows, v.cols);
        s.finish_into(&q, s.m(), &mut second);
        for (a, b) in first.data.iter().zip(&second.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn prefix_readout_matches_fresh_lower_m_forward() {
        // a session absorbed at m = 8 read at m' ∈ {1, 2, 4} must be
        // bit-identical to a fresh m'-round forward from the same RNG
        // seed — the hash-major draw order makes the m'-hasher a prefix
        // of the m-hasher (the contract the degradation ladder rides)
        for fast in [false, true] {
            let att = YosoAttention::new(5, 8, fast);
            let (q, k, v) = setup(24, 16, 13);
            let mut s = YosoStream::new(&att, 16, 16, &mut Rng::new(17));
            s.append(&k, &v);
            for m_read in [1usize, 2, 4, 8] {
                let small = YosoAttention::new(5, m_read, fast);
                let expected = small.forward(&q, &k, &v, &mut Rng::new(17));
                let mut out = Mat::zeros(q.rows, v.cols);
                s.finish_into(&q, m_read, &mut out);
                for (a, b) in out.data.iter().zip(&expected.data) {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "fast={fast} m_read={m_read}"
                    );
                }
            }
        }
    }

    #[test]
    fn approx_bytes_counts_tables() {
        let att = YosoAttention::new(6, 8, false);
        let s = YosoStream::new(&att, 32, 32, &mut Rng::new(2));
        // m · 2^tau · dv floats of tables at minimum
        assert!(s.approx_bytes() >= 8 * 64 * 32 * 4);
    }
}
