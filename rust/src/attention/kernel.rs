//! Fused, zero-allocation YOSO kernel core.
//!
//! The seed-faithful kernel (`YosoAttention` with [`KernelVariant::Seed`])
//! re-allocates its bucket table, code buffers, hasher projections, and
//! normalized q/k copies on every forward, hashes one token at a time,
//! and scatters value rows at random bucket offsets — so serving
//! throughput measures allocator churn and cache misses, not the
//! algorithm. This module is the rewrite (Fig. 3 / Remark 3's constant
//! factor, made real):
//!
//! * [`KernelArena`] — one reusable workspace (bucket table, per-hash
//!   codes, bucket-sort index buffers, hasher plane/sign storage and
//!   projection scratch, normalized q/k copies). Buffers only grow;
//!   steady-state forwards at a fixed geometry allocate **zero** heap
//!   (asserted by `tests/alloc_kernel.rs` via the counting allocator).
//!   Long-lived workers (pool threads, gateway replicas) reach it
//!   through a thread-local slot ([`with_arena`]); the explicit API
//!   (`YosoAttention::forward_fused_into`) is there for callers that
//!   own their arena.
//! * **Fused per-hash pipeline** — hash → scatter → gather one hash at a
//!   time, so code buffers are sized `n` instead of `m·n` and stay hot
//!   in L1 across the scatter and gather of their hash round.
//! * **Bucket-sorted streaming scatter** — a *stable* counting sort of
//!   key indices by bucket turns the seed kernel's random-offset table
//!   writes into bucket-contiguous sequential accumulation. Stability
//!   preserves the ascending-`j` addition order within each bucket —
//!   the seed kernel's exact floating-point summation order — so
//!   outputs stay **bit-identical** (property-tested in
//!   `tests/prop_kernel_equiv.rs`).
//! * **Matmul-backed hashing** — `HyperplaneHasher::hash_block_into`
//!   projects all tokens of one hash through a tiled matmul (each plane
//!   row streams once per 8-token tile); every projection is exactly
//!   `linalg::dot`, so sign bits match the seed per-token loop
//!   bit-for-bit. `HadamardHasher::hash_block_into` runs the HD3
//!   transform in the arena's scratch instead of a per-call buffer.
//!
//! The accumulation loops run on `chunks_exact(8)` bodies (SIMD-friendly
//! fixed-width inner loops); each element's add is independent, so the
//! reordering is layout-only and the bytes are unchanged.
//!
//! `YOSO_KERNEL=seed|fused` selects the default variant at construction
//! ([`KernelVariant::from_env`]) so benches and CI can A/B the two
//! kernels; the seed kernel stays the property-test oracle.

use super::yoso::{WorkspaceTrace, YosoAttention};
use crate::lsh::{hadamard, HadamardHasher, HyperplaneHasher};
use crate::obs::{KernelProbe, Phase};
use crate::tensor::Mat;
use crate::util::Rng;
use std::cell::RefCell;

/// Which implementation runs the YOSO scatter/gather hot path. Outputs
/// are bit-identical between the variants (property-tested); the choice
/// is a pure performance knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum KernelVariant {
    /// The seed repo's kernel, preserved verbatim: per-token hashing,
    /// random-offset scatter, fresh allocations per forward. The A/B
    /// baseline and property-test oracle.
    Seed,
    /// The arena-backed fused pipeline above.
    #[default]
    Fused,
}

impl KernelVariant {
    /// Default variant from `YOSO_KERNEL` (`seed` selects the baseline,
    /// `fused`/unset/empty the fused kernel; anything else panics so a
    /// typo'd A/B — `YOSO_KERNEL=Sead` — fails loudly instead of
    /// silently benchmarking fused against fused).
    pub fn from_env() -> KernelVariant {
        KernelVariant::from_setting(std::env::var("YOSO_KERNEL").ok().as_deref())
    }

    /// The `YOSO_KERNEL` parse itself, env-free so tests cover it
    /// without `set_var` (mutating the process environment races
    /// parallel tests that call `getenv` — UB on glibc).
    pub fn from_setting(v: Option<&str>) -> KernelVariant {
        match v.map(str::trim) {
            Some("seed") => KernelVariant::Seed,
            Some("fused") | Some("") | None => KernelVariant::Fused,
            Some(other) => {
                panic!("YOSO_KERNEL must be `seed` or `fused`, got `{other}`")
            }
        }
    }

    /// Stable label for CSV columns and logs.
    pub fn label(self) -> &'static str {
        match self {
            KernelVariant::Seed => "seed",
            KernelVariant::Fused => "fused",
        }
    }
}

/// Reusable workspace for the fused kernel. Construct once per
/// long-lived owner (worker thread, replica, bench loop) and thread it
/// through every forward: after the first call at a given geometry,
/// subsequent forwards allocate nothing. Slice buffers never shrink and
/// engine rounds (m = 1) keep hasher slots separate from full forwards,
/// so a mixed workload (different sequence lengths, engine rounds
/// interleaved with forwards) settles at the high-water footprint; only
/// a change of the *full-forward* hasher geometry (m, d, tau) — e.g.
/// alternating two different attention configs on one thread — rebuilds
/// that hasher's plane/sign storage.
pub struct KernelArena {
    /// normalized query/key copies (the seed kernel's `unit_rows`)
    qn: Mat,
    kn: Mat,
    /// bucket table H, 2^tau x dv
    table: Vec<f32>,
    /// per-hash codes (sized n, not m·n — the fused pipeline's point)
    codes_q: Vec<u32>,
    codes_k: Vec<u32>,
    /// hasher scratch: (n, tau) projections or the (n, d) HD3 buffer
    proj: Vec<f32>,
    /// counting-sort bucket offsets (2^tau + 1)
    counts: Vec<u32>,
    /// key indices, stable-sorted by bucket
    order: Vec<u32>,
    /// arena-held hashers; `refill` redraws them without reallocating.
    /// Full forwards (m = att.m) and engine rounds (m = 1) keep separate
    /// slots so a thread interleaving both — a serve worker also running
    /// engine chunks — settles without per-call hasher reallocation.
    hyper: Option<HyperplaneHasher>,
    hada: Option<HadamardHasher>,
    hyper_round: Option<HyperplaneHasher>,
    hada_round: Option<HadamardHasher>,
    /// phase timers (`obs`): latches the global trace gate once per
    /// forward; pure branches when tracing is off, zero-alloc once its
    /// span scratch is warm when on
    probe: KernelProbe,
}

impl Default for KernelArena {
    fn default() -> Self {
        KernelArena::new()
    }
}

impl KernelArena {
    /// An empty arena: nothing allocated until the first forward.
    pub fn new() -> KernelArena {
        KernelArena {
            qn: Mat::zeros(0, 0),
            kn: Mat::zeros(0, 0),
            table: Vec::new(),
            codes_q: Vec::new(),
            codes_k: Vec::new(),
            proj: Vec::new(),
            counts: Vec::new(),
            order: Vec::new(),
            hyper: None,
            hada: None,
            hyper_round: None,
            hada_round: None,
            probe: KernelProbe::new(),
        }
    }

    /// This arena's cumulative kernel phase profile (see
    /// [`KernelProbe::phase_total`]); all zeros unless tracing
    /// (`YOSO_TRACE` / `obs::set_trace_enabled`) was on during forwards.
    pub fn probe(&self) -> &KernelProbe {
        &self.probe
    }

    /// Grow (never shrink) every buffer a forward at this geometry
    /// touches. No-op — zero allocation — once warm.
    fn grow(&mut self, nq: usize, nk: usize, d: usize, dv: usize, tau: usize, fast: bool) {
        let nb = 1usize << tau;
        grow_f32(&mut self.table, nb * dv);
        grow_u32(&mut self.codes_q, nq);
        grow_u32(&mut self.codes_k, nk);
        grow_u32(&mut self.counts, nb + 1);
        grow_u32(&mut self.order, nk);
        let n = nq.max(nk);
        grow_f32(&mut self.proj, if fast { n * d } else { n * tau });
    }
}

pub(crate) fn grow_f32(v: &mut Vec<f32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}

pub(crate) fn grow_u32(v: &mut Vec<u32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0);
    }
}

thread_local! {
    static TLS_ARENA: RefCell<KernelArena> = RefCell::new(KernelArena::new());
}

/// Run `f` with this thread's kernel arena. Worker threads are
/// long-lived (pool workers, gateway replicas, the serve loops), so
/// steady-state forwards find warm buffers here and allocate nothing.
/// Do not call `with_arena` again from inside `f` (single slot).
pub fn with_arena<R>(f: impl FnOnce(&mut KernelArena) -> R) -> R {
    TLS_ARENA.with(|a| f(&mut a.borrow_mut()))
}

/// Bucket-sort scratch bytes: counting-sort offsets + sorted key order.
pub(crate) fn sort_scratch_bytes(tau: usize, nk: usize) -> usize {
    ((1usize << tau) + 1 + nk) * 4
}

/// Hasher storage + projection scratch bytes for `m` hashes over `n`
/// tokens: planes and an (n, tau) projection block for the hyperplane
/// hasher, sign diagonals and the (n, d) HD3 buffer for Hadamard.
pub(crate) fn hash_scratch_bytes(
    tau: usize,
    m: usize,
    fast: bool,
    n: usize,
    d: usize,
) -> usize {
    if fast {
        (m * hadamard::ROUNDS * d + n * d) * 4
    } else {
        (m * tau * d + n * tau) * 4
    }
}

/// Copy `src` into `dst` and l2-normalize rows in place — the seed
/// kernel's `unit_rows`, minus the allocation once `dst` has capacity.
pub(crate) fn copy_unit_rows(dst: &mut Mat, src: &Mat) {
    dst.rows = src.rows;
    dst.cols = src.cols;
    dst.data.clear();
    dst.data.extend_from_slice(&src.data);
    dst.l2_normalize_rows();
}

/// Reuse or (re)build the arena's hyperplane hasher for this geometry,
/// drawing the exact RNG sequence a fresh construction would.
pub(crate) fn prep_hyper(
    slot: &mut Option<HyperplaneHasher>,
    rng: &mut Rng,
    m: usize,
    d: usize,
    tau: usize,
) {
    match slot {
        Some(h) if h.m == m && h.d == d && h.tau == tau => h.refill(rng),
        _ => *slot = Some(HyperplaneHasher::new(rng, m, d, tau)),
    }
}

pub(crate) fn prep_hada(
    slot: &mut Option<HadamardHasher>,
    rng: &mut Rng,
    m: usize,
    d: usize,
    tau: usize,
) {
    match slot {
        Some(h) if h.m == m && h.d == d && h.tau == tau => h.refill(rng),
        _ => *slot = Some(HadamardHasher::new(rng, m, d, tau)),
    }
}

/// `dst[i] += src[i]`, 8-wide fixed chunks (element adds are
/// independent, so the tiling never changes the bytes).
#[inline]
pub(crate) fn add_rows_8(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let mut d8 = dst.chunks_exact_mut(8);
    let mut s8 = src.chunks_exact(8);
    for (d, s) in (&mut d8).zip(&mut s8) {
        for t in 0..8 {
            d[t] += s[t];
        }
    }
    for (d, s) in d8.into_remainder().iter_mut().zip(s8.remainder()) {
        *d += s;
    }
}

/// `dst[i] += a * src[i]`, 8-wide fixed chunks — elementwise identical
/// to the seed gather's `*o += inv_m * s`.
#[inline]
pub(crate) fn axpy_rows_8(a: f32, src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let mut d8 = dst.chunks_exact_mut(8);
    let mut s8 = src.chunks_exact(8);
    for (d, s) in (&mut d8).zip(&mut s8) {
        for t in 0..8 {
            d[t] += a * s[t];
        }
    }
    for (d, s) in d8.into_remainder().iter_mut().zip(s8.remainder()) {
        *d += a * s;
    }
}

/// Stable counting sort of key indices by bucket, then bucket-contiguous
/// accumulation into the table: sequential table writes (each occupied
/// bucket's row is touched once, not once per key), with stability
/// keeping each bucket's additions in ascending-`j` order — the seed
/// kernel's exact floating-point summation order, so the table bytes
/// are identical.
fn scatter_sorted(
    table: &mut [f32],
    counts: &mut [u32],
    order: &mut [u32],
    codes_k: &[u32],
    v: &Mat,
    dv: usize,
) {
    let nb = counts.len() - 1;
    counts.fill(0);
    for &c in codes_k {
        counts[c as usize + 1] += 1;
    }
    for b in 0..nb {
        counts[b + 1] += counts[b];
    }
    for (j, &c) in codes_k.iter().enumerate() {
        let slot = &mut counts[c as usize];
        order[*slot as usize] = j as u32;
        *slot += 1;
    }
    // counts[b] is now the end offset of bucket b
    table.fill(0.0);
    let mut start = 0usize;
    for b in 0..nb {
        let end = counts[b] as usize;
        if end > start {
            let dst = &mut table[b * dv..(b + 1) * dv];
            for &j in &order[start..end] {
                add_rows_8(dst, v.row(j as usize));
            }
        }
        start = end;
    }
}

/// The fused forward: `out` must be (nq, dv) and is overwritten with the
/// raw (unnormalized) B-hat V estimate. Returns the Remark-3 workspace
/// trace (a pure function of shape — never of bucket skew). Zero heap
/// allocation once `arena` is warm at this geometry.
pub(crate) fn forward_fused_into(
    att: &YosoAttention,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    rng: &mut Rng,
    arena: &mut KernelArena,
    out: &mut Mat,
) -> WorkspaceTrace {
    let nq = q.rows;
    let nk = k.rows;
    let d = q.cols;
    let dv = v.cols;
    assert_eq!(k.cols, d);
    assert_eq!(v.rows, nk);
    assert_eq!((out.rows, out.cols), (nq, dv), "out must be (nq, dv)");
    let (tau, m, fast) = (att.tau, att.m, att.fast_hash);
    let nb = 1usize << tau;

    arena.probe.begin_forward();
    arena.probe.enter(Phase::Prep);
    arena.grow(nq, nk, d, dv, tau, fast);
    copy_unit_rows(&mut arena.qn, q);
    copy_unit_rows(&mut arena.kn, k);
    // same draw order as the seed kernel: the whole hasher up front
    if fast {
        prep_hada(&mut arena.hada, rng, m, d, tau);
    } else {
        prep_hyper(&mut arena.hyper, rng, m, d, tau);
    }
    arena.probe.exit();

    out.data.fill(0.0);
    let inv_m = 1.0 / m as f32;
    let KernelArena {
        qn, kn, table, codes_q, codes_k, proj, counts, order, hyper, hada, probe, ..
    } = arena;
    let table = &mut table[..nb * dv];
    let codes_q = &mut codes_q[..nq];
    let codes_k = &mut codes_k[..nk];
    let counts = &mut counts[..nb + 1];
    let order = &mut order[..nk];

    for h in 0..m {
        // Hash is the matmul-backed phase: codes come out of a tiled
        // matrix product, so its timer doubles as the matmul timer
        probe.enter(Phase::Hash);
        if fast {
            let hasher = hada.as_ref().unwrap();
            hasher.hash_block_into(qn, h, proj, codes_q);
            hasher.hash_block_into(kn, h, proj, codes_k);
        } else {
            let hasher = hyper.as_ref().unwrap();
            hasher.hash_block_into(qn, h, proj, codes_q);
            hasher.hash_block_into(kn, h, proj, codes_k);
        }
        probe.exit();
        // scatter: H[f(K_j)] += V_j, bucket-contiguous
        probe.enter(Phase::Scatter);
        scatter_sorted(table, counts, order, codes_k, v, dv);
        probe.exit();
        // gather: Y_i += H[f(Q_i)] / m
        probe.enter(Phase::Gather);
        for (i, &c) in codes_q.iter().enumerate() {
            let b = c as usize;
            axpy_rows_8(inv_m, &table[b * dv..(b + 1) * dv], &mut out.data[i * dv..(i + 1) * dv]);
        }
        probe.exit();
    }
    probe.finish_forward();

    WorkspaceTrace {
        table_bytes: nb * dv * 4,
        codes_bytes: (nq + nk) * 4,
        scratch_bytes: sort_scratch_bytes(tau, nk)
            + hash_scratch_bytes(tau, m, fast, nq.max(nk), d)
            + (nq + nk) * d * 4,
    }
}

/// One engine hash round through the fused pipeline: refill a 1-hash
/// hasher from `rng`, hash, sort-scatter, and gather *raw* sums straight
/// into `acc` (the engine applies 1/m in its chunk reduction, and
/// `acc += 0 + table[b]` equals the seed round's partial-then-add
/// bit-for-bit). `qn`/`kn` are already normalized by the engine.
pub(crate) fn fused_round(
    arena: &mut KernelArena,
    qn: &Mat,
    kn: &Mat,
    v: &Mat,
    tau: usize,
    fast: bool,
    rng: &mut Rng,
    acc: &mut Mat,
) {
    let nq = qn.rows;
    let nk = kn.rows;
    let d = qn.cols;
    let dv = v.cols;
    let nb = 1usize << tau;
    arena.probe.begin_forward();
    arena.probe.enter(Phase::Prep);
    arena.grow(nq, nk, d, dv, tau, fast);
    // the m = 1 round slots, not the full-forward hashers: interleaving
    // engine rounds with trait forwards must not thrash either slot
    if fast {
        prep_hada(&mut arena.hada_round, rng, 1, d, tau);
    } else {
        prep_hyper(&mut arena.hyper_round, rng, 1, d, tau);
    }
    arena.probe.exit();
    let KernelArena {
        table, codes_q, codes_k, proj, counts, order, hyper_round, hada_round, probe, ..
    } = arena;
    let table = &mut table[..nb * dv];
    let codes_q = &mut codes_q[..nq];
    let codes_k = &mut codes_k[..nk];
    probe.enter(Phase::Hash);
    if fast {
        let hasher = hada_round.as_ref().unwrap();
        hasher.hash_block_into(qn, 0, proj, codes_q);
        hasher.hash_block_into(kn, 0, proj, codes_k);
    } else {
        let hasher = hyper_round.as_ref().unwrap();
        hasher.hash_block_into(qn, 0, proj, codes_q);
        hasher.hash_block_into(kn, 0, proj, codes_k);
    }
    probe.exit();
    probe.enter(Phase::Scatter);
    scatter_sorted(table, &mut counts[..nb + 1], &mut order[..nk], codes_k, v, dv);
    probe.exit();
    probe.enter(Phase::Gather);
    for (i, &c) in codes_q.iter().enumerate() {
        let b = c as usize;
        add_rows_8(&mut acc.data[i * dv..(i + 1) * dv], &table[b * dv..(b + 1) * dv]);
    }
    probe.exit();
    probe.finish_forward();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse_and_labels() {
        assert_eq!(KernelVariant::from_setting(Some("seed")), KernelVariant::Seed);
        assert_eq!(KernelVariant::from_setting(Some("fused")), KernelVariant::Fused);
        assert_eq!(KernelVariant::from_setting(Some("")), KernelVariant::Fused);
        assert_eq!(KernelVariant::from_setting(Some(" seed ")), KernelVariant::Seed);
        assert_eq!(KernelVariant::from_setting(None), KernelVariant::Fused);
        assert_eq!(KernelVariant::Seed.label(), "seed");
        assert_eq!(KernelVariant::Fused.label(), "fused");
        assert_eq!(KernelVariant::default(), KernelVariant::Fused);
    }

    #[test]
    #[should_panic(expected = "YOSO_KERNEL")]
    fn variant_parse_rejects_typos() {
        // a typo'd A/B must fail loudly, not silently run fused-vs-fused
        let _ = KernelVariant::from_setting(Some("Sead"));
    }

    #[test]
    fn scatter_sorted_matches_random_offset_scatter() {
        // the streaming scatter vs the seed kernel's loop, same codes
        let mut rng = Rng::new(3);
        let nk = 40;
        let dv = 12; // not a multiple of 8: exercises the remainder path
        let tau = 3;
        let nb = 1usize << tau;
        let v = Mat::randn(nk, dv, 1.0, &mut rng);
        let codes: Vec<u32> = (0..nk).map(|_| rng.below(nb) as u32).collect();
        let mut seed_table = vec![0.0f32; nb * dv];
        for j in 0..nk {
            let b = codes[j] as usize;
            let dst = &mut seed_table[b * dv..(b + 1) * dv];
            for (t, s) in dst.iter_mut().zip(v.row(j)) {
                *t += s;
            }
        }
        let mut table = vec![0.0f32; nb * dv];
        let mut counts = vec![0u32; nb + 1];
        let mut order = vec![0u32; nk];
        scatter_sorted(&mut table, &mut counts, &mut order, &codes, &v, dv);
        for (a, b) in table.iter().zip(&seed_table) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // stability: per bucket, sorted indices ascend
        let mut start = 0usize;
        for b in 0..nb {
            let end = counts[b] as usize;
            assert!(order[start..end].windows(2).all(|w| w[0] < w[1]), "bucket {b}");
            start = end;
        }
    }

    #[test]
    fn arena_buffers_only_grow() {
        let mut a = KernelArena::new();
        a.grow(64, 64, 32, 32, 6, false);
        let big = a.table.len();
        a.grow(8, 8, 8, 8, 3, false);
        assert_eq!(a.table.len(), big, "shrank");
        a.grow(64, 64, 32, 64, 6, false);
        assert!(a.table.len() > big, "grew for wider dv");
    }
}
