//! Longformer (Beltagy et al., 2020): sliding-window attention, the true
//! O(n * w) banded kernel (each query attends to +-window neighbors).

use super::Attention;
use crate::tensor::{linalg, Mat};
use crate::util::Rng;

pub struct Longformer {
    pub window: usize,
}

impl Attention for Longformer {
    fn name(&self) -> &'static str {
        "longformer"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, _rng: &mut Rng) -> Mat {
        let n = q.rows;
        let d = q.cols;
        let dv = v.cols;
        let scale = 1.0 / (d as f32).sqrt();
        let mut out = Mat::zeros(n, dv);
        let mut scores = vec![0.0f32; 2 * self.window + 1];
        for i in 0..n {
            let lo = i.saturating_sub(self.window);
            let hi = (i + self.window + 1).min(n);
            let qrow = q.row(i);
            let mut mx = f32::NEG_INFINITY;
            for (s, j) in (lo..hi).enumerate() {
                scores[s] = linalg::dot(qrow, k.row(j)) * scale;
                mx = mx.max(scores[s]);
            }
            let mut z = 0.0;
            for s in scores.iter_mut().take(hi - lo) {
                *s = (*s - mx).exp();
                z += *s;
            }
            let orow = out.row_mut(i);
            for (s, j) in (lo..hi).enumerate() {
                linalg::axpy(scores[s] / z, v.row(j), orow);
            }
        }
        out
    }

    fn workspace_bytes(&self, _n: usize, _d: usize) -> usize {
        (2 * self.window + 1) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::SoftmaxAttention;

    #[test]
    fn full_window_equals_softmax() {
        // window >= n reproduces exact softmax attention — the same
        // property the paper notes for Longformer at 512/512.
        let mut rng = Rng::new(0);
        let q = Mat::randn(24, 8, 1.0, &mut rng);
        let k = Mat::randn(24, 8, 1.0, &mut rng);
        let v = Mat::randn(24, 8, 1.0, &mut rng);
        let full = Longformer { window: 24 }.forward(&q, &k, &v, &mut rng);
        let exact = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
        assert!(full.max_abs_diff(&exact) < 1e-4);
    }

    #[test]
    fn out_of_window_tokens_ignored() {
        // Values far outside the window must not influence the output.
        let mut rng = Rng::new(1);
        let n = 64;
        let q = Mat::randn(n, 8, 1.0, &mut rng);
        let k = Mat::randn(n, 8, 1.0, &mut rng);
        let mut v1 = Mat::randn(n, 8, 1.0, &mut rng);
        let mut v2 = v1.clone();
        // perturb a value 40 positions away from token 0
        for j in 0..8 {
            v2.set(50, j, 100.0);
        }
        let a1 = Longformer { window: 4 }.forward(&q, &k, &v1, &mut rng);
        let a2 = Longformer { window: 4 }.forward(&q, &k, &v2, &mut rng);
        for j in 0..8 {
            assert_eq!(a1.at(0, j), a2.at(0, j));
        }
        // but it does influence its neighbors
        assert!(a1.max_abs_diff(&a2) > 0.1);
        v1.set(0, 0, v1.at(0, 0)); // silence unused-mut lint path
    }
}
