//! Linformer (Wang et al., 2020): project keys/values along the sequence
//! axis to a fixed dimension k, then exact softmax over the projected
//! sequence — O(n * k).

use super::Attention;
use crate::tensor::Mat;
use crate::util::Rng;

pub struct Linformer {
    pub k_proj: usize,
    /// (max_n, k) shared projection; rows beyond the current n are unused.
    proj: Mat,
}

impl Linformer {
    pub fn new(rng: &mut Rng, k_proj: usize, _d: usize) -> Self {
        // Shared E = F projection as in the paper's most efficient setting.
        // Sized lazily up to 16k tokens.
        let max_n = 16384;
        let std = 1.0 / (k_proj as f32).sqrt();
        Linformer { k_proj, proj: Mat::randn(max_n, k_proj, std, rng) }
    }

    fn project(&self, x: &Mat) -> Mat {
        // (k, n) @ (n, d) using the first n rows of proj
        let n = x.rows;
        let mut out = Mat::zeros(self.k_proj, x.cols);
        for i in 0..n {
            let w = self.proj.row(i);
            let xr = x.row(i);
            for (kk, wk) in w.iter().enumerate().take(self.k_proj) {
                let dst = out.row_mut(kk);
                for (d, xv) in dst.iter_mut().zip(xr) {
                    *d += wk * xv;
                }
            }
        }
        out
    }
}

impl Attention for Linformer {
    fn name(&self) -> &'static str {
        "linformer"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, _rng: &mut Rng) -> Mat {
        let kp = self.project(k); // (kproj, d)
        let vp = self.project(v); // (kproj, dv)
        let mut scores = q.matmul_t(&kp); // (n, kproj)
        scores.scale(1.0 / (q.cols as f32).sqrt());
        scores.softmax_rows();
        scores.matmul(&vp)
    }

    fn workspace_bytes(&self, n: usize, d: usize) -> usize {
        (n * self.k_proj + 2 * self.k_proj * d) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_shape_and_finite() {
        let mut rng = Rng::new(0);
        let lin = Linformer::new(&mut rng, 32, 16);
        let q = Mat::randn(128, 16, 1.0, &mut rng);
        let k = Mat::randn(128, 16, 1.0, &mut rng);
        let v = Mat::randn(128, 16, 1.0, &mut rng);
        let out = lin.forward(&q, &k, &v, &mut rng);
        assert_eq!((out.rows, out.cols), (128, 16));
        assert!(out.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn output_is_convex_combination_of_projected_values() {
        // Softmax rows are convex weights over the *projected* values, so
        // every output entry lies within that column's projected range.
        let mut rng = Rng::new(1);
        let lin = Linformer::new(&mut rng, 16, 8);
        let q = Mat::randn(64, 8, 1.0, &mut rng);
        let k = Mat::randn(64, 8, 1.0, &mut rng);
        let v = Mat::randn(64, 8, 1.0, &mut rng);
        let vp = lin.project(&v);
        let out = lin.forward(&q, &k, &v, &mut rng);
        for j in 0..8 {
            let lo = (0..vp.rows).map(|i| vp.at(i, j)).fold(f32::INFINITY, f32::min);
            let hi = (0..vp.rows).map(|i| vp.at(i, j)).fold(f32::NEG_INFINITY, f32::max);
            for i in 0..out.rows {
                let x = out.at(i, j);
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4, "({i},{j}): {x} not in [{lo},{hi}]");
            }
        }
    }
}
