//! Exact softmax attention — the O(n^2) baseline the paper approximates.

use super::Attention;
use crate::tensor::Mat;
use crate::util::Rng;

pub struct SoftmaxAttention;

impl Attention for SoftmaxAttention {
    fn name(&self) -> &'static str {
        "softmax"
    }

    fn forward(&self, q: &Mat, k: &Mat, v: &Mat, _rng: &mut Rng) -> Mat {
        let mut scores = q.matmul_t(k); // (n, n)
        scores.scale(1.0 / (q.cols as f32).sqrt());
        scores.softmax_rows();
        scores.matmul(v)
    }

    fn workspace_bytes(&self, n: usize, _d: usize) -> usize {
        n * n * 4 // the full attention matrix
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_when_keys_identical() {
        // If all keys are identical, attention output = mean-ish of values
        // (every row of the softmax matrix is uniform).
        let mut rng = Rng::new(0);
        let n = 8;
        let d = 4;
        let q = Mat::randn(n, d, 1.0, &mut rng);
        let k = Mat::from_fn(n, d, |_, j| if j == 0 { 1.0 } else { 0.0 });
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let out = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
        let mut mean = vec![0.0f32; d];
        for i in 0..n {
            for j in 0..d {
                mean[j] += v.at(i, j) / n as f32;
            }
        }
        for i in 0..n {
            for j in 0..d {
                assert!((out.at(i, j) - mean[j]).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn sharp_peak_selects_matching_value() {
        // Query exactly equal to one key (scaled up) attends mostly there.
        let d = 16;
        let n = 8;
        let mut rng = Rng::new(1);
        let k = Mat::randn(n, d, 1.0, &mut rng).unit_rows();
        let mut q = Mat::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                q.set(i, j, k.at(i, j) * 40.0);
            }
        }
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let out = SoftmaxAttention.forward(&q, &k, &v, &mut rng);
        for i in 0..n {
            for j in 0..d {
                assert!((out.at(i, j) - v.at(i, j)).abs() < 0.15);
            }
        }
    }
}
